"""AOT driver: lower every Layer-2 workload graph to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the published ``xla`` rust crate) rejects (``proto.id() <=
INT_MAX``). The HLO text parser reassigns ids, so text round-trips
cleanly. See /opt/xla-example/README.md.

Outputs, per workload:
  artifacts/<name>.hlo.txt   — the lowered module
  artifacts/manifest.json    — input shapes/dtypes + output arity, consumed
                               by rust/src/runtime/manifest.rs

``--stats`` additionally prints per-module HLO op histograms (the L2 perf
check: one fused module per workload, no duplicated kernel bodies).
"""

import argparse
import collections
import hashlib
import json
import os
import re
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import WORKLOADS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def op_histogram(hlo_text: str) -> dict:
    """Count HLO instruction opcodes (cheap text-level cost analysis)."""
    hist = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*[\w\[\],<>{}\s]*\s([a-z][\w\-]*)\(",
            line,
        )
        if m:
            hist[m.group(2)] += 1
    return dict(hist)


def lower_one(name: str, out_dir: str, stats: bool) -> dict:
    fn, specs = WORKLOADS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_avals = jax.eval_shape(fn, *specs)
    entry = {
        "name": name,
        "hlo": f"{name}.hlo.txt",
        "inputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ],
        "outputs": [
            {"shape": list(o.shape), "dtype": str(o.dtype)} for o in out_avals
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    if stats:
        hist = op_histogram(text)
        top = sorted(hist.items(), key=lambda kv: -kv[1])[:8]
        print(f"  {name:8s} {len(text):>9d} chars  top-ops: "
              + " ".join(f"{k}={v}" for k, v in top))
    return entry


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="comma-separated workload subset")
    ap.add_argument("--stats", action="store_true", help="print HLO op histograms")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = args.only.split(",") if args.only else list(WORKLOADS)
    unknown = [n for n in names if n not in WORKLOADS]
    if unknown:
        print(f"unknown workloads: {unknown}", file=sys.stderr)
        return 2

    manifest = {"workloads": []}
    for name in names:
        print(f"lowering {name} ...", flush=True)
        manifest["workloads"].append(lower_one(name, args.out, args.stats))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['workloads'])} artifacts + manifest to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
