"""Build-time-only package: Layer-2 JAX workload graphs + Layer-1 Pallas
kernels + the AOT lowering driver. Never imported at simulation time —
``make artifacts`` runs :mod:`compile.aot` once and the Rust binary loads
the emitted HLO text via PJRT."""
