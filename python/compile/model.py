"""Layer-2 JAX workload graphs for the CXL-GPU evaluation suite.

Each function here is the *compute* of one Table-1b workload (11
Rodinia-style programs + the two real-world composites gnn and mri),
expressed as a jittable JAX graph that calls the Layer-1 Pallas kernels
for its hot-spot. ``aot.py`` lowers every graph once to HLO text; the
Rust coordinator executes the artifacts via PJRT and drives the memory-
system timing simulator with the matching access streams
(``rust/src/workloads/``).

All graphs return tuples (lowered with ``return_tuple=True``) so the Rust
side can unwrap uniformly.
"""

import jax
import jax.numpy as jnp

from .kernels import conv3, gemm, rsum, saxpy, stencil, vadd

# ---------------------------------------------------------------------------
# Compute-intensive workloads
# ---------------------------------------------------------------------------


def rsum_graph(x):
    """rsum: repeated row-reduction; compute ratio 31.4%, load 53.3%."""
    s = rsum(x)
    # Normalize rows by their sums and reduce again — keeps arithmetic
    # intensity high relative to bytes moved, as Table 1b characterizes.
    y = x / (s + 1.0)
    return (rsum(y),)


def stencil_graph(x, steps: int = 8):
    """stencil: ``steps`` Jacobi sweeps over a 2D grid."""

    def body(_, v):
        return stencil(v)

    return (jax.lax.fori_loop(0, steps, body, x),)


def sort_graph(x):
    """sort: full sort of a vector (binary-tree 'Around' access pattern)."""
    s = jnp.sort(x)
    # Rank lookup makes the graph produce both the sorted keys and an
    # order-dependent checksum, mirroring Rodinia's key-index output pair.
    return (s, jnp.argsort(x).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Load-intensive workloads
# ---------------------------------------------------------------------------


def gemm_graph(x, y):
    """gemm: dense matmul; load ratio 99.9%."""
    return (gemm(x, y),)


def vadd_graph(x, y):
    """vadd: 1D vector add; the paper's flagship SR workload (15.6x)."""
    return (vadd(x, y),)


def saxpy_graph(a, x, y):
    """saxpy: a*x + y."""
    return (saxpy(a, x, y),)


def conv3_graph(x, w):
    """conv3: 3x3 'same' convolution."""
    return (conv3(x, w),)


def path_graph(cost):
    """path: Rodinia pathfinder — DP min-reduction down the rows.

    cost: (H, W). Row i adds min(prev[j-1], prev[j], prev[j+1]).
    Irregular 'Rand'-leaning access in the paper's taxonomy (frontier
    jumps), modest SR benefit.
    """
    cost = cost.astype(jnp.float32)

    def step(prev, row):
        left = jnp.pad(prev[:-1], (1, 0), constant_values=jnp.inf)
        right = jnp.pad(prev[1:], (0, 1), constant_values=jnp.inf)
        best = jnp.minimum(prev, jnp.minimum(left, right))
        nxt = row + best
        return nxt, nxt[0]

    final, trace = jax.lax.scan(step, cost[0], cost[1:])
    return (final, trace)


# ---------------------------------------------------------------------------
# Store-intensive workloads
# ---------------------------------------------------------------------------


def cfd_graph(rho, mom, energy, steps: int = 4):
    """cfd: simplified explicit Euler flux update over 1D fields.

    Store-intensive: every step writes all three conserved fields.
    """
    rho = rho.astype(jnp.float32)
    mom = mom.astype(jnp.float32)
    energy = energy.astype(jnp.float32)

    def body(_, state):
        r, m, e = state
        v = m / (r + 1e-6)
        p = 0.4 * (e - 0.5 * m * v)
        flux_r = m
        flux_m = m * v + p
        flux_e = v * (e + p)

        def ddx(f):
            return 0.5 * (jnp.roll(f, -1) - jnp.roll(f, 1))

        dt = 0.01
        return (r - dt * ddx(flux_r), m - dt * ddx(flux_m), e - dt * ddx(flux_e))

    r, m, e = jax.lax.fori_loop(0, steps, body, (rho, mom, energy))
    return (r, m, e)


def gauss_graph(a):
    """gauss: forward Gaussian elimination of an augmented (N, N+1) system.

    'Around' access pattern: runtime decides current vs previous row.
    """
    a = a.astype(jnp.float32)
    n = a.shape[0]

    def body(i, acc):
        pivot = acc[i, i]
        factors = acc[:, i] / pivot
        rows = jnp.arange(n)
        mask = (rows > i).astype(jnp.float32)[:, None]
        return acc - mask * factors[:, None] * acc[i][None, :]

    return (jax.lax.fori_loop(0, n - 1, body, a),)


def bfs_graph(adj, src_onehot, steps: int = 8):
    """bfs: frontier expansion by boolean-semiring matvec over a dense
    adjacency matrix; store-intensive + 'Rand' access in the taxonomy.

    adj: (N, N) f32 0/1, src_onehot: (N,) f32 one-hot source.
    Returns per-node BFS level (inf where unreached within ``steps``).
    """
    adj = adj.astype(jnp.float32)
    n = adj.shape[0]
    big = jnp.float32(1e9)

    def body(i, state):
        level, frontier = state
        # Neighbour reachability: any frontier node with an edge to v.
        reach = jnp.minimum(adj.T @ frontier, 1.0)
        newly = jnp.where((reach > 0) & (level >= big), 1.0, 0.0)
        level = jnp.where(newly > 0, jnp.float32(i + 1), level)
        return (level, newly)

    level0 = jnp.where(src_onehot > 0, 0.0, big)
    level, _ = jax.lax.fori_loop(0, steps, body, (level0, src_onehot))
    return (level,)


# ---------------------------------------------------------------------------
# Real-world composites (paper: gnn = bfs + vadd + gemm; mri = sort + conv3)
# ---------------------------------------------------------------------------


def gnn_graph(adj, feats, weight, src_onehot):
    """gnn: one message-passing layer — BFS reachability mask, neighbour
    aggregation (vadd-style), then a dense feature transform (gemm).

    adj: (N, N), feats: (N, D), weight: (D, D), src_onehot: (N,).
    """
    (level,) = bfs_graph(adj, src_onehot, steps=4)
    reach = (level < 1e9).astype(feats.dtype)[:, None]
    agg = gemm(adj.astype(feats.dtype), feats) + feats  # aggregate + self
    out = gemm(agg * reach, weight)
    return (out, level)


def mri_graph(kspace, w):
    """mri: gridding-style reconstruction — sort sample magnitudes, then a
    conv3 smoothing pass over the (H, W) image plane.

    kspace: (H, W) image-domain samples, w: (3, 3) smoothing taps.
    """
    flat = kspace.reshape(-1)
    s = jnp.sort(flat)
    # Median-shifted image, then conv3 smoothing (the paper composes the
    # workload from sort + conv3).
    med = s[s.shape[0] // 2]
    img = kspace - med
    return (conv3(img, w), s)


# ---------------------------------------------------------------------------
# Registry used by aot.py: name -> (fn, example-arg builder)
# ---------------------------------------------------------------------------


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: name -> (graph_fn, tuple of ShapeDtypeStructs). Shapes are the AOT
#: example shapes: deliberately small enough for CPU-interpret pallas but
#: large enough to exercise multi-tile grids.
WORKLOADS = {
    "rsum": (rsum_graph, (_f32(512, 512),)),
    "stencil": (stencil_graph, (_f32(256, 256),)),
    "sort": (sort_graph, (_f32(65536),)),
    "gemm": (gemm_graph, (_f32(256, 256), _f32(256, 256))),
    "vadd": (vadd_graph, (_f32(262144), _f32(262144))),
    "saxpy": (saxpy_graph, (_f32(1, 1), _f32(262144), _f32(262144))),
    "conv3": (conv3_graph, (_f32(256, 256), _f32(3, 3))),
    "path": (path_graph, (_f32(256, 1024),)),
    "cfd": (cfd_graph, (_f32(65536), _f32(65536), _f32(65536))),
    "gauss": (gauss_graph, (_f32(128, 129),)),
    "bfs": (bfs_graph, (_f32(512, 512), _f32(512))),
    "gnn": (gnn_graph, (_f32(256, 256), _f32(256, 64), _f32(64, 64), _f32(256))),
    "mri": (mri_graph, (_f32(128, 128), _f32(3, 3))),
}
