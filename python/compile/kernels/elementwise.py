"""Layer-1 Pallas kernels: bandwidth-bound elementwise ops (vadd, saxpy).

These are the compute cores of the paper's load-intensive 1D workloads
(``vadd``, ``saxpy``) — the workloads where Speculative Read shines
(15.6x in Fig. 9b) because their access streams are perfectly sequential.

TPU adaptation: the CUDA grid-stride loop becomes a 1D Pallas grid over
(8, 128)-lane-aligned row blocks; the VPU (not the MXU) executes the adds.
Inputs are reshaped to 2D (rows x 128 lanes) by the wrappers so arbitrary
1D lengths stay tile-aligned.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step of the (rows, 128) working view. 256 rows x 128 lanes
# x 4 B x 3 operands = 384 KiB of VMEM per step — safely inside budget
# while long enough to amortize the HBM->VMEM pipeline.
BLOCK_ROWS = 256
LANES = 128


def _vadd_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _saxpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0, 0] * x_ref[...] + y_ref[...]


def _as_rows(v):
    """View a 1D vector as (rows, LANES), padding to a lane multiple."""
    n = v.shape[0]
    rows = pl.cdiv(n, LANES)
    pad = rows * LANES - n
    if pad:
        v = jnp.pad(v, (0, pad))
    return v.reshape(rows, LANES), n


@jax.jit
def vadd(x, y):
    """Elementwise ``x + y`` over 1D vectors of any length."""
    xv, n = _as_rows(x)
    yv, _ = _as_rows(y)
    rows = xv.shape[0]
    block = min(BLOCK_ROWS, rows)
    out = pl.pallas_call(
        _vadd_kernel,
        grid=(pl.cdiv(rows, block),),
        in_specs=[
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=True,
    )(xv, yv)
    return out.reshape(-1)[:n]


@jax.jit
def saxpy(a, x, y):
    """``a * x + y`` with scalar ``a`` shaped (1, 1), 1D ``x``/``y``."""
    xv, n = _as_rows(x)
    yv, _ = _as_rows(y)
    rows = xv.shape[0]
    block = min(BLOCK_ROWS, rows)
    out = pl.pallas_call(
        _saxpy_kernel,
        grid=(pl.cdiv(rows, block),),
        in_specs=[
            # Scalar broadcast tile: every grid step sees the same (1,1).
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xv.shape, x.dtype),
        interpret=True,
    )(a, xv, yv)
    return out.reshape(-1)[:n]
