"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal for Layer 1: pytest compares each
Pallas kernel (run with ``interpret=True``) against the function of the
same name here, across a hypothesis-driven sweep of shapes and dtypes.

Nothing in this module may import pallas — it must stay a plain-jnp
executable specification.
"""

import jax.numpy as jnp


def gemm(x, y):
    """Dense matmul with f32 accumulation: ``x @ y``.

    x: (M, K), y: (K, N) -> (M, N). Accumulates in float32 regardless of
    input dtype (mirrors the MXU's accumulate-in-f32 behaviour).
    """
    out = jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))
    return out.astype(x.dtype)


def vadd(x, y):
    """Elementwise vector add: ``x + y``."""
    return x + y


def saxpy(a, x, y):
    """Scaled vector add: ``a * x + y`` with scalar ``a`` shaped (1, 1)."""
    return a * x + y


def rsum(x):
    """Row-reduction sum: (M, N) -> (M, 1), f32 accumulation."""
    return jnp.sum(x.astype(jnp.float32), axis=-1, keepdims=True).astype(x.dtype)


def conv3(x, w):
    """3x3 'same' convolution of a single-channel 2D image.

    x: (H, W), w: (3, 3) -> (H, W), zero padding. This is the compute core
    of the paper's ``conv3`` workload (Rodinia-style convolution).
    """
    xp = jnp.pad(x.astype(jnp.float32), ((1, 1), (1, 1)))
    out = jnp.zeros(x.shape, dtype=jnp.float32)
    H, W = x.shape
    for di in range(3):
        for dj in range(3):
            out = out + w[di, dj].astype(jnp.float32) * xp[di:di + H, dj:dj + W]
    return out.astype(x.dtype)


def stencil(x):
    """5-point Jacobi stencil with copied boundary, one sweep.

    x: (H, W) -> (H, W): out[i,j] = 0.25*(up+down+left+right) on the
    interior; boundary rows/cols are copied through unchanged.
    """
    xf = jnp.asarray(x).astype(jnp.float32)
    interior = 0.25 * (xf[:-2, 1:-1] + xf[2:, 1:-1] + xf[1:-1, :-2] + xf[1:-1, 2:])
    out = xf.at[1:-1, 1:-1].set(interior)
    return out.astype(x.dtype)


def gauss_step(a, pivot_row):
    """One Gaussian-elimination step on augmented matrix ``a`` (M, N):
    eliminate column ``pivot_row`` in all rows below ``pivot_row``.

    Compute core of the paper's ``gauss`` workload. Assumes a nonzero
    pivot (test inputs are diagonally dominated).
    """
    a = a.astype(jnp.float32)
    pivot = a[pivot_row, pivot_row]
    factors = a[:, pivot_row] / pivot
    rows = jnp.arange(a.shape[0])
    mask = (rows > pivot_row).astype(jnp.float32)[:, None]
    return a - mask * factors[:, None] * a[pivot_row][None, :]


def spmv_gather(values, col_idx, x):
    """Gather-multiply used by the gnn composite: ``values * x[col_idx]``.

    values: (NNZ,), col_idx: (NNZ,) int32, x: (N,) -> (NNZ,).
    Models the irregular-access multiply of sparse matrix-vector products
    (bfs/gnn style); the segment reduction is done by the caller.
    """
    return values * jnp.take(x, col_idx, axis=0)
