"""Layer-1 Pallas kernel: row-reduction sum (``rsum``).

Compute core of the paper's compute-intensive ``rsum`` workload
(Rodinia-style reduction). TPU adaptation: the CUDA tree reduction in
shared memory becomes a two-level reduce — the VPU reduces each VMEM tile
along the lane axis, and a f32 scratch column accumulates partial sums
across the column-tile grid axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_M = 256
TILE_N = 512


def _rsum_kernel(x_ref, o_ref, acc_ref, *, n_j: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(
        x_ref[...].astype(jnp.float32), axis=-1, keepdims=True
    )

    @pl.when(j == n_j - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@jax.jit
def rsum(x):
    """Row sums of a 2D array: (M, N) -> (M, 1), f32 accumulation."""
    m, n = x.shape
    tile_m = min(TILE_M, m)
    tile_n = min(TILE_N, n)
    # Zero-pad the reduced axis to a tile multiple: interpret-mode ragged
    # blocks are padded with unspecified values, which must not enter the
    # accumulation. (Ragged M is safe — those rows are clipped on write.)
    n_j = pl.cdiv(n, tile_n)
    pad_n = n_j * tile_n - n
    if pad_n:
        x = jnp.pad(x, ((0, 0), (0, pad_n)))
    return pl.pallas_call(
        functools.partial(_rsum_kernel, n_j=n_j),
        grid=(pl.cdiv(m, tile_m), n_j),
        in_specs=[pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile_m, 1), jnp.float32)],
        interpret=True,
    )(x)
