"""Layer-1 Pallas kernel: VMEM-tiled dense GEMM with f32 accumulation.

TPU adaptation of the paper's ``gemm`` workload (Rodinia CUDA matmul):
the CUDA threadblock tiling over shared memory becomes a BlockSpec
HBM->VMEM schedule, and the inner product targets the MXU systolic array
(f32 accumulate). The K dimension is walked by the innermost grid axis;
the accumulator tile lives in a VMEM scratch buffer across K steps.

``interpret=True`` is mandatory in this environment: real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-friendly tile sizes. 128x128 matches the MXU systolic array
# geometry; see DESIGN.md §9 for the VMEM budget (≈256 KiB per grid step).
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _gemm_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x_tile @ y_tile.

    The accumulator scratch persists across the K axis (innermost grid
    dim); on the last K step it is flushed to the output tile.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def gemm(x, y, *, tile_m: int = TILE_M, tile_n: int = TILE_N, tile_k: int = TILE_K):
    """Tiled matmul ``x @ y`` via Pallas.

    x: (M, K), y: (K, N) -> (M, N). M, N, K need not divide the tile
    sizes; Pallas masks the ragged edge blocks.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    tile_m = min(tile_m, m)
    tile_n = min(tile_n, n)
    tile_k = min(tile_k, k)
    n_k = pl.cdiv(k, tile_k)
    # Zero-pad the contraction axis to a tile multiple: interpret-mode
    # ragged blocks are padded with unspecified values, which must not
    # enter the accumulator. (Ragged M/N are safe — clipped on write.)
    pad_k = n_k * tile_k - k
    if pad_k:
        x = jnp.pad(x, ((0, 0), (0, pad_k)))
        y = jnp.pad(y, ((0, pad_k), (0, 0)))

    return pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(pl.cdiv(m, tile_m), pl.cdiv(n, tile_n), n_k),
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        interpret=True,
    )(x, y)
