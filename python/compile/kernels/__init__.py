"""Layer-1 Pallas kernels for the CXL-GPU workload suite.

Each kernel has a pure-jnp oracle of the same name in :mod:`ref`;
``python/tests/test_kernels.py`` sweeps shapes/dtypes with hypothesis and
asserts allclose. All kernels run ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); real-TPU projections are in DESIGN.md §9.
"""

from .conv import conv3
from .elementwise import saxpy, vadd
from .gemm import gemm
from .reduce import rsum
from .stencil import stencil

__all__ = ["conv3", "saxpy", "vadd", "gemm", "rsum", "stencil"]
