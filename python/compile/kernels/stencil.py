"""Layer-1 Pallas kernel: 5-point Jacobi stencil (``stencil``).

Compute core of the paper's compute-intensive ``stencil`` workload
(Rodinia hotspot-style). Same halo strategy as conv3: the padded input is
staged whole and each grid step slices its row strip with a 1-row halo,
computing out = 0.25*(up+down+left+right) on interior points. Boundary
rows/cols are copied through by the wrapper's mask.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STRIP = 128


def _stencil_kernel(xp_ref, o_ref, *, strip: int, width: int):
    i = pl.program_id(0)
    xp = jax.lax.dynamic_slice(
        xp_ref[...], (i * strip, 0), (strip + 2, width + 2)
    ).astype(jnp.float32)
    up = jax.lax.dynamic_slice(xp, (0, 1), (strip, width))
    down = jax.lax.dynamic_slice(xp, (2, 1), (strip, width))
    left = jax.lax.dynamic_slice(xp, (1, 0), (strip, width))
    right = jax.lax.dynamic_slice(xp, (1, 2), (strip, width))
    o_ref[...] = (0.25 * (up + down + left + right)).astype(o_ref.dtype)


@jax.jit
def stencil(x):
    """One Jacobi sweep on (H, W); boundary cells copied unchanged.

    Matches ``ref.stencil``: interior gets the 4-neighbour average,
    boundary rows/columns pass through.
    """
    hgt, width = x.shape
    strip = min(STRIP, hgt)
    n_i = pl.cdiv(hgt, strip)
    pad_bottom = n_i * strip - hgt + 1
    xp = jnp.pad(x, ((1, pad_bottom + 1), (1, 1)))
    swept = pl.pallas_call(
        functools.partial(_stencil_kernel, strip=strip, width=width),
        grid=(n_i,),
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((strip, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hgt, width), x.dtype),
        interpret=True,
    )(xp)
    # Boundary policy lives outside the kernel: copy edges through.
    xf = x.astype(swept.dtype)
    out = xf.at[1:-1, 1:-1].set(swept[1:-1, 1:-1]) if min(hgt, width) > 2 else xf
    return out
