"""Layer-1 Pallas kernel: direct 3x3 'same' convolution (``conv3``).

Compute core of the paper's ``conv3`` workload. TPU adaptation: instead
of the CUDA halo-loaded shared-memory tile, each grid step slices a row
strip (plus 2-row halo) out of the zero-padded input staged in VMEM and
applies the 9 taps as shifted VPU multiply-adds — no im2col, no gather.

Standard BlockSpecs cannot express overlapping (haloed) blocks, so the
padded input is passed whole and the kernel slices its strip with
``program_id``; on real TPU this is the pattern Mosaic double-buffers as
consecutive row strips (DESIGN.md §9).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output rows per grid step; the kernel reads STRIP+2 input rows (halo).
STRIP = 128


def _conv3_kernel(xp_ref, w_ref, o_ref, *, strip: int, width: int):
    i = pl.program_id(0)
    # Strip + halo from the zero-padded image: rows [i*strip, i*strip+strip+2).
    xp = jax.lax.dynamic_slice(
        xp_ref[...], (i * strip, 0), (strip + 2, width + 2)
    ).astype(jnp.float32)
    acc = jnp.zeros((strip, width), dtype=jnp.float32)
    for di in range(3):
        for dj in range(3):
            acc += w_ref[di, dj].astype(jnp.float32) * jax.lax.dynamic_slice(
                xp, (di, dj), (strip, width)
            )
    o_ref[...] = acc.astype(o_ref.dtype)


@jax.jit
def conv3(x, w):
    """3x3 zero-padded 'same' convolution: x (H, W), w (3, 3) -> (H, W)."""
    hgt, width = x.shape
    strip = min(STRIP, hgt)
    n_i = pl.cdiv(hgt, strip)
    # 1-px conv halo on all sides, plus bottom fill so every strip slice is
    # in-bounds (rows written from fill never land in the output: the
    # output BlockSpec clips the last partial strip).
    pad_bottom = n_i * strip - hgt + 1
    xp = jnp.pad(x, ((1, pad_bottom + 1), (1, 1)))
    return pl.pallas_call(
        functools.partial(_conv3_kernel, strip=strip, width=width),
        grid=(n_i,),
        in_specs=[
            # Whole padded image visible to every step (sliced in-kernel).
            pl.BlockSpec(xp.shape, lambda i: (0, 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((strip, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hgt, width), x.dtype),
        interpret=True,
    )(xp, w)
