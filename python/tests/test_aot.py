"""AOT path correctness: HLO text emission, manifest integrity, and
round-trip stability of the interchange format."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.model import WORKLOADS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_to_hlo_text_is_deterministic():
    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    f = lambda x, y: (jnp.matmul(x, y),)
    a = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    b = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
    assert a == b


def test_op_histogram_counts():
    text = """
HloModule m
ENTRY e {
  %p0 = f32[4]{0} parameter(0)
  %p1 = f32[4]{0} parameter(1)
  %a = f32[4]{0} add(%p0, %p1)
  %b = f32[4]{0} add(%a, %p1)
  ROOT %m = f32[4]{0} multiply(%a, %b)
}
"""
    hist = aot.op_histogram(text)
    assert hist["add"] == 2
    assert hist["multiply"] == 1
    assert hist["parameter"] == 2


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_workloads_present(self):
        names = {w["name"] for w in self._manifest()["workloads"]}
        assert names == set(WORKLOADS)

    def test_hlo_files_exist_and_hash(self):
        import hashlib
        for w in self._manifest()["workloads"]:
            path = os.path.join(ART, w["hlo"])
            assert os.path.exists(path), w["hlo"]
            with open(path) as f:
                text = f.read()
            assert hashlib.sha256(text.encode()).hexdigest() == w["sha256"]
            assert "HloModule" in text

    def test_manifest_shapes_match_registry(self):
        for w in self._manifest()["workloads"]:
            _, specs = WORKLOADS[w["name"]]
            assert len(w["inputs"]) == len(specs)
            for mi, spec in zip(w["inputs"], specs):
                assert tuple(mi["shape"]) == tuple(spec.shape)
                assert mi["dtype"] == str(spec.dtype)

    def test_outputs_nonempty(self):
        for w in self._manifest()["workloads"]:
            assert len(w["outputs"]) >= 1
