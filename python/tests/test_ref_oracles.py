"""Self-consistency of the pure-jnp oracles themselves (the contracts the
Pallas kernels are held to), including the composite building blocks not
exercised by a kernel (gauss_step, spmv_gather)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref

COMMON = dict(max_examples=25, deadline=None)


class TestGaussStep:
    def test_eliminates_column_below_pivot(self):
        rng = np.random.default_rng(0)
        n = 8
        a = rng.standard_normal((n, n + 1)).astype(np.float32)
        a[np.arange(n), np.arange(n)] += n
        out = np.asarray(ref.gauss_step(jnp.asarray(a), 0))
        assert_allclose(out[1:, 0], np.zeros(n - 1), atol=1e-5)
        # Row 0 and rows' other structure preserved where expected.
        assert_allclose(out[0], a[0], rtol=1e-6)

    def test_is_idempotent_on_eliminated_column(self):
        rng = np.random.default_rng(1)
        n = 6
        a = rng.standard_normal((n, n + 1)).astype(np.float32)
        a[np.arange(n), np.arange(n)] += n
        once = ref.gauss_step(jnp.asarray(a), 0)
        twice = ref.gauss_step(once, 0)
        assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-4)

    def test_sequence_produces_upper_triangular(self):
        rng = np.random.default_rng(2)
        n = 10
        a = rng.standard_normal((n, n + 1)).astype(np.float32)
        a[np.arange(n), np.arange(n)] += 2 * n
        cur = jnp.asarray(a)
        for i in range(n - 1):
            cur = ref.gauss_step(cur, i)
        lower = np.tril(np.asarray(cur)[:, :n], k=-1)
        assert np.abs(lower).max() < 1e-3


class TestSpmvGather:
    @settings(**COMMON)
    @given(nnz=st.integers(1, 200), n=st.integers(1, 100),
           seed=st.integers(0, 2**31))
    def test_matches_dense_gather(self, nnz, n, seed):
        rng = np.random.default_rng(seed)
        values = rng.standard_normal(nnz).astype(np.float32)
        col_idx = rng.integers(0, n, nnz).astype(np.int32)
        x = rng.standard_normal(n).astype(np.float32)
        got = np.asarray(ref.spmv_gather(values, col_idx, x))
        want = values * x[col_idx]
        assert_allclose(got, want, rtol=1e-6)

    def test_segment_sum_completes_spmv(self):
        # values/col_idx/row_ptr of a tiny CSR matrix; the caller-side
        # reduction the docstring promises.
        values = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
        col_idx = np.array([0, 1, 0, 2], np.int32)
        rows = np.array([0, 0, 1, 1], np.int32)  # segment ids
        x = np.array([10.0, 100.0, 1000.0], np.float32)
        prod = np.asarray(ref.spmv_gather(values, col_idx, x))
        y = jax.ops.segment_sum(jnp.asarray(prod), jnp.asarray(rows), num_segments=2)
        assert_allclose(np.asarray(y), [210.0, 4030.0])


class TestOracleAlgebra:
    @settings(**COMMON)
    @given(m=st.integers(1, 32), k=st.integers(1, 32), seed=st.integers(0, 2**31))
    def test_gemm_identity(self, m, k, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, k)).astype(np.float32)
        eye = np.eye(k, dtype=np.float32)
        assert_allclose(np.asarray(ref.gemm(x, eye)), x, rtol=1e-5, atol=1e-5)

    @settings(**COMMON)
    @given(n=st.integers(1, 500), seed=st.integers(0, 2**31))
    def test_vadd_commutes(self, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(n).astype(np.float32)
        y = rng.standard_normal(n).astype(np.float32)
        assert_allclose(np.asarray(ref.vadd(x, y)), np.asarray(ref.vadd(y, x)))

    def test_rsum_linearity(self):
        rng = np.random.default_rng(5)
        x = rng.standard_normal((16, 64)).astype(np.float32)
        y = rng.standard_normal((16, 64)).astype(np.float32)
        lhs = np.asarray(ref.rsum(x + y))
        rhs = np.asarray(ref.rsum(x)) + np.asarray(ref.rsum(y))
        assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    def test_conv3_linearity_in_kernel(self):
        rng = np.random.default_rng(6)
        x = rng.standard_normal((20, 20)).astype(np.float32)
        w1 = rng.standard_normal((3, 3)).astype(np.float32)
        w2 = rng.standard_normal((3, 3)).astype(np.float32)
        lhs = np.asarray(ref.conv3(x, w1 + w2))
        rhs = np.asarray(ref.conv3(x, w1)) + np.asarray(ref.conv3(x, w2))
        assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)

    def test_stencil_preserves_mean_interior(self):
        # The 4-neighbour average is mean-preserving on a constant field
        # and bounded by min/max on any field (discrete maximum principle).
        rng = np.random.default_rng(7)
        x = rng.standard_normal((32, 32)).astype(np.float32)
        out = np.asarray(ref.stencil(x))
        assert out[1:-1, 1:-1].max() <= x.max() + 1e-6
        assert out[1:-1, 1:-1].min() >= x.min() - 1e-6
