"""Layer-2 correctness: workload graphs compute the right thing and
shape-check at the AOT example shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.model import WORKLOADS


def _zeros_args(specs):
    return [jnp.zeros(s.shape, s.dtype) for s in specs]


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_graph_shapes_match_manifest_contract(name):
    fn, specs = WORKLOADS[name]
    outs = jax.eval_shape(fn, *specs)
    assert isinstance(outs, tuple) and len(outs) >= 1
    for o in outs:
        assert all(d > 0 for d in o.shape) or o.shape == ()


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_graph_executes_finite(name):
    fn, specs = WORKLOADS[name]
    rng = np.random.default_rng(42)
    args = []
    for i, s in enumerate(specs):
        a = rng.standard_normal(s.shape).astype(s.dtype)
        args.append(jnp.asarray(a))
    # Workload-specific validity fixups.
    if name == "gauss":
        a = np.array(args[0])  # writable copy
        n = a.shape[0]
        a[np.arange(n), np.arange(n)] += n  # diagonal dominance
        args[0] = jnp.asarray(a)
    if name in ("bfs", "gnn"):
        adj = (np.asarray(args[0]) > 0.8).astype(np.float32)
        args[0] = jnp.asarray(adj)
        onehot = np.zeros(specs[-1].shape, np.float32)
        onehot[0] = 1.0
        args[-1] = jnp.asarray(onehot)
    if name == "cfd":
        args[0] = jnp.abs(args[0]) + 1.0   # positive density
        args[2] = jnp.abs(args[2]) + 10.0  # positive energy
    outs = jax.jit(fn)(*args)
    for o in outs:
        assert np.isfinite(np.asarray(o)).all(), f"{name} produced non-finite"


def test_path_dp_small_case():
    # 3x3 grid, hand-checked DP.
    cost = jnp.asarray(np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]], np.float32))
    final, _ = jax.jit(model.path_graph)(cost)
    # row0 = [1,2,3]; row1 = [4+1, 5+1, 6+2] = [5,6,8];
    # row2 = [7+5, 8+5, 9+6] = [12,13,15]
    assert_allclose(np.asarray(final), [12, 13, 15])


def test_bfs_levels_line_graph():
    n = 8
    adj = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        adj[i, i + 1] = 1.0
        adj[i + 1, i] = 1.0
    onehot = np.zeros(n, np.float32)
    onehot[0] = 1.0
    (level,) = jax.jit(model.bfs_graph)(jnp.asarray(adj), jnp.asarray(onehot))
    assert_allclose(np.asarray(level), np.arange(n, dtype=np.float32))


def test_gauss_eliminates_lower_triangle():
    rng = np.random.default_rng(3)
    n = 16
    a = rng.standard_normal((n, n + 1)).astype(np.float32)
    a[np.arange(n), np.arange(n)] += n
    (out,) = jax.jit(model.gauss_graph)(jnp.asarray(a))
    out = np.asarray(out)
    lower = np.tril(out[:, :n], k=-1)
    assert np.abs(lower).max() < 1e-2


def test_sort_graph_sorted_and_permutation():
    rng = np.random.default_rng(4)
    x = rng.standard_normal(1000).astype(np.float32)
    s, idx = jax.jit(model.sort_graph)(jnp.asarray(x))
    s, idx = np.asarray(s), np.asarray(idx)
    assert (np.diff(s) >= 0).all()
    assert_allclose(np.sort(x), s)
    assert sorted(idx.tolist()) == list(range(1000))


def test_gnn_composition_masks_unreachable():
    n, d = 16, 8
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0  # only nodes 0,1 connected
    feats = np.ones((n, d), np.float32)
    w = np.eye(d, dtype=np.float32)
    onehot = np.zeros(n, np.float32)
    onehot[0] = 1.0
    out, level = jax.jit(model.gnn_graph)(
        jnp.asarray(adj), jnp.asarray(feats), jnp.asarray(w), jnp.asarray(onehot))
    out, level = np.asarray(out), np.asarray(level)
    assert level[0] == 0 and level[1] == 1
    assert (level[2:] >= 1e9).all()
    # Unreachable nodes contribute zero rows after masking.
    assert np.abs(out[2:]).max() == 0.0
    assert np.abs(out[:2]).max() > 0.0


def test_mri_composition():
    rng = np.random.default_rng(5)
    k = rng.standard_normal((32, 32)).astype(np.float32)
    w = np.zeros((3, 3), np.float32)
    w[1, 1] = 1.0
    img, s = jax.jit(model.mri_graph)(jnp.asarray(k), jnp.asarray(w))
    img, s = np.asarray(img), np.asarray(s)
    assert (np.diff(s) >= 0).all()
    med = np.sort(k.reshape(-1))[k.size // 2]
    assert_allclose(img, k - med, rtol=1e-5, atol=1e-5)


def test_cfd_conserves_mass_periodic():
    # Central-difference flux on a periodic domain conserves total mass.
    rng = np.random.default_rng(6)
    n = 512
    rho = (np.abs(rng.standard_normal(n)) + 1.0).astype(np.float32)
    mom = rng.standard_normal(n).astype(np.float32) * 0.1
    e = (np.abs(rng.standard_normal(n)) + 10.0).astype(np.float32)
    r, m, en = jax.jit(model.cfd_graph)(
        jnp.asarray(rho), jnp.asarray(mom), jnp.asarray(e))
    assert_allclose(np.asarray(r).sum(), rho.sum(), rtol=1e-3)
