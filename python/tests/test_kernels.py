"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal for the kernels that end up inside the AOT
artifacts the Rust runtime executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import conv3, gemm, rsum, saxpy, stencil, vadd
from compile.kernels import ref

# interpret-mode pallas is slow; keep sweeps tight but meaningful.
COMMON = dict(max_examples=20, deadline=None)

dims = st.integers(min_value=1, max_value=160)
small_dims = st.integers(min_value=3, max_value=96)
lengths = st.integers(min_value=1, max_value=5000)
dtypes = st.sampled_from([np.float32])  # bf16 via explicit tests below


def _rand(rng, shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


class TestGemm:
    @settings(**COMMON)
    @given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, (m, k)), _rand(rng, (k, n))
        got = np.asarray(gemm(x, y))
        want = np.asarray(ref.gemm(x, y))
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_tile_exact_multiple(self):
        rng = np.random.default_rng(0)
        x, y = _rand(rng, (256, 256)), _rand(rng, (256, 256))
        assert_allclose(np.asarray(gemm(x, y)), np.asarray(ref.gemm(x, y)),
                        rtol=1e-4, atol=1e-4)

    def test_ragged_all_axes(self):
        rng = np.random.default_rng(1)
        x, y = _rand(rng, (129, 131)), _rand(rng, (131, 133))
        assert_allclose(np.asarray(gemm(x, y)), np.asarray(ref.gemm(x, y)),
                        rtol=1e-4, atol=1e-4)

    def test_single_element(self):
        x = np.array([[3.0]], dtype=np.float32)
        y = np.array([[4.0]], dtype=np.float32)
        assert_allclose(np.asarray(gemm(x, y)), [[12.0]], rtol=1e-6)

    def test_custom_tiles(self):
        rng = np.random.default_rng(2)
        x, y = _rand(rng, (64, 64)), _rand(rng, (64, 64))
        got = np.asarray(gemm(x, y, tile_m=32, tile_n=16, tile_k=8))
        assert_allclose(got, np.asarray(ref.gemm(x, y)), rtol=1e-4, atol=1e-4)

    def test_zero_blocks_cleared(self):
        # Accumulator must be reset per (i, j) tile — run twice, second
        # output must not inherit first accumulation.
        rng = np.random.default_rng(3)
        x, y = _rand(rng, (128, 128)), _rand(rng, (128, 128))
        a = np.asarray(gemm(x, y))
        b = np.asarray(gemm(x, y))
        assert_allclose(a, b, rtol=0, atol=0)


class TestElementwise:
    @settings(**COMMON)
    @given(n=lengths, seed=st.integers(0, 2**31))
    def test_vadd_matches_ref(self, n, seed):
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, n), _rand(rng, n)
        assert_allclose(np.asarray(vadd(x, y)), np.asarray(ref.vadd(x, y)),
                        rtol=1e-6)

    @settings(**COMMON)
    @given(n=lengths, a=st.floats(-100, 100, width=32),
           seed=st.integers(0, 2**31))
    def test_saxpy_matches_ref(self, n, a, seed):
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, n), _rand(rng, n)
        av = np.array([[a]], dtype=np.float32)
        # ref broadcasts the (1,1) scalar against 1D x to (1, n); the
        # kernel keeps the 1D shape — compare flattened.
        assert_allclose(np.asarray(saxpy(av, x, y)).ravel(),
                        np.asarray(ref.saxpy(av, x, y)).ravel(),
                        rtol=1e-5, atol=1e-5)

    def test_vadd_non_lane_multiple(self):
        rng = np.random.default_rng(7)
        x, y = _rand(rng, 127), _rand(rng, 127)
        assert_allclose(np.asarray(vadd(x, y)), x + y, rtol=1e-6)

    def test_vadd_exact_block_boundary(self):
        n = 256 * 128  # exactly BLOCK_ROWS * LANES
        rng = np.random.default_rng(8)
        x, y = _rand(rng, n), _rand(rng, n)
        assert_allclose(np.asarray(vadd(x, y)), x + y, rtol=1e-6)

    def test_saxpy_zero_scale(self):
        rng = np.random.default_rng(9)
        x, y = _rand(rng, 1000), _rand(rng, 1000)
        a = np.zeros((1, 1), dtype=np.float32)
        assert_allclose(np.asarray(saxpy(a, x, y)), y, rtol=0, atol=0)


class TestRsum:
    @settings(**COMMON)
    @given(m=dims, n=st.integers(1, 1200), seed=st.integers(0, 2**31))
    def test_matches_ref(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (m, n))
        assert_allclose(np.asarray(rsum(x)), np.asarray(ref.rsum(x)),
                        rtol=1e-4, atol=1e-4)

    def test_ragged_reduce_axis_no_nan(self):
        # Regression: ragged N once pulled interpret-mode pad garbage into
        # the accumulator.
        x = np.ones((37, 513), dtype=np.float32)
        got = np.asarray(rsum(x))
        assert np.isfinite(got).all()
        assert_allclose(got, np.full((37, 1), 513.0), rtol=1e-6)

    def test_single_column(self):
        x = np.arange(5, dtype=np.float32).reshape(5, 1)
        assert_allclose(np.asarray(rsum(x)), x, rtol=0)


class TestConv3:
    @settings(**COMMON)
    @given(h=small_dims, w=small_dims, seed=st.integers(0, 2**31))
    def test_matches_ref(self, h, w, seed):
        rng = np.random.default_rng(seed)
        x, k = _rand(rng, (h, w)), _rand(rng, (3, 3))
        assert_allclose(np.asarray(conv3(x, k)), np.asarray(ref.conv3(x, k)),
                        rtol=1e-4, atol=1e-5)

    def test_identity_kernel(self):
        rng = np.random.default_rng(11)
        x = _rand(rng, (40, 40))
        k = np.zeros((3, 3), dtype=np.float32)
        k[1, 1] = 1.0
        assert_allclose(np.asarray(conv3(x, k)), x, rtol=1e-6, atol=1e-6)

    def test_multi_strip(self):
        rng = np.random.default_rng(12)
        x, k = _rand(rng, (300, 64)), _rand(rng, (3, 3))
        assert_allclose(np.asarray(conv3(x, k)), np.asarray(ref.conv3(x, k)),
                        rtol=1e-4, atol=1e-5)


class TestStencil:
    @settings(**COMMON)
    @given(h=small_dims, w=small_dims, seed=st.integers(0, 2**31))
    def test_matches_ref(self, h, w, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (h, w))
        assert_allclose(np.asarray(stencil(x)), np.asarray(ref.stencil(x)),
                        rtol=1e-5, atol=1e-6)

    def test_constant_field_fixed_point(self):
        x = np.full((50, 50), 3.25, dtype=np.float32)
        assert_allclose(np.asarray(stencil(x)), x, rtol=0, atol=0)

    def test_boundary_copied(self):
        rng = np.random.default_rng(13)
        x = _rand(rng, (64, 64))
        out = np.asarray(stencil(x))
        assert_allclose(out[0, :], x[0, :], rtol=0)
        assert_allclose(out[-1, :], x[-1, :], rtol=0)
        assert_allclose(out[:, 0], x[:, 0], rtol=0)
        assert_allclose(out[:, -1], x[:, -1], rtol=0)
