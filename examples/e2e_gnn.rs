//! End-to-end driver: proves all three layers compose.
//!
//! 1. Loads the AOT artifacts (L1 Pallas kernels inside L2 JAX graphs,
//!    lowered by `make artifacts`) and *executes the real gnn composite*
//!    through PJRT from Rust — real numbers, checked finite/stable.
//! 2. Runs the same workload's access stream through the L3 full-system
//!    simulator across the paper's configurations.
//! 3. Reports the paper's headline metric: execution time vs GPU-DRAM,
//!    and the CXL-over-UVM speedup.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_gnn
//! ```
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::runner::run_with;
use cxl_gpu::media::MediaKind;
use cxl_gpu::runtime::Runtime;
use cxl_gpu::util::bench::Table;
use cxl_gpu::workloads::table1b::spec;

fn main() {
    // --- Layer 1+2: real compute through PJRT -------------------------
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts from `{dir}` ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let mut checksums = Vec::new();
    for wl in ["gnn", "bfs", "vadd", "gemm"] {
        let out = rt.execute_named(wl, 42).expect("execute");
        println!(
            "  executed {wl:6} via PJRT: {} outputs, {} elements, checksum {:+.6}",
            out.outputs, out.elements, out.checksum
        );
        checksums.push((wl, out.checksum));
    }
    // Determinism: same seed, same numbers.
    let again = rt.execute_named("gnn", 42).expect("re-execute");
    assert_eq!(again.checksum, checksums[0].1, "PJRT execution must be deterministic");

    // --- Layer 3: the memory-system study on the same workload --------
    println!("\nSimulating gnn across memory configurations (Z-NAND expander):");
    let mut t = Table::new(
        "gnn end-to-end",
        &["config", "exec (ms)", "vs ideal", "faults", "sr issued", "ds intercepts"],
    );
    let mut ideal = None;
    let mut uvm_time = 0u64;
    let mut cxl_time = 0u64;
    for name in ["gpu-dram", "uvm", "cxl", "cxl-sr", "cxl-ds"] {
        let media =
            if name == "gpu-dram" || name == "uvm" { MediaKind::Ddr5 } else { MediaKind::Znand };
        let mut cfg = SystemConfig::named(name, media);
        cfg.ssd_scale();
        let r = run_with(spec("gnn"), &cfg);
        let exec = r.metrics.exec_time;
        let base = *ideal.get_or_insert(exec);
        if name == "uvm" {
            uvm_time = exec;
        }
        if name == "cxl" {
            cxl_time = exec;
        }
        t.rowv(vec![
            name.into(),
            format!("{:.3}", r.metrics.exec_ms()),
            format!("{:.1}x", exec as f64 / base as f64),
            r.metrics.faults.to_string(),
            r.metrics.sr_issued.to_string(),
            r.metrics.ds_intercepts.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nheadline metric — CXL over UVM on gnn: {:.1}x (paper's aggregate claim: 2.36x, DRAM-EP figure: 44.2x)",
        uvm_time as f64 / cxl_time as f64
    );
    assert!(uvm_time > cxl_time, "CXL must beat UVM");
    println!("e2e OK: real PJRT compute + full-system simulation compose.");
}
