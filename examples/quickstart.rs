//! Quickstart: simulate one workload on the CXL-expanded GPU and print a
//! human-readable report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::runner::run_with;
use cxl_gpu::media::MediaKind;
use cxl_gpu::obs::Stage;
use cxl_gpu::util::bench::Table;
use cxl_gpu::workloads::table1b::spec;

fn main() {
    println!("CXL-GPU quickstart: vadd across the paper's five configurations\n");
    let mut t = Table::new(
        "vadd (Z-NAND expander where applicable)",
        &["config", "exec (ms)", "vs ideal", "llc hit", "ep-DRAM hit", "notes"],
    );
    let mut ideal_time = None;
    for name in ["gpu-dram", "uvm", "gds", "cxl", "cxl-sr", "cxl-ds"] {
        let media = if name == "gpu-dram" || name == "uvm" {
            MediaKind::Ddr5
        } else {
            MediaKind::Znand
        };
        let mut cfg = SystemConfig::named(name, media);
        cfg.ssd_scale(); // one shared scale so rows are comparable
        let r = run_with(spec("vadd"), &cfg);
        let exec = r.metrics.exec_time as f64;
        let ideal = *ideal_time.get_or_insert(exec);
        let notes = match name {
            "gpu-dram" => "ideal: all data on-device",
            "uvm" => "page faults via host runtime",
            "gds" => "faults + SSD reads",
            "cxl" => "direct CXL.mem access",
            "cxl-sr" => "+ speculative read",
            "cxl-ds" => "+ deterministic store",
            _ => "",
        };
        t.rowv(vec![
            name.into(),
            format!("{:.3}", r.metrics.exec_ms()),
            format!("{:.1}x", exec / ideal),
            format!("{:.0}%", r.metrics.llc.hit_rate() * 100.0),
            format!("{:.0}%", r.metrics.ep_hit_rate() * 100.0),
            notes.into(),
        ]);
    }
    t.print();

    // Where the nanoseconds go: re-run the plain expander with the §18
    // span tracer armed (tracing adds no latency and draws no RNG, so
    // the run itself is bit-identical) and print the per-stage ledger.
    let mut cfg = SystemConfig::named("cxl", MediaKind::Znand);
    cfg.ssd_scale();
    cfg.obs.enabled = true;
    cfg.obs.sample_shift = 0;
    let m = run_with(spec("vadd"), &cfg).metrics;
    let mut b = Table::new(
        "cxl on vadd — mean ns per sampled span, by path stage (sums to e2e)",
        &["stage", "ns/span", "share"],
    );
    for &s in Stage::ALL.iter() {
        let ns = m.obs_stage_per_span_ns(s);
        if ns == 0.0 {
            continue;
        }
        b.rowv(vec![
            s.name().into(),
            format!("{ns:.1}"),
            format!("{:.1}%", m.obs_stage_share(s) * 100.0),
        ]);
    }
    b.print();
    println!(
        "{} spans traced, {} conservation violations",
        m.obs_spans(),
        m.obs_violations()
    );
    println!("\nSee `cxl-gpu experiments` for the full figure reproductions.");
}
