//! Serve the simulated root complex over a socket: a tiny memory-request
//! service in the style of a disaggregated-memory daemon. Requests are
//! `R <hex-addr>` / `W <hex-addr>` lines; responses carry the simulated
//! completion latency in nanoseconds.
//!
//! ```sh
//! cargo run --release --example serve_expander &   # listens on 127.0.0.1:7999
//! printf 'R 1000\nW 2000\nR 1000\nQ\n' | nc 127.0.0.1 7999
//! ```
//!
//! (std::net + threads; the offline build has no tokio.)
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::{Arc, Mutex};

use cxl_gpu::cxl::ControllerKind;
use cxl_gpu::media::{SsdModel, SsdParams};
use cxl_gpu::rootcomplex::{EpBackend, RootComplex, RootPort, SrPolicy};
use cxl_gpu::sim::{ps_to_ns, Time};
use cxl_gpu::util::prng::Pcg32;

fn main() {
    let ports = (0..2)
        .map(|i| {
            RootPort::new(
                i,
                ControllerKind::Panmnesia,
                EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
                SrPolicy::Window,
                true,
                1 << 20,
            )
        })
        .collect();
    let mut rc = RootComplex::new(ports);
    rc.enumerate(64 << 20).expect("HDM enumerate");
    let shared = Arc::new(Mutex::new((rc, Pcg32::new(7, 7), 0u64 as Time)));

    let listener = TcpListener::bind("127.0.0.1:7999").expect("bind 127.0.0.1:7999");
    println!("serve_expander: listening on 127.0.0.1:7999 (R <hex> | W <hex> | Q)");
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            let mut out = stream.try_clone().expect("clone");
            let reader = BufReader::new(stream);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                let mut parts = line.split_whitespace();
                let (op, addr) = (parts.next(), parts.next());
                let reply = match (op, addr.and_then(|a| u64::from_str_radix(a, 16).ok())) {
                    (Some("R"), Some(addr)) => {
                        let mut g = shared.lock().unwrap();
                        let (rc, _, now) = &mut *g;
                        let t = *now;
                        let outp = rc.load(t, addr % (64 << 20), 64);
                        *now = t + 1000; // 1 ns between arrivals
                        format!("OK R {:.1}ns path={:?}\n", ps_to_ns(outp.done - t), outp.path)
                    }
                    (Some("W"), Some(addr)) => {
                        let mut g = shared.lock().unwrap();
                        let (rc, rng, now) = &mut *g;
                        let t = *now;
                        let outp = rc.store(t, addr % (64 << 20), 64, rng);
                        *now = t + 1000;
                        format!(
                            "OK W {:.1}ns buffered={}\n",
                            ps_to_ns(outp.ack - t),
                            outp.buffered
                        )
                    }
                    (Some("Q"), _) => break,
                    _ => "ERR usage: R <hex-addr> | W <hex-addr> | Q\n".into(),
                };
                if out.write_all(reply.as_bytes()).is_err() {
                    break;
                }
            }
        });
    }
}
