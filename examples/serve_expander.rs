//! Serve the simulated root complex over a socket: a tiny memory-request
//! service in the style of a disaggregated-memory daemon. Requests are
//! `R <hex-addr>` / `W <hex-addr>` lines; responses carry the simulated
//! completion latency in nanoseconds.
//!
//! ```sh
//! cargo run --release --example serve_expander &   # listens on 127.0.0.1:7999
//! printf 'R 1000\nW 2000\nR 1000\nQ\n' | nc 127.0.0.1 7999
//! ```
//!
//! (std::net + threads; the offline build has no tokio.)
//!
//! The daemon reuses the serving front door's vocabulary (DESIGN.md §16):
//! shared [`ServeStats`] count every request, and each completion is
//! checked against a [`ServeSpec`] SLO so the per-connection summary
//! reports goodput the same way the simulator does. Every fallible edge —
//! enumerate, bind, clone, even a peer thread that panicked while holding
//! the lock — degrades to a message or a dropped connection, never to a
//! daemon crash.
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::{Arc, Mutex, MutexGuard};

use cxl_gpu::cxl::ControllerKind;
use cxl_gpu::media::{SsdModel, SsdParams};
use cxl_gpu::rootcomplex::{EpBackend, RootComplex, RootPort, SrPolicy};
use cxl_gpu::serve::{ServeSpec, ServeStats};
use cxl_gpu::sim::{ps_to_ns, Time};
use cxl_gpu::util::prng::Pcg32;

/// Lock that survives a poisoned mutex: a handler thread that panicked
/// mid-request leaves the root complex in a consistent state (every
/// `load`/`store` either completed or never started), so serving must
/// continue rather than propagate the poison to every future connection.
fn lock_shared<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn main() {
    let ports = (0..2)
        .map(|i| {
            RootPort::new(
                i,
                ControllerKind::Panmnesia,
                EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
                SrPolicy::Window,
                true,
                1 << 20,
            )
        })
        .collect();
    let mut rc = RootComplex::new(ports);
    if let Err(e) = rc.enumerate(64 << 20) {
        eprintln!("serve_expander: HDM enumerate failed: {e}");
        std::process::exit(1);
    }
    let shared = Arc::new(Mutex::new((rc, Pcg32::new(7, 7), 0u64 as Time)));
    let stats = Arc::new(Mutex::new(ServeStats::default()));
    // The front door's per-request SLO, reused as this daemon's goodput
    // threshold for the connection summaries.
    let slo = ServeSpec::default().slo;

    let listener = match TcpListener::bind("127.0.0.1:7999") {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve_expander: cannot bind 127.0.0.1:7999: {e}");
            std::process::exit(1);
        }
    };
    println!("serve_expander: listening on 127.0.0.1:7999 (R <hex> | W <hex> | Q)");
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(&shared);
        let stats = Arc::clone(&stats);
        std::thread::spawn(move || {
            let mut out = match stream.try_clone() {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("serve_expander: dropping connection (clone failed: {e})");
                    return;
                }
            };
            let reader = BufReader::new(stream);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                let mut parts = line.split_whitespace();
                let (op, addr) = (parts.next(), parts.next());
                let reply = match (op, addr.and_then(|a| u64::from_str_radix(a, 16).ok())) {
                    (Some("R"), Some(addr)) => {
                        let mut g = lock_shared(&shared);
                        let (rc, _, now) = &mut *g;
                        let t = *now;
                        let outp = rc.load(t, addr % (64 << 20), 64);
                        *now = t + 1000; // 1 ns between arrivals
                        drop(g);
                        bookkeep(&stats, outp.done - t, slo);
                        format!("OK R {:.1}ns path={:?}\n", ps_to_ns(outp.done - t), outp.path)
                    }
                    (Some("W"), Some(addr)) => {
                        let mut g = lock_shared(&shared);
                        let (rc, rng, now) = &mut *g;
                        let t = *now;
                        let outp = rc.store(t, addr % (64 << 20), 64, rng);
                        *now = t + 1000;
                        drop(g);
                        bookkeep(&stats, outp.ack - t, slo);
                        format!(
                            "OK W {:.1}ns buffered={}\n",
                            ps_to_ns(outp.ack - t),
                            outp.buffered
                        )
                    }
                    (Some("Q"), _) => break,
                    _ => "ERR usage: R <hex-addr> | W <hex-addr> | Q\n".into(),
                };
                if out.write_all(reply.as_bytes()).is_err() {
                    break;
                }
            }
            let s = lock_shared(&stats);
            println!(
                "serve_expander: connection closed ({} served, {} within the {} ns SLO)",
                s.completed,
                s.completed_in_slo,
                slo / 1000
            );
        });
    }
}

/// Charge one served request to the shared front-door counters.
fn bookkeep(stats: &Mutex<ServeStats>, latency: Time, slo: Time) {
    let mut s = lock_shared(stats);
    s.arrivals += 1;
    s.admitted += 1;
    s.completed += 1;
    if latency <= slo {
        s.completed_in_slo += 1;
    }
}
