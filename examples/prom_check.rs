//! Validate a `--telemetry-out` Prometheus exposition file
//! (docs/TELEMETRY.md): every line must be a `# HELP`/`# TYPE` comment
//! or a `name{labels} value` sample with a finite value, every sample's
//! family must have been declared by a preceding `# TYPE`, and label
//! values must be properly quoted. Exits nonzero with a message on any
//! violation; prints a one-line census on success.
//!
//!     cargo run --release --example prom_check -- telemetry.jsonl.prom

use std::collections::BTreeSet;

/// Split a sample line into (family, labels, value), panicking with a
/// location on any shape violation.
fn split_sample<'a>(path: &str, i: usize, line: &'a str) -> (&'a str, &'a str, &'a str) {
    let (name_labels, value) = line
        .rsplit_once(' ')
        .unwrap_or_else(|| panic!("{path}:{}: sample has no value: {line}", i + 1));
    match name_labels.split_once('{') {
        Some((name, rest)) => {
            let labels = rest
                .strip_suffix('}')
                .unwrap_or_else(|| panic!("{path}:{}: unterminated label set", i + 1));
            (name, labels, value)
        }
        None => (name_labels, "", value),
    }
}

fn main() {
    let path = std::env::args().nth(1).unwrap_or_else(|| "telemetry.jsonl.prom".into());
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    let mut typed: BTreeSet<String> = BTreeSet::new();
    let (mut comments, mut samples) = (0usize, 0usize);
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        if let Some(c) = line.strip_prefix("# ") {
            let mut parts = c.splitn(3, ' ');
            let keyword = parts.next().unwrap_or("");
            let family = parts.next().unwrap_or_else(|| {
                panic!("{path}:{}: comment names no metric family", i + 1)
            });
            match keyword {
                "HELP" => {}
                "TYPE" => {
                    typed.insert(family.to_string());
                }
                other => panic!("{path}:{}: unexpected comment keyword `{other}`", i + 1),
            }
            comments += 1;
            continue;
        }
        let (name, labels, value) = split_sample(&path, i, line);
        assert!(
            typed.contains(name),
            "{path}:{}: sample `{name}` precedes its # TYPE declaration",
            i + 1
        );
        assert!(
            name.starts_with("cxlgpu_"),
            "{path}:{}: family `{name}` misses the cxlgpu_ namespace",
            i + 1
        );
        for pair in labels.split(',').filter(|p| !p.is_empty()) {
            let (_, v) = pair
                .split_once('=')
                .unwrap_or_else(|| panic!("{path}:{}: malformed label `{pair}`", i + 1));
            assert!(
                v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                "{path}:{}: unquoted label value `{v}`",
                i + 1
            );
        }
        let v: f64 = value
            .parse()
            .unwrap_or_else(|e| panic!("{path}:{}: bad sample value `{value}`: {e}", i + 1));
        assert!(v.is_finite(), "{path}:{}: non-finite sample value", i + 1);
        samples += 1;
    }
    assert!(samples > 0, "{path}: no samples");
    assert!(!typed.is_empty(), "{path}: no # TYPE declarations");
    println!(
        "{path}: OK ({samples} samples across {} families, {comments} comment lines)",
        typed.len()
    );
}
