//! Validate a `--trace-out` file (docs/TRACING.md): parse it through the
//! in-tree JSON parser and check the trace-event shape CI relies on —
//! a `traceEvents` array with process-name metadata, complete (`X`)
//! span events, and nonnegative ts/dur on every event. Exits nonzero
//! with a message on any violation; prints a one-line census on success.
//!
//!     cargo run --release --example trace_check -- run.json

use cxl_gpu::util::json::{parse, Json};

fn main() {
    let path = std::env::args().nth(1).unwrap_or_else(|| "run.json".into());
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{path}: {e}"));
    let doc = parse(&text).unwrap_or_else(|e| panic!("{path}: invalid JSON: {e}"));
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("{path}: no traceEvents array"));
    assert!(!events.is_empty(), "{path}: empty traceEvents");
    let (mut meta, mut spans) = (0usize, 0usize);
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("{path}: event {i} has no ph"));
        match ph {
            "M" => meta += 1,
            "X" => {
                spans += 1;
                let ts = ev.get("ts").and_then(Json::as_f64);
                let dur = ev.get("dur").and_then(Json::as_f64);
                match (ts, dur) {
                    (Some(ts), Some(dur)) if ts >= 0.0 && dur >= 0.0 => {}
                    _ => panic!("{path}: event {i} has bad ts/dur"),
                }
                assert!(ev.get("pid").is_some(), "{path}: event {i} has no pid");
                assert!(ev.get("name").is_some(), "{path}: event {i} has no name");
            }
            other => panic!("{path}: event {i} has unexpected ph `{other}`"),
        }
    }
    assert!(meta > 0, "{path}: no process_name metadata events");
    assert!(spans > 0, "{path}: no span events");
    println!("{path}: OK ({} events: {meta} metadata, {spans} spans)", events.len());
}
