//! Design-space explorer: sweep EP media x mechanisms x a workload trio
//! and report normalized execution time — the kind of study Fig. 9c
//! distills.
//!
//! ```sh
//! cargo run --release --example media_explorer [workload ...]
//! ```
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::runner::run_with;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::workloads::table1b::spec;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workloads: Vec<&str> = if args.is_empty() {
        vec!["vadd", "sort", "bfs"]
    } else {
        args.iter().map(String::as_str).collect()
    };
    let medias =
        [MediaKind::Ddr5, MediaKind::Optane, MediaKind::Znand, MediaKind::Nand];
    for wl in workloads {
        let mut base_cfg = SystemConfig::named("gpu-dram", MediaKind::Ddr5);
        base_cfg.ssd_scale();
        let base = run_with(spec(wl), &base_cfg);
        let mut t = Table::new(
            &format!("{wl}: exec time normalized to GPU-DRAM"),
            &["media", "CXL", "CXL-SR", "CXL-DS", "best mechanism"],
        );
        for media in medias {
            let mut row = Vec::new();
            let mut best = ("CXL", f64::INFINITY);
            for cfg_name in ["cxl", "cxl-sr", "cxl-ds"] {
                let mut cfg = SystemConfig::named(cfg_name, media);
                cfg.ssd_scale();
                let r = run_with(spec(wl), &cfg);
                let n = r.normalized_to(&base);
                if n < best.1 {
                    best = (cfg_name, n);
                }
                row.push(format!("{n:.1}x"));
            }
            t.rowv(vec![
                media.name().into(),
                row[0].clone(),
                row[1].clone(),
                row[2].clone(),
                format!("{} ({:.1}x)", best.0, best.1),
            ]);
        }
        t.print();
    }
}
