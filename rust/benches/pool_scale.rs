//! §17 — sharded conservative-lookahead pool coordinator: wall-clock
//! scaling with bit-identity to the serial merge.
//!
//! Runs the `pool-scale` experiment (8/16/64-tenant pools, each at
//! 1/2/4/8 shards), emits `BENCH_pool_scale.json` (schema:
//! docs/BENCH_SCHEMA.md), and asserts the tentpole's win condition:
//! every cell's tenant fingerprints + pool sums equal the serial
//! `run_pool` bit-for-bit, and the 64-tenant pool at 4 shards runs
//! ≥ 2.5x faster than the serial coordinator.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{pool_scale, Scale};
use cxl_gpu::util::json::Json;

/// 64-tenant × 4-shard wall-clock speedup floor over serial.
const FLOOR_SPEEDUP_64X4: f64 = 2.5;

fn main() {
    let res = pool_scale(Scale::default(), true);

    let rows: Vec<Json> = res
        .rows
        .iter()
        .map(|r| {
            let cells: Vec<Json> = r
                .cells
                .iter()
                .map(|c| {
                    let mut m = BTreeMap::new();
                    m.insert("shards".into(), Json::Num(c.shards as f64));
                    m.insert("wall_ms".into(), Json::Num(c.wall_ms));
                    m.insert("speedup".into(), Json::Num(c.speedup));
                    m.insert("identical".into(), Json::Bool(c.identical));
                    Json::Obj(m)
                })
                .collect();
            let mut m = BTreeMap::new();
            m.insert("tenants".into(), Json::Num(r.tenants as f64));
            m.insert("serial_wall_ms".into(), Json::Num(r.serial_wall_ms));
            m.insert("events".into(), Json::Num(r.events as f64));
            m.insert("pool_loads".into(), Json::Num(r.pool_loads as f64));
            m.insert("cells".into(), Json::Arr(cells));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("pool_scale".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_speedup_64x4".into(), Json::Num(FLOOR_SPEEDUP_64X4));
    top.insert("all_identical".into(), Json::Bool(res.all_identical));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_pool_scale.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        res.all_identical,
        "sharded pool runs must match the serial coordinator bit-for-bit \
         (and exercise the fabric): identity is the whole contract"
    );
    let speedup = res.speedup_at(64, 4);
    assert!(
        speedup >= FLOOR_SPEEDUP_64X4,
        "64-tenant x 4-shard pool below the {FLOOR_SPEEDUP_64X4}x wall-clock floor: {speedup:.2}x"
    );
    println!("pool_scale bench OK (64x4 speedup {speedup:.2}x, all cells bit-identical)");
}
