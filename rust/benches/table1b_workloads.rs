//! E2 — Table 1b: regenerate the workload instruction mixes and check
//! them against the paper's columns; bench trace generation throughput.
use cxl_gpu::coordinator::experiments;
use cxl_gpu::util::bench::Bench;
use cxl_gpu::workloads::table1b::spec;
use cxl_gpu::workloads::{generate, TraceParams};

fn main() {
    let rows = experiments::table1b(true);
    assert_eq!(rows.len(), 13);
    for (name, compute, load) in &rows {
        let s = spec(name);
        assert!((compute - s.compute_ratio).abs() < 0.03, "{name}: compute ratio drift");
        assert!((load - s.load_ratio).abs() < 0.04, "{name}: load ratio drift");
    }
    let p = TraceParams { total_ops: 120_000, ..Default::default() };
    Bench::new("workloads/generate(vadd,120k)").iters(1, 5, 3).run(|| {
        std::hint::black_box(generate(spec("vadd"), &p));
    });
    println!("table1b bench OK");
}
