//! E2 — Table 1b: regenerate the workload instruction mixes and check
//! them against the paper's columns; bench trace generation throughput,
//! streamed vs materialized.
use cxl_gpu::coordinator::experiments;
use cxl_gpu::util::bench::Bench;
use cxl_gpu::workloads::table1b::spec;
use cxl_gpu::workloads::{collect_trace, OpStream, TraceParams};

fn main() {
    let rows = experiments::table1b(true);
    assert_eq!(rows.len(), 13);
    for (name, compute, load) in &rows {
        let s = spec(name);
        assert!((compute - s.compute_ratio).abs() < 0.03, "{name}: compute ratio drift");
        assert!((load - s.load_ratio).abs() < 0.04, "{name}: load ratio drift");
    }
    // Streamed generation at the 10x budget vs the old eager path at the
    // old budget: the stream never allocates per-op, so it also serves as
    // the allocation-free reference number.
    let p10 = TraceParams { total_ops: 1_200_000, ..Default::default() };
    Bench::new("workloads/stream(vadd,1.2M)").iters(1, 5, 3).run(|| {
        for w in 0..p10.warps {
            for op in OpStream::new(spec("vadd"), &p10, w) {
                std::hint::black_box(op);
            }
        }
    });
    let p = TraceParams { total_ops: 120_000, ..Default::default() };
    Bench::new("workloads/collect_trace(vadd,120k)").iters(1, 5, 3).run(|| {
        std::hint::black_box(collect_trace(spec("vadd"), &p));
    });
    println!("table1b bench OK");
}
