//! §Perf — scenario scaling under streamed traces: events per
//! wall-second and resident trace memory vs `total_ops`.
//!
//! Before op streaming, `workloads::generate` materialized every dynamic
//! instruction up front, so a scenario's memory grew linearly with its
//! op budget (x sweep threads). With lazy `OpStream`s the per-scenario
//! trace state is O(warps); this bench sweeps the op budget over 1.5
//! decades (0.3M..10M), records throughput plus both memory models, and
//! asserts that peak RSS no longer scales with `total_ops`.
//!
//! Emits `BENCH_trace_stream.json` alongside `BENCH_sim_throughput.json`
//! (schema: docs/BENCH_SCHEMA.md).
use std::collections::BTreeMap;

use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::gpu::Op;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::util::json::Json;
use cxl_gpu::workloads::table1b::spec;
use cxl_gpu::workloads::{OpStream, TraceParams};

/// Same per-event floor as `sim_throughput` — scaling the scenario up
/// must not cost per-event throughput.
const FLOOR_EVENTS_PER_SEC: f64 = 2.0e6;

/// Peak-RSS growth allowed across the whole sweep. The 10M-op run would
/// have materialized ≥160 MB of trace (10M x 16 B ops) under the old
/// generator; streamed, the growth is a few MB of allocator noise.
const MAX_RSS_GROWTH_KB: u64 = 40 * 1024;

/// `VmHWM` (peak resident set) in kB from /proc/self/status; None off
/// Linux or in sandboxes that hide procfs.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn main() {
    let budgets: [usize; 4] = [300_000, 1_000_000, 3_000_000, 10_000_000];
    let wl = spec("vadd");

    // Warm up allocator + code paths at the smallest budget so the HWM
    // baseline includes every fixed cost (LLC arrays, queue ring, maps).
    let mut warm = SystemConfig::named("cxl", MediaKind::Ddr5);
    warm.total_ops = budgets[0];
    System::new(wl, &warm).run();
    let rss_base_kb = peak_rss_kb();

    let mut t = Table::new(
        "scenario scaling — streamed traces (cxl/vadd/ddr5)",
        &["total_ops", "events", "M events/s", "stream state", "materialized would-be", "peak RSS"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut worst = f64::INFINITY;
    let mut last_rss_kb = rss_base_kb;
    for &ops in &budgets {
        let mut cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
        cfg.total_ops = ops;
        let p = TraceParams {
            footprint: cfg.footprint,
            warps: cfg.warps,
            total_ops: cfg.total_ops,
            seed: cfg.seed,
            ..Default::default()
        };
        // O(warps) side of the memory model: the full resident trace
        // state of a streamed scenario...
        let stream_bytes: usize =
            (0..cfg.warps).map(|w| OpStream::new(wl, &p, w).state_bytes()).sum();
        // ...vs what the old eager generator would have kept resident.
        let materialized_bytes = ops * std::mem::size_of::<Op>()
            + cfg.warps * std::mem::size_of::<Vec<Op>>();

        let m = System::new(wl, &cfg).run();
        let eps = m.events_per_sec();
        worst = worst.min(eps);
        last_rss_kb = peak_rss_kb();

        t.rowv(vec![
            format!("{}k", ops / 1000),
            m.events.to_string(),
            format!("{:.2}", eps / 1e6),
            format!("{:.1} KiB", stream_bytes as f64 / 1024.0),
            format!("{:.1} MiB", materialized_bytes as f64 / (1 << 20) as f64),
            match last_rss_kb {
                Some(kb) => format!("{:.1} MiB", kb as f64 / 1024.0),
                None => "n/a".into(),
            },
        ]);
        let mut row = BTreeMap::new();
        row.insert("total_ops".into(), Json::Num(ops as f64));
        row.insert("events".into(), Json::Num(m.events as f64));
        row.insert("wall_ns".into(), Json::Num(m.wall_ns as f64));
        row.insert("events_per_sec".into(), Json::Num(eps));
        row.insert("stream_state_bytes".into(), Json::Num(stream_bytes as f64));
        row.insert("materialized_bytes".into(), Json::Num(materialized_bytes as f64));
        if let Some(kb) = last_rss_kb {
            row.insert("peak_rss_kb".into(), Json::Num(kb as f64));
        }
        rows.push(Json::Obj(row));
    }
    t.print();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("trace_stream".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_events_per_sec".into(), Json::Num(FLOOR_EVENTS_PER_SEC));
    top.insert("worst_events_per_sec".into(), Json::Num(worst));
    if let Some(kb) = rss_base_kb {
        top.insert("baseline_peak_rss_kb".into(), Json::Num(kb as f64));
    }
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_trace_stream.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        worst > FLOOR_EVENTS_PER_SEC,
        "scenario scaling dropped below {:.0}M events/s: {worst}",
        FLOOR_EVENTS_PER_SEC / 1e6
    );
    if let (Some(base), Some(end)) = (rss_base_kb, last_rss_kb) {
        let growth = end.saturating_sub(base);
        assert!(
            growth < MAX_RSS_GROWTH_KB,
            "peak RSS grew {growth} kB across a 33x op-budget sweep — trace memory is \
             scaling with total_ops again"
        );
        println!(
            "trace_stream bench OK (worst {:.1} M events/s, RSS growth {growth} kB over 0.3M→10M ops)",
            worst / 1e6
        );
    } else {
        println!(
            "trace_stream bench OK (worst {:.1} M events/s; RSS probe unavailable)",
            worst / 1e6
        );
    }
}
