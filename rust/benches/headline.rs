//! E8 — the abstract's headline: our CXL approach outperforms UVM
//! (paper: 2.36x aggregate) and a commercial PCIe-era EP controller
//! (paper: 1.36x).
use cxl_gpu::coordinator::experiments::{self, Scale};

fn main() {
    let r = experiments::headline(Scale::default(), true);
    assert!(r.cxl_over_uvm > 2.0, "CXL over UVM: {}", r.cxl_over_uvm);
    assert!(r.cxl_over_smt > 1.05, "CXL over commercial EP: {}", r.cxl_over_smt);
    println!("headline bench OK");
}
