//! Design-choice ablations beyond the paper's figures (DESIGN.md §10):
//!  A1 root-port count sweep       — how much does port fan-out matter?
//!  A2 controller latency sweep    — ours vs PCIe-era controllers end-to-end
//!  A3 heterogeneous expanders     — Fig. 1a's "DRAMs and/or SSDs" mixed
//!                                   topology vs pure configurations.
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::runner::run_with;
use cxl_gpu::cxl::ControllerKind;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::workloads::table1b::spec;

fn main() {
    // A1: port count (vadd, DRAM EPs).
    let mut t = Table::new("A1 — root-port fan-out (vadd, DRAM EPs)", &["ports", "exec (ms)"]);
    let mut prev = f64::INFINITY;
    let mut one_port = 0.0;
    for ports in [1usize, 2, 4, 8] {
        let mut cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
        cfg.ports = ports;
        let r = run_with(spec("vadd"), &cfg);
        let ms = r.metrics.exec_ms();
        if ports == 1 {
            one_port = ms;
        }
        t.rowv(vec![ports.to_string(), format!("{ms:.3}")]);
        assert!(ms <= prev * 1.10, "more ports should not slow things down much");
        prev = ms;
    }
    t.print();
    assert!(prev < one_port, "8 ports must beat 1 port");

    // A2: controller silicon end-to-end (the Fig. 3b latency gap as seen
    // by a whole workload, not a microbenchmark).
    let mut t = Table::new(
        "A2 — controller silicon, end-to-end (vadd, DRAM EPs)",
        &["controller", "exec (ms)", "vs ours"],
    );
    let mut ours_ms = 0.0;
    for (name, kind) in [
        ("panmnesia", ControllerKind::Panmnesia),
        ("smt", ControllerKind::Smt),
        ("tpp", ControllerKind::Tpp),
    ] {
        let mut cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
        cfg.controller = kind;
        let r = run_with(spec("vadd"), &cfg);
        let ms = r.metrics.exec_ms();
        if kind == ControllerKind::Panmnesia {
            ours_ms = ms;
        }
        t.rowv(vec![name.into(), format!("{ms:.3}"), format!("{:.2}x", ms / ours_ms)]);
    }
    t.print();

    // A3: heterogeneous DRAM+SSD ports vs pure configurations.
    let mut t = Table::new(
        "A3 — heterogeneous expanders (Z-NAND class, SR+DS on)",
        &["workload", "pure DRAM", "pure SSD (cxl-ds)", "hybrid"],
    );
    for wl in ["vadd", "bfs", "gnn"] {
        let mut row = vec![wl.to_string()];
        let mut vals = Vec::new();
        for name in ["cxl", "cxl-ds", "cxl-hybrid"] {
            let media = if name == "cxl" { MediaKind::Ddr5 } else { MediaKind::Znand };
            let mut cfg = SystemConfig::named(name, media);
            cfg.ssd_scale();
            let r = run_with(spec(wl), &cfg);
            vals.push(r.metrics.exec_ms());
            row.push(format!("{:.3}", r.metrics.exec_ms()));
        }
        t.rowv(row);
        // The hybrid must land between pure-DRAM and pure-SSD.
        assert!(
            vals[2] <= vals[1] * 1.05,
            "{wl}: hybrid should not lose to pure SSD ({} vs {})",
            vals[2],
            vals[1]
        );
    }
    t.print();
    println!("ablations bench OK");
}
