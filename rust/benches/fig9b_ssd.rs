//! E4 — Fig. 9b: Z-NAND expander — GDS / CXL / CXL-SR / CXL-DS over the
//! suite, normalized to GPU-DRAM (log scale in the paper).
use cxl_gpu::coordinator::experiments::{self, Scale};
use cxl_gpu::workloads::table1b::spec;
use cxl_gpu::workloads::Category;

fn main() {
    let r = experiments::fig9b(Scale::default(), true);
    // SR must help overall (paper: 7.4x).
    assert!(r.sr_over_cxl > 1.3, "SR gain too small: {}", r.sr_over_cxl);
    // DS must add on top of SR for store-intensive workloads (paper: +62.8%).
    assert!(r.ds_over_sr_store > 0.2, "DS store gain: {}", r.ds_over_sr_store);
    // Per-workload: SR strictly helps the 1D sequential workloads.
    for (i, c) in r.cxl.iter().enumerate() {
        if matches!(c.workload, "vadd" | "saxpy" | "rsum") {
            assert!(
                r.sr[i].metrics.exec_time < c.metrics.exec_time,
                "{}: SR should win on sequential workloads",
                c.workload
            );
        }
        if spec(c.workload).category == Category::StoreIntensive {
            assert!(
                r.ds[i].metrics.exec_time <= r.sr[i].metrics.exec_time,
                "{}: DS must not lose to SR on store-intensive",
                c.workload
            );
        }
    }
    println!("fig9b bench OK");
}
