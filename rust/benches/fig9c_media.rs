//! E5 — Fig. 9c: backend-media sweep (Optane / Z-NAND / NAND) for vadd,
//! path and bfs.
use cxl_gpu::coordinator::experiments::{self, Scale};
use cxl_gpu::media::MediaKind;

fn main() {
    let cells = experiments::fig9c(Scale::default(), true);
    assert_eq!(cells.len(), 9);
    // vadd (sequential): SR gain must be substantial on every medium and
    // grow with media slowness N >= O (paper: 7.1x / 8.8x / 10.1x trend).
    let gain = |wl: &str, m: MediaKind| {
        let c = cells.iter().find(|c| c.workload == wl && c.media == m).unwrap();
        c.cxl / c.sr
    };
    assert!(gain("vadd", MediaKind::Optane) > 1.5);
    assert!(gain("vadd", MediaKind::Znand) > 1.5);
    assert!(gain("vadd", MediaKind::Nand) > 1.5);
    // The paper's trend (gain grows with media slowness, 7.1/8.8/10.1x)
    // holds between O and Z here; NAND's long GC episodes compress the
    // measured gain at this scale, so only a soft bound is asserted.
    assert!(
        gain("vadd", MediaKind::Nand) >= 0.5 * gain("vadd", MediaKind::Optane),
        "NAND SR gain collapsed entirely"
    );
    // bfs (store-heavy, random): DS must provide the main benefit
    // (paper: up to 4x for bfs).
    for m in [MediaKind::Optane, MediaKind::Znand, MediaKind::Nand] {
        let c = cells.iter().find(|c| c.workload == "bfs" && c.media == m).unwrap();
        assert!(c.ds < c.sr, "bfs on {:?}: DS {} !< SR {}", m, c.ds, c.sr);
    }
    println!("fig9c bench OK");
}
