//! §15 — CXL RAS layer: graceful-degradation floors.
//!
//! Runs the `ras` experiment (CRC fault-rate × media sweep on `bfs`,
//! plus the degraded-pooled-endpoint and dirty-rescue scenarios), emits
//! `BENCH_ras.json` (schema: docs/BENCH_SCHEMA.md), and asserts the
//! tentpole's win conditions: link retry/replay contains a realistic
//! 1e-6 per-flit error rate at ≤ 10% execution-time cost; one degraded
//! pooled endpoint bounds (not destroys) the victim's p99 while the
//! switch demotes its WRR share; and every dirty device-cache byte is
//! drained to media before the degradation latch.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{ras, Scale};
use cxl_gpu::util::json::Json;

/// Exec-time slowdown ceiling at the 1e-6 flit-error rate (x fault-free).
const FLOOR_SLOWDOWN_1E6: f64 = 1.10;
/// Victim p99 ceiling with one pooled endpoint degraded (x healthy pool).
const FLOOR_DEGRADED_P99_X: f64 = 8.0;

fn main() {
    let res = ras(Scale::default(), true);

    let rows: Vec<Json> = res
        .rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("media".into(), Json::Str(r.media.name().into()));
            m.insert("crc_rate".into(), Json::Num(r.crc_rate));
            m.insert("exec_ms".into(), Json::Num(r.exec_ms));
            m.insert("slowdown".into(), Json::Num(r.slowdown));
            m.insert("retries".into(), Json::Num(r.retries as f64));
            m.insert("replays".into(), Json::Num(r.replays as f64));
            m.insert("poisons".into(), Json::Num(r.poisons as f64));
            m.insert("timeouts".into(), Json::Num(r.timeouts as f64));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("ras".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_slowdown_1e6".into(), Json::Num(FLOOR_SLOWDOWN_1E6));
    top.insert("floor_degraded_p99_x".into(), Json::Num(FLOOR_DEGRADED_P99_X));
    top.insert("slowdown_at_1e6".into(), Json::Num(res.slowdown_at_1e6));
    top.insert("degraded_healthy_p99_us".into(), Json::Num(res.degraded.healthy_p99_us));
    top.insert("degraded_p99_us".into(), Json::Num(res.degraded.degraded_p99_us));
    top.insert("degraded_victim_p99_x".into(), Json::Num(res.degraded.victim_p99_x));
    top.insert("degraded_failovers".into(), Json::Num(res.degraded.failovers as f64));
    top.insert(
        "rescue_dirty_bytes".into(),
        Json::Num(res.rescue.dirty_rescued_bytes as f64),
    );
    top.insert("rescue_line_bytes".into(), Json::Num(res.rescue.line_bytes as f64));
    top.insert("rescue_failovers".into(), Json::Num(res.rescue.failovers as f64));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_ras.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    // Zero-rate rows must land exactly on the fault-free baseline (the
    // structural bit-transparency contract, measured end to end).
    for r in res.rows.iter().filter(|r| r.crc_rate == 0.0) {
        assert!(
            (r.slowdown - 1.0).abs() < 1e-9,
            "{}: zero-rate cxl-ras must be bit-identical to cxl: {:.6}x",
            r.media.name(),
            r.slowdown
        );
        assert_eq!(r.retries + r.poisons + r.timeouts, 0);
    }
    // Nonzero rates must actually inject (the sweep isn't a no-op) and
    // the highest rate must draw retries on every media.
    for r in res.rows.iter().filter(|r| r.crc_rate >= 1e-3) {
        assert!(r.retries > 0, "{}: 1e-3 flit-error rate drew no retries", r.media.name());
        assert!(r.replays >= r.retries, "each retry replays at least one flit");
    }
    assert!(
        res.slowdown_at_1e6 <= FLOOR_SLOWDOWN_1E6,
        "1e-6 flit-error rate must cost ≤ {:.0}%: {:.3}x",
        (FLOOR_SLOWDOWN_1E6 - 1.0) * 100.0,
        res.slowdown_at_1e6
    );
    assert!(
        res.degraded.failovers >= 1,
        "the scheduled endpoint failure must latch and demote"
    );
    assert!(
        res.degraded.victim_p99_x <= FLOOR_DEGRADED_P99_X,
        "one degraded endpoint must leave the victim's p99 bounded: {:.2}x > {FLOOR_DEGRADED_P99_X}x",
        res.degraded.victim_p99_x
    );
    assert!(
        res.rescue.dirty_rescued_bytes > 0,
        "the pre-degradation drain must rescue dirty device-cache lines"
    );
    assert_eq!(
        res.rescue.dirty_rescued_bytes % res.rescue.line_bytes,
        0,
        "rescued bytes must be whole cache lines"
    );
    assert!(res.rescue.failovers >= 1);
    println!(
        "ras bench OK (slowdown at 1e-6: {:.3}x; degraded victim p99 {:.2}x; {} dirty bytes rescued)",
        res.slowdown_at_1e6, res.degraded.victim_p99_x, res.rescue.dirty_rescued_bytes
    );
}
