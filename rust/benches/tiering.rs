//! §12 — tiered hybrid-port memory: hot-fraction sweep.
//!
//! Runs the `tiering` experiment (tiered hybrid vs. all-DRAM vs. all-SSD
//! vs. static hybrid vs. the frozen-placement ablation, over the
//! `hot50..hot95` synthetics), emits `BENCH_tiering.json`
//! (schema: docs/BENCH_SCHEMA.md), and asserts the tentpole's win
//! condition: the tiered hybrid must beat the static `cxl-hybrid` split
//! on geomean across the sweep, with the migration engine actually
//! moving pages.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{tiering, Scale};
use cxl_gpu::util::json::Json;

/// Geomean speedup over the static hybrid the tiered config must clear.
const FLOOR_SPEEDUP_OVER_HYBRID: f64 = 1.0;

fn main() {
    let res = tiering(Scale::default(), true);

    let rows: Vec<Json> = res
        .rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("hot_permille".into(), Json::Num(r.hot_permille as f64));
            m.insert("all_dram_ms".into(), Json::Num(r.all_dram_ms));
            m.insert("all_ssd_ms".into(), Json::Num(r.all_ssd_ms));
            m.insert("hybrid_ms".into(), Json::Num(r.hybrid_ms));
            m.insert("tier_static_ms".into(), Json::Num(r.tier_static_ms));
            m.insert("tier_ms".into(), Json::Num(r.tier_ms));
            m.insert("promotions".into(), Json::Num(r.promotions as f64));
            m.insert("migrated_bytes".into(), Json::Num(r.migrated_bytes as f64));
            m.insert("tier_fast_ratio".into(), Json::Num(r.tier_fast_ratio));
            m.insert("static_fast_ratio".into(), Json::Num(r.static_fast_ratio));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("tiering".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_speedup_over_hybrid".into(), Json::Num(FLOOR_SPEEDUP_OVER_HYBRID));
    top.insert(
        "tier_speedup_over_hybrid".into(),
        Json::Num(res.tier_speedup_over_hybrid),
    );
    top.insert(
        "tier_speedup_over_static".into(),
        Json::Num(res.tier_speedup_over_static),
    );
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_tiering.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        res.tier_speedup_over_hybrid > FLOOR_SPEEDUP_OVER_HYBRID,
        "tiered hybrid must beat the static split: {:.3}x geomean",
        res.tier_speedup_over_hybrid
    );
    assert!(
        res.rows.iter().all(|r| r.promotions > 0),
        "every sweep point must migrate at least one page"
    );
    assert!(
        res.rows.iter().all(|r| r.tier_fast_ratio >= r.static_fast_ratio),
        "migration must not lower the fast-tier hit ratio"
    );
    println!(
        "tiering bench OK (tier over hybrid {:.2}x, over frozen placement {:.2}x)",
        res.tier_speedup_over_hybrid, res.tier_speedup_over_static
    );
}
