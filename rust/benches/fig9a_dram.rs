//! E3 — Fig. 9a: DRAM expander — UVM vs CXL vs GPU-DRAM over the full
//! Table 1b suite. Asserts the paper's qualitative shape.
use cxl_gpu::coordinator::experiments::{self, Scale};

fn main() {
    let r = experiments::fig9a(Scale::default(), true);
    // Shape: UVM is one-to-three orders of magnitude slower than ideal
    // (paper: 52.7x average); CXL sits within a small factor of ideal.
    assert!(r.uvm_over_ideal > 20.0, "UVM must be dramatically slower: {}", r.uvm_over_ideal);
    assert!(
        r.cxl_gap_load.abs() < 1.0,
        "CXL load-intensive gap should be fractional, got {}",
        r.cxl_gap_load
    );
    // CXL must beat UVM on every workload (paper: 44.2x average).
    for (c, u) in r.cxl.iter().zip(&r.uvm) {
        assert!(
            u.metrics.exec_time > c.metrics.exec_time,
            "{}: UVM faster than CXL?",
            c.workload
        );
    }
    println!("fig9a bench OK");
}
