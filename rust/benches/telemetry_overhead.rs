//! §19 — flight-recorder overhead: an armed recorder at the default
//! 50 µs cadence must be a rounding error on the simulator hot path.
//!
//! Runs the same (config, workload) cells with telemetry disabled and
//! with the recorder armed at the default cadence, five repeats each,
//! and compares median wall-clocks. Emits `BENCH_telemetry_overhead.json`
//! (schema: docs/BENCH_SCHEMA.md) before asserting, then enforces two
//! floors: armed throughput stays above the engine's 2M events/s floor,
//! and the armed median wall-clock stays within 1.10x of the disabled
//! one.
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::util::json::{write_file, Json, JsonObj};
use cxl_gpu::workloads::table1b::spec;

/// Same floor as sim_throughput: sampling must not cost the engine its
/// events-per-second budget.
const FLOOR_EVENTS_PER_SEC: f64 = 2.0e6;
/// Armed-over-disabled wall-clock ceiling at the default cadence.
const MAX_WALL_RATIO: f64 = 1.10;
const REPEATS: usize = 5;

/// Median wall-clock (ns) and the last run's metrics-derived event rate.
fn median_wall(cfg: &SystemConfig, wl: &str) -> (f64, f64) {
    let mut walls: Vec<f64> = Vec::with_capacity(REPEATS);
    let mut eps = 0.0;
    for _ in 0..REPEATS {
        let m = System::new(spec(wl), cfg).run();
        walls.push(m.wall_ns as f64);
        eps = m.events_per_sec();
    }
    walls.sort_by(|a, b| a.partial_cmp(b).expect("wall-clock is finite"));
    (walls[REPEATS / 2], eps)
}

fn main() {
    let mut t = Table::new(
        "telemetry overhead — armed (default cadence) vs disabled, median of 5",
        &["config", "workload", "off (ms)", "on (ms)", "ratio", "on M events/s", "frames"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut worst_ratio = 0.0f64;
    let mut worst_eps = f64::INFINITY;
    for (cfg_name, media, wl) in [
        ("cxl", MediaKind::Ddr5, "vadd"),
        ("cxl-cache", MediaKind::Znand, "hot90"),
    ] {
        let mut off = SystemConfig::named(cfg_name, media);
        off.total_ops = 2_000_000;
        if media.is_ssd() {
            off.ssd_scale();
        }
        let mut on = off.clone();
        on.telemetry.enabled = true;

        let (off_wall, _) = median_wall(&off, wl);
        let (on_wall, on_eps) = median_wall(&on, wl);
        let frames = System::new(spec(wl), &on).run().telemetry_frames();
        let ratio = on_wall / off_wall;
        worst_ratio = worst_ratio.max(ratio);
        worst_eps = worst_eps.min(on_eps);

        t.rowv(vec![
            cfg_name.into(),
            wl.into(),
            format!("{:.1}", off_wall / 1e6),
            format!("{:.1}", on_wall / 1e6),
            format!("{ratio:.3}"),
            format!("{:.2}", on_eps / 1e6),
            frames.to_string(),
        ]);
        rows.push(
            JsonObj::new()
                .set("config", cfg_name)
                .set("media", media.name())
                .set("workload", wl)
                .set("off_wall_ns", off_wall)
                .set("on_wall_ns", on_wall)
                .set("wall_ratio", ratio)
                .set("on_events_per_sec", on_eps)
                .set("frames", frames)
                .build(),
        );
    }
    t.print();

    // Write the report before asserting so a floor regression still
    // leaves the numbers on disk for diagnosis.
    let doc = JsonObj::new()
        .set("bench", "telemetry_overhead")
        .set("schema", "docs/BENCH_SCHEMA.md")
        .set("floor_events_per_sec", FLOOR_EVENTS_PER_SEC)
        .set("max_wall_ratio", MAX_WALL_RATIO)
        .set("worst_wall_ratio", worst_ratio)
        .set("worst_on_events_per_sec", worst_eps)
        .set("results", rows)
        .build();
    let path = "BENCH_telemetry_overhead.json";
    match write_file(path, &doc) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {e}"),
    }

    assert!(
        worst_eps > FLOOR_EVENTS_PER_SEC,
        "armed telemetry drops the simulator below {:.0}M events/s: {worst_eps}",
        FLOOR_EVENTS_PER_SEC / 1e6
    );
    assert!(
        worst_ratio < MAX_WALL_RATIO,
        "armed telemetry costs more than {MAX_WALL_RATIO}x wall-clock: {worst_ratio:.3}x"
    );
    println!(
        "telemetry_overhead bench OK (worst ratio {worst_ratio:.3}x, worst armed {:.1} M events/s)",
        worst_eps / 1e6
    );
}
