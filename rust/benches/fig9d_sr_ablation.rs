//! E6 — Fig. 9d: SR ablation (CXL-NAIVE / CXL-DYN / CXL-SR) over the
//! Seq / Around / Rand access classes, with EP internal-DRAM hit rates.
use cxl_gpu::coordinator::experiments::{self, Scale};

fn main() {
    let rows = experiments::fig9d(Scale::default(), true);
    assert_eq!(rows.len(), 3);
    for r in &rows {
        // Hit rate must rise monotonically from CXL through the SR
        // variants' general trend (paper: 47.4 -> 88.4 -> 99+ for Seq).
        assert!(r.hit_naive >= r.hit_cxl, "{}: naive should not lower hits", r.pattern);
        assert!(r.hit_dyn > r.hit_naive, "{}: DYN must beat naive hits", r.pattern);
    }
    let seq = rows.iter().find(|r| r.pattern == "Seq").unwrap();
    // Full SR must be the best (or tied) config for sequential streams.
    assert!(seq.sr <= seq.dyn_ * 1.05, "Seq: SR {} should match/beat DYN {}", seq.sr, seq.dyn_);
    assert!(seq.cxl / seq.sr > 1.4, "Seq: SR gain over CXL too small");
    println!("fig9d bench OK");
}
