//! §14 — expander-side device cache: capacity × workload-reuse sweep.
//!
//! Runs the `expander_cache` experiment (plain `cxl` vs the admit-all
//! `cxl-cache-bypass` ablation vs adaptive `cxl-cache` on a Z-NAND
//! expander, over the `hot50..hot95` reuse synthetics plus the `vadd`
//! streaming reference), emits `BENCH_expander_cache.json`
//! (schema: docs/BENCH_SCHEMA.md), and asserts the tentpole's win
//! condition: cached Z-NAND must beat uncached on geomean demand-load
//! latency across the reuse-heavy rows, with the admission predictor
//! actually bypassing the streams.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{expander_cache, Scale};
use cxl_gpu::util::json::Json;

/// Geomean uncached/cached load-latency ratio the reuse-heavy rows must
/// clear.
const FLOOR_CACHED_READ_SPEEDUP: f64 = 1.0;

fn main() {
    let res = expander_cache(Scale::default(), true);

    let rows: Vec<Json> = res
        .rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("workload".into(), Json::Str(r.workload.into()));
            m.insert("hot_permille".into(), Json::Num(r.hot_permille as f64));
            m.insert("capacity_bytes".into(), Json::Num(r.capacity_bytes as f64));
            m.insert("uncached_load_us".into(), Json::Num(r.uncached_load_us));
            m.insert("admit_all_load_us".into(), Json::Num(r.admit_all_load_us));
            m.insert("cached_load_us".into(), Json::Num(r.cached_load_us));
            m.insert("uncached_exec_ms".into(), Json::Num(r.uncached_exec_ms));
            m.insert("cached_exec_ms".into(), Json::Num(r.cached_exec_ms));
            m.insert("hit_rate".into(), Json::Num(r.hit_rate));
            m.insert("bypasses".into(), Json::Num(r.bypasses as f64));
            m.insert("writebacks".into(), Json::Num(r.writebacks as f64));
            m.insert("wb_hwm".into(), Json::Num(r.wb_hwm as f64));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("expander_cache".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert(
        "floor_cached_read_speedup".into(),
        Json::Num(FLOOR_CACHED_READ_SPEEDUP),
    );
    top.insert("cached_read_speedup".into(), Json::Num(res.cached_read_speedup));
    top.insert("admit_speedup".into(), Json::Num(res.admit_speedup));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_expander_cache.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        res.cached_read_speedup > FLOOR_CACHED_READ_SPEEDUP,
        "cached Z-NAND must beat uncached on reuse-heavy geomean: {:.3}x",
        res.cached_read_speedup
    );
    // The reuse-heavy rows must genuinely exercise the cache...
    assert!(
        res.rows.iter().filter(|r| r.hot_permille > 0).any(|r| r.hit_rate > 0.5),
        "no reuse row reached a 50% device-cache hit rate"
    );
    // ...and the streaming reference must be kept out of it.
    assert!(
        res.rows.iter().any(|r| r.bypasses > 0),
        "the admission predictor never bypassed anything"
    );
    println!(
        "expander-cache bench OK (cached over uncached {:.2}x, adaptive over admit-all {:.2}x)",
        res.cached_read_speedup, res.admit_speedup
    );
}
