//! §Perf — simulator throughput: events per wall-second across
//! representative configurations (the L3 hot-path metric).
use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::workloads::table1b::spec;

fn main() {
    let mut t = Table::new(
        "simulator throughput (events per wall-second)",
        &["config", "workload", "events", "wall (ms)", "M events/s"],
    );
    let mut worst = f64::INFINITY;
    for (cfg_name, media, wl) in [
        ("gpu-dram", MediaKind::Ddr5, "vadd"),
        ("cxl", MediaKind::Ddr5, "vadd"),
        ("cxl", MediaKind::Ddr5, "bfs"),
        ("uvm", MediaKind::Ddr5, "vadd"),
        ("cxl-sr", MediaKind::Znand, "vadd"),
        ("cxl-ds", MediaKind::Znand, "bfs"),
    ] {
        let mut cfg = SystemConfig::named(cfg_name, media);
        cfg.total_ops = 300_000;
        if media.is_ssd() {
            cfg.ssd_scale();
        }
        let m = System::new(spec(wl), &cfg).run();
        let eps = m.events_per_sec();
        worst = worst.min(eps);
        t.rowv(vec![
            cfg_name.into(),
            wl.into(),
            m.events.to_string(),
            format!("{:.1}", m.wall_ns as f64 / 1e6),
            format!("{:.2}", eps / 1e6),
        ]);
    }
    t.print();
    assert!(worst > 1e6, "simulator below 1M events/s: {worst}");
    println!("sim_throughput bench OK (worst {:.1} M events/s)", worst / 1e6);
}
