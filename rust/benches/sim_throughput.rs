//! §Perf — simulator throughput: events per wall-second across
//! representative configurations (the L3 hot-path metric).
//!
//! Emits `BENCH_sim_throughput.json` (via `util::json`; schema:
//! docs/BENCH_SCHEMA.md) so the perf trajectory is tracked across PRs,
//! then asserts the floor. The floor
//! was 1M events/s on the seed's binary-heap engine; the bucketed-queue +
//! allocation-free rebuild clears ≥2x that, so the assert rides at 2M.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::util::json::Json;
use cxl_gpu::workloads::table1b::spec;

/// Raised from the seed engine's 1e6 (acceptance: ≥2x events/s).
const FLOOR_EVENTS_PER_SEC: f64 = 2.0e6;

fn main() {
    let mut t = Table::new(
        "simulator throughput (events per wall-second)",
        &["config", "workload", "events", "wall (ms)", "M events/s"],
    );
    let mut worst = f64::INFINITY;
    let mut rows: Vec<Json> = Vec::new();
    for (cfg_name, media, wl) in [
        ("gpu-dram", MediaKind::Ddr5, "vadd"),
        ("cxl", MediaKind::Ddr5, "vadd"),
        ("cxl", MediaKind::Ddr5, "bfs"),
        ("uvm", MediaKind::Ddr5, "vadd"),
        ("cxl-sr", MediaKind::Znand, "vadd"),
        ("cxl-ds", MediaKind::Znand, "bfs"),
        // The device-cache path (§14) must hold the same per-event floor.
        ("cxl-cache", MediaKind::Znand, "hot90"),
        // The RAS fault-injection path (§15) must hold it too.
        ("cxl-ras", MediaKind::Znand, "bfs"),
        // The serving front door (§16: open-loop arrivals + request
        // dispatch) must hold it too.
        ("cxl-serve", MediaKind::Ddr5, "vadd"),
        // The sharded-pool config (§17) must hold it too. Standalone it
        // builds like `cxl-pool` (a one-tenant fabric); the per-event
        // cost it probes is the deferral-capable hot path.
        ("cxl-pool-shard", MediaKind::Ddr5, "vadd"),
    ] {
        let mut cfg = SystemConfig::named(cfg_name, media);
        // 10x the pre-streaming budget: op streams freed the O(total_ops)
        // trace memory, so the throughput probe runs at long-scenario
        // scale (the floor is per-event and scale-independent).
        cfg.total_ops = 3_000_000;
        if media.is_ssd() {
            cfg.ssd_scale();
        }
        let m = System::new(spec(wl), &cfg).run();
        let eps = m.events_per_sec();
        worst = worst.min(eps);
        t.rowv(vec![
            cfg_name.into(),
            wl.into(),
            m.events.to_string(),
            format!("{:.1}", m.wall_ns as f64 / 1e6),
            format!("{:.2}", eps / 1e6),
        ]);
        let mut row = BTreeMap::new();
        row.insert("config".into(), Json::Str(cfg_name.into()));
        row.insert("media".into(), Json::Str(media.name().into()));
        row.insert("workload".into(), Json::Str(wl.into()));
        row.insert("events".into(), Json::Num(m.events as f64));
        row.insert("wall_ns".into(), Json::Num(m.wall_ns as f64));
        row.insert("events_per_sec".into(), Json::Num(eps));
        rows.push(Json::Obj(row));
    }
    t.print();

    // Write the report before asserting so a floor regression still
    // leaves the numbers on disk for diagnosis.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("sim_throughput".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_events_per_sec".into(), Json::Num(FLOOR_EVENTS_PER_SEC));
    top.insert("worst_events_per_sec".into(), Json::Num(worst));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_sim_throughput.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        worst > FLOOR_EVENTS_PER_SEC,
        "simulator below {:.0}M events/s floor: {worst}",
        FLOOR_EVENTS_PER_SEC / 1e6
    );
    println!("sim_throughput bench OK (worst {:.1} M events/s)", worst / 1e6);
}
