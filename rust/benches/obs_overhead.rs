//! §18 — span-tracing overhead: armed tracing at 1/64 sampling must be
//! a rounding error on the simulator hot path.
//!
//! Runs the same (config, workload) cells with tracing disabled and with
//! tracing armed at `sample_shift = 6`, five repeats each, and compares
//! median wall-clocks. Emits `BENCH_obs_overhead.json` (schema:
//! docs/BENCH_SCHEMA.md) before asserting, then enforces two floors:
//! armed throughput stays above the engine's 2M events/s floor, and the
//! armed median wall-clock stays within 1.10x of the disabled one.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::util::json::Json;
use cxl_gpu::workloads::table1b::spec;

/// Same floor as sim_throughput: tracing must not cost the engine its
/// events-per-second budget.
const FLOOR_EVENTS_PER_SEC: f64 = 2.0e6;
/// Armed-over-disabled wall-clock ceiling at 1/64 sampling.
const MAX_WALL_RATIO: f64 = 1.10;
const REPEATS: usize = 5;

/// Median wall-clock (ns) and the last run's metrics-derived event rate.
fn median_wall(cfg: &SystemConfig, wl: &str) -> (f64, f64) {
    let mut walls: Vec<f64> = Vec::with_capacity(REPEATS);
    let mut eps = 0.0;
    for _ in 0..REPEATS {
        let m = System::new(spec(wl), cfg).run();
        walls.push(m.wall_ns as f64);
        eps = m.events_per_sec();
    }
    walls.sort_by(|a, b| a.partial_cmp(b).expect("wall-clock is finite"));
    (walls[REPEATS / 2], eps)
}

fn main() {
    let mut t = Table::new(
        "obs overhead — armed (1/64 sampling) vs disabled, median of 5",
        &["config", "workload", "off (ms)", "on (ms)", "ratio", "on M events/s", "spans"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut worst_ratio = 0.0f64;
    let mut worst_eps = f64::INFINITY;
    for (cfg_name, media, wl) in [
        ("cxl", MediaKind::Ddr5, "vadd"),
        ("cxl-cache", MediaKind::Znand, "hot90"),
    ] {
        let mut off = SystemConfig::named(cfg_name, media);
        off.total_ops = 2_000_000;
        if media.is_ssd() {
            off.ssd_scale();
        }
        let mut on = off.clone();
        on.obs.enabled = true;
        on.obs.sample_shift = 6;

        let (off_wall, _) = median_wall(&off, wl);
        let (on_wall, on_eps) = median_wall(&on, wl);
        let spans = System::new(spec(wl), &on).run().obs_spans();
        let ratio = on_wall / off_wall;
        worst_ratio = worst_ratio.max(ratio);
        worst_eps = worst_eps.min(on_eps);

        t.rowv(vec![
            cfg_name.into(),
            wl.into(),
            format!("{:.1}", off_wall / 1e6),
            format!("{:.1}", on_wall / 1e6),
            format!("{ratio:.3}"),
            format!("{:.2}", on_eps / 1e6),
            spans.to_string(),
        ]);
        let mut row = BTreeMap::new();
        row.insert("config".into(), Json::Str(cfg_name.into()));
        row.insert("media".into(), Json::Str(media.name().into()));
        row.insert("workload".into(), Json::Str(wl.into()));
        row.insert("off_wall_ns".into(), Json::Num(off_wall));
        row.insert("on_wall_ns".into(), Json::Num(on_wall));
        row.insert("wall_ratio".into(), Json::Num(ratio));
        row.insert("on_events_per_sec".into(), Json::Num(on_eps));
        row.insert("spans".into(), Json::Num(spans as f64));
        rows.push(Json::Obj(row));
    }
    t.print();

    // Write the report before asserting so a floor regression still
    // leaves the numbers on disk for diagnosis.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("obs_overhead".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_events_per_sec".into(), Json::Num(FLOOR_EVENTS_PER_SEC));
    top.insert("max_wall_ratio".into(), Json::Num(MAX_WALL_RATIO));
    top.insert("worst_wall_ratio".into(), Json::Num(worst_ratio));
    top.insert("worst_on_events_per_sec".into(), Json::Num(worst_eps));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_obs_overhead.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    assert!(
        worst_eps > FLOOR_EVENTS_PER_SEC,
        "armed tracing drops the simulator below {:.0}M events/s: {worst_eps}",
        FLOOR_EVENTS_PER_SEC / 1e6
    );
    assert!(
        worst_ratio < MAX_WALL_RATIO,
        "armed tracing costs more than {MAX_WALL_RATIO}x wall-clock: {worst_ratio:.3}x"
    );
    println!(
        "obs_overhead bench OK (worst ratio {worst_ratio:.3}x, worst armed {:.1} M events/s)",
        worst_eps / 1e6
    );
}
