//! E1 — Fig. 3b: CXL controller round-trip latency, ours vs SMT vs TPP.
//!
//! Reproduces the figure's three bars plus the per-layer breakdown of
//! Fig. 3a, and micro-benchmarks the latency-model hot path itself.
use cxl_gpu::coordinator::experiments;
use cxl_gpu::cxl::{ControllerKind, CxlController, Flit, MemOpcode};
use cxl_gpu::util::bench::Bench;

fn main() {
    let r = experiments::fig3b(true);
    // Shape assertions (the paper's qualitative claims).
    assert!(r.ours_ns < 100.0, "ours must be two-digit ns: {}", r.ours_ns);
    assert!(r.smt_ns / r.ours_ns > 3.0, "paper: >3x faster than SMT");
    assert!(r.tpp_ns / r.ours_ns > 3.0, "paper: >3x faster than TPP");
    assert!((200.0..300.0).contains(&r.smt_ns), "SMT ~250 ns");

    // Hot-path micro-bench: latency computation per flit.
    let ctrl = CxlController::new(ControllerKind::Panmnesia);
    let flit = Flit { op: MemOpcode::MemRd, addr: 0x1000, len: 64, issued_at: 0, req_id: 1 };
    Bench::new("controller/request_leg").iters(1000, 7, 100_000).run(|| {
        std::hint::black_box(ctrl.request_leg(std::hint::black_box(&flit)));
    });
    println!("fig3b bench OK");
}
