//! §16 — online serving front door: knee + graceful-overload floors.
//!
//! Runs the `serve` experiment (offered-load ladder per config at a 1 ms
//! SLO, then 2x-knee open-loop overload), emits `BENCH_serve.json`
//! (schema: docs/BENCH_SCHEMA.md), and asserts the tentpole's win
//! conditions: every CXL config has a measurable knee inside the ladder
//! and above the UVM baseline's; at 2x-knee offered load goodput holds
//! ≥ 70% of knee goodput with the bounded queue and deadline shedder —
//! not unbounded queue growth — absorbing the excess.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{serve, Scale, ServePoint};
use cxl_gpu::util::json::Json;

/// Goodput retention floor at 2x-knee offered load (x knee goodput).
const FLOOR_OVERLOAD_GOODPUT: f64 = 0.70;
/// Admission queue bound the experiment arms (requests).
const QUEUE_CAP: u64 = 32;

fn point_json(p: &ServePoint) -> Json {
    let mut m = BTreeMap::new();
    m.insert("rate_rps".into(), Json::Num(p.rate_rps));
    m.insert("p50_us".into(), Json::Num(p.p50_us));
    m.insert("p99_us".into(), Json::Num(p.p99_us));
    m.insert("p999_us".into(), Json::Num(p.p999_us));
    m.insert("goodput_rps".into(), Json::Num(p.goodput_rps));
    m.insert("arrivals".into(), Json::Num(p.arrivals as f64));
    m.insert("completed".into(), Json::Num(p.completed as f64));
    m.insert("shed".into(), Json::Num(p.shed as f64));
    m.insert("timed_out".into(), Json::Num(p.timed_out as f64));
    m.insert("rejected".into(), Json::Num(p.rejected as f64));
    m.insert("queue_hwm".into(), Json::Num(p.queue_hwm as f64));
    m.insert("sustainable".into(), Json::Bool(p.sustainable));
    Json::Obj(m)
}

fn main() {
    let res = serve(Scale::default(), true);

    let variants: Vec<Json> = res
        .variants
        .iter()
        .map(|v| {
            let mut m = BTreeMap::new();
            m.insert("config".into(), Json::Str(v.name.into()));
            m.insert("media".into(), Json::Str(v.media.name().into()));
            m.insert("knee_rps".into(), Json::Num(v.knee_rps));
            m.insert("knee_goodput_rps".into(), Json::Num(v.knee_goodput_rps));
            m.insert(
                "overload_goodput_ratio".into(),
                Json::Num(v.overload_goodput_ratio),
            );
            if let Some(o) = &v.overload {
                m.insert("overload".into(), point_json(o));
            }
            m.insert("points".into(), Json::Arr(v.points.iter().map(point_json).collect()));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("serve".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_overload_goodput".into(), Json::Num(FLOOR_OVERLOAD_GOODPUT));
    top.insert("queue_cap".into(), Json::Num(QUEUE_CAP as f64));
    if let Some(b) = &res.bucketed {
        top.insert("bucketed_overload".into(), point_json(b));
    }
    top.insert("results".into(), Json::Arr(variants));
    let path = "BENCH_serve.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    let uvm = &res.variants[0];
    assert_eq!(uvm.name, "uvm", "variant 0 is the UVM baseline");
    let top_rate = uvm.points.last().expect("ladder has rungs").rate_rps;
    for v in res.variants.iter().skip(1) {
        // (a) A measurable knee exists: some rung sustains, the top rung
        // does not, and the CXL knee clears the UVM baseline's.
        assert!(v.knee_rps > 0.0, "{}: no sustainable rung on the ladder", v.name);
        assert!(
            v.knee_rps < top_rate,
            "{}: knee must sit inside the ladder (top rung unsustainable)",
            v.name
        );
        assert!(
            v.knee_rps > uvm.knee_rps,
            "{}: CXL knee ({:.0} rps) must clear the UVM baseline ({:.0} rps)",
            v.name,
            v.knee_rps,
            uvm.knee_rps
        );
        // (b) Graceful degradation at 2x knee: goodput holds while the
        // bounded queue sheds/times out the excess.
        let o = v.overload.as_ref().expect("kneed variant has an overload run");
        assert!(
            v.overload_goodput_ratio >= FLOOR_OVERLOAD_GOODPUT,
            "{}: goodput at 2x knee fell to {:.0}% of knee goodput (floor {:.0}%)",
            v.name,
            100.0 * v.overload_goodput_ratio,
            100.0 * FLOOR_OVERLOAD_GOODPUT
        );
        assert!(
            o.shed + o.timed_out > 0,
            "{}: 2x-knee excess must be absorbed by shedding/timeouts",
            v.name
        );
        assert!(
            o.queue_hwm <= QUEUE_CAP,
            "{}: admission queue must stay bounded: hwm {} > cap {QUEUE_CAP}",
            v.name,
            o.queue_hwm
        );
    }
    // Admission control on top: the token bucket converts overload into
    // cheap rejections while goodput still holds the floor.
    let b = res.bucketed.as_ref().expect("a best variant kneed");
    assert!(b.rejected > 0, "the knee-rate token bucket must reject the 2x excess");
    assert!(b.queue_hwm <= QUEUE_CAP);
    println!(
        "serve bench OK ({} variants; knees {} k rps; worst 2x-knee goodput {:.0}%)",
        res.variants.len(),
        res.variants
            .iter()
            .map(|v| format!("{:.0}", v.knee_rps / 1e3))
            .collect::<Vec<_>>()
            .join("/"),
        100.0
            * res
                .variants
                .iter()
                .skip(1)
                .map(|v| v.overload_goodput_ratio)
                .fold(f64::INFINITY, f64::min)
    );
}
