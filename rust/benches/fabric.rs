//! §13 — pooled CXL fabric: multi-tenant QoS floors.
//!
//! Runs the `multi-tenant` experiment (victim solo / shared pool /
//! shared pool + QoS over the 2/4/8-tenant hog mixes), emits
//! `BENCH_fabric.json` (schema: docs/BENCH_SCHEMA.md), and asserts the
//! tentpole's win condition: with QoS enabled the victim tenant's p99
//! expander-load slowdown under hog co-tenants is bounded (≤ 2x its
//! solo run) while pooled geomean throughput stays within 5% of the
//! no-QoS pool — i.e. isolation is nearly free.
use std::collections::BTreeMap;

use cxl_gpu::coordinator::experiments::{multi_tenant, Scale};
use cxl_gpu::util::json::Json;

/// Victim p99 slowdown ceiling under QoS (x solo).
const FLOOR_VICTIM_P99_X: f64 = 2.0;
/// Pooled geomean throughput floor, QoS vs no-QoS.
const FLOOR_QOS_TPUT_RATIO: f64 = 0.95;

fn main() {
    let res = multi_tenant(Scale::default(), true);

    let rows: Vec<Json> = res
        .rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("mix".into(), Json::Str(r.mix.into()));
            m.insert("tenants".into(), Json::Num(r.tenants as f64));
            m.insert("victim_solo_p99_us".into(), Json::Num(r.victim_solo_p99_us));
            m.insert("victim_pool_p99_x".into(), Json::Num(r.victim_pool_p99_x));
            m.insert("victim_qos_p99_x".into(), Json::Num(r.victim_qos_p99_x));
            m.insert("pool_geo_tput_mops".into(), Json::Num(r.pool_geo_tput_mops));
            m.insert("qos_geo_tput_mops".into(), Json::Num(r.qos_geo_tput_mops));
            m.insert("qos_tput_ratio".into(), Json::Num(r.qos_tput_ratio));
            m.insert("qos_throttle_waits".into(), Json::Num(r.qos_throttle_waits as f64));
            m.insert("qos_ingress_hwm".into(), Json::Num(r.qos_ingress_hwm as f64));
            m.insert("pool_backpressure".into(), Json::Num(r.pool_backpressure as f64));
            Json::Obj(m)
        })
        .collect();

    // Report before asserting so regressions still leave data on disk.
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("fabric".into()));
    top.insert("schema".into(), Json::Str("docs/BENCH_SCHEMA.md".into()));
    top.insert("floor_victim_p99_x".into(), Json::Num(FLOOR_VICTIM_P99_X));
    top.insert("floor_qos_tput_ratio".into(), Json::Num(FLOOR_QOS_TPUT_RATIO));
    top.insert("results".into(), Json::Arr(rows));
    let path = "BENCH_fabric.json";
    match std::fs::write(path, Json::Obj(top).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    for r in &res.rows {
        assert!(
            r.victim_qos_p99_x <= FLOOR_VICTIM_P99_X,
            "{}: QoS must bound the victim's p99 slowdown: {:.2}x > {FLOOR_VICTIM_P99_X}x",
            r.mix,
            r.victim_qos_p99_x
        );
        assert!(
            r.qos_tput_ratio >= FLOOR_QOS_TPUT_RATIO,
            "{}: QoS must not tax pooled throughput: {:.3} < {FLOOR_QOS_TPUT_RATIO}",
            r.mix,
            r.qos_tput_ratio
        );
        assert!(
            r.qos_ingress_hwm >= 1,
            "{}: multi-tenant traffic must transit the switch ingress",
            r.mix
        );
    }
    println!(
        "fabric bench OK ({} mixes; worst QoS p99 {:.2}x, worst QoS tput ratio {:.3})",
        res.rows.len(),
        res.rows.iter().map(|r| r.victim_qos_p99_x).fold(0.0, f64::max),
        res.rows.iter().map(|r| r.qos_tput_ratio).fold(f64::INFINITY, f64::min),
    );
}
