//! E7 — Fig. 9e: time series of load/store latency and ingress-queue
//! occupancy around GC episodes, CXL-SR vs CXL-DS (bfs, Z-NAND).
use cxl_gpu::coordinator::experiments::{self, Scale};

fn main() {
    let r = experiments::fig9e(Scale::default(), true);
    assert!(!r.sr_load.is_empty() && !r.ds_load.is_empty());
    // The paper's claim: DS hides the write tail — its peak store-latency
    // bucket must sit far below CXL-SR's.
    assert!(
        r.ds_peak_store_us < r.sr_peak_store_us,
        "DS peak store {} !< SR peak {}",
        r.ds_peak_store_us,
        r.sr_peak_store_us
    );
    println!("fig9e bench OK");
}
