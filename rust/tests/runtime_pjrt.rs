//! Integration: the PJRT runtime loads and executes every AOT artifact.
//! Skips (with a message) when `make artifacts` has not been run.
//! Compiled only with `--features pjrt` (the runtime needs the vendored
//! `xla` closure, absent from offline builds).
#![cfg(feature = "pjrt")]

use cxl_gpu::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_thirteen_workloads() {
    let Some(rt) = runtime() else { return };
    let names = rt.manifest().names();
    assert_eq!(names.len(), 13, "{names:?}");
    for w in cxl_gpu::workloads::table1b::ALL_WORKLOADS {
        assert!(names.contains(&w.name), "missing artifact for {}", w.name);
    }
}

#[test]
fn every_artifact_executes_with_finite_outputs() {
    let Some(rt) = runtime() else { return };
    for name in rt.manifest().names() {
        let out = rt.execute_named(name, 7).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(out.elements > 0, "{name}: empty output");
        assert!(out.checksum.is_finite(), "{name}: non-finite checksum");
    }
}

#[test]
fn execution_is_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let a = rt.execute_named("vadd", 3).unwrap();
    let b = rt.execute_named("vadd", 3).unwrap();
    assert_eq!(a.checksum, b.checksum);
    let c = rt.execute_named("vadd", 4).unwrap();
    assert_ne!(a.checksum, c.checksum, "different seed, different inputs");
}

#[test]
fn saxpy_checksum_matches_reference_math() {
    let Some(rt) = runtime() else { return };
    // saxpy = 2.5*x + y with x, y ~ U(-1, 1): E[out] ~ 0; the checksum
    // (mean) must be small relative to the value scale.
    let out = rt.execute_named("saxpy", 11).unwrap();
    assert!(out.checksum.abs() < 0.05, "saxpy mean {}", out.checksum);
}
