//! Determinism regression: the engine rebuild (bucketed event queue,
//! arena waiter chains, parallel sweep runner) must keep runs
//! bit-reproducible. Each scenario runs twice back to back and once
//! through the parallel runner; every `RunMetrics` fingerprint must be
//! identical — this guards both the queue swap and the threaded runner.

use cxl_gpu::coordinator::config::SystemConfig;
use cxl_gpu::coordinator::runner::{run_jobs, run_suite, run_with, SweepJob};
use cxl_gpu::coordinator::system::System;
use cxl_gpu::coordinator::RunMetrics;
use cxl_gpu::media::MediaKind;
use cxl_gpu::workloads::table1b::{spec, ALL_WORKLOADS};

/// Everything deterministic about a run (wall-clock excluded, of course).
/// Latency summaries are compared through their exact f64 bits: the same
/// event order must produce the same accumulator states. The field list
/// lives on `RunMetrics` itself now (the sharded-pool equivalence layer
/// compares through it too); this wrapper keeps the test bodies short.
fn fingerprint(m: &RunMetrics) -> Vec<u64> {
    m.fingerprint()
}

fn small(name: &str, media: MediaKind) -> SystemConfig {
    let mut c = SystemConfig::named(name, media);
    c.total_ops = 6_000;
    c.ssd_scale();
    c
}

#[test]
fn repeated_runs_are_bit_identical() {
    for (name, media, wl) in [
        ("cxl-sr", MediaKind::Znand, "bfs"),
        ("uvm", MediaKind::Ddr5, "vadd"),
        // Tiered configs: the migration engine's decisions (epoch scans,
        // swap plans, per-chunk transfers) must be bit-reproducible too.
        ("cxl-tier", MediaKind::Znand, "hot90"),
        ("cxl-tier-static", MediaKind::Znand, "hot90"),
        // Pooled fabric, with and without the QoS token bucket.
        ("cxl-pool", MediaKind::Znand, "bfs"),
        ("cxl-pool-qos", MediaKind::Znand, "bfs"),
        // Device cache: admission epochs, LRU state and the writeback
        // drain must replay bit-for-bit (with and without the
        // admission predictor).
        ("cxl-cache", MediaKind::Znand, "hot75"),
        ("cxl-cache-bypass", MediaKind::Znand, "hot75"),
        // RAS fault injection: the forked fault sub-streams, retry legs
        // and containment waits must replay bit-for-bit too.
        ("cxl-ras", MediaKind::Znand, "bfs"),
        // Serving front door: open-loop arrival draws, admission
        // decisions and request expansions must replay bit-for-bit,
        // direct and pooled.
        ("cxl-serve", MediaKind::Ddr5, "vadd"),
        ("cxl-pool-serve", MediaKind::Znand, "bfs"),
    ] {
        let cfg = small(name, media);
        let a = System::new(spec(wl), &cfg).run();
        let b = System::new(spec(wl), &cfg).run();
        assert_eq!(fingerprint(&a), fingerprint(&b), "{name}/{wl} diverged across runs");
    }
}

#[test]
fn parallel_runner_matches_direct_runs() {
    // The same (workload, config) cells, once executed directly in this
    // thread and once through the work-stealing pool: identical metrics,
    // identical order.
    let mk = |name: &str, media: MediaKind, wl: &str| -> SweepJob {
        (spec(wl), small(name, media))
    };
    let jobs = vec![
        mk("cxl-sr", MediaKind::Znand, "bfs"),
        mk("uvm", MediaKind::Ddr5, "vadd"),
        mk("cxl-ds", MediaKind::Znand, "sort"),
        mk("cxl", MediaKind::Ddr5, "gnn"),
        mk("cxl-tier", MediaKind::Znand, "hot90"),
        mk("cxl-tier-static", MediaKind::Znand, "hot75"),
    ];
    let direct: Vec<_> = jobs.iter().map(|j| run_with(j.0, &j.1)).collect();
    let pooled = run_jobs(&jobs);
    assert_eq!(direct.len(), pooled.len());
    for (d, p) in direct.iter().zip(&pooled) {
        assert_eq!(d.workload, p.workload, "parallel runner reordered results");
        assert_eq!(d.config, p.config);
        assert_eq!(
            fingerprint(&d.metrics),
            fingerprint(&p.metrics),
            "{}/{} diverged under the parallel runner",
            d.workload,
            d.config
        );
    }
}

/// Streamed traces advance RNG state op by op instead of in one up-front
/// pass, so determinism must also hold at a budget far above the other
/// tests here (50x their 6k ops). 300k is the old full-sweep scale — the
/// benches' 3M/4M budgets are release-mode territory, too slow for a
/// debug-mode `cargo test`; any op-by-op drift compounds well before
/// 300k draws per run.
#[test]
fn large_budget_runs_are_bit_identical() {
    let mut cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
    cfg.total_ops = 300_000;
    let a = System::new(spec("gnn"), &cfg).run();
    let b = System::new(spec("gnn"), &cfg).run();
    assert_eq!(fingerprint(&a), fingerprint(&b), "cxl/gnn diverged at the large budget");
    assert!(a.exec_time > 0 && a.events > 0);
}

/// The passthrough invariant (DESIGN.md §13): a single-tenant,
/// no-QoS pool is the direct topology — the switch adds no latency, no
/// arbitration, no bookkeeping — so `cxl-pool` must reproduce `cxl`
/// *bit-identically*, media and engines included.
#[test]
fn single_tenant_pool_reproduces_direct_cxl_bit_identically() {
    for (media, wl) in [(MediaKind::Ddr5, "gnn"), (MediaKind::Znand, "bfs")] {
        let direct = System::new(spec(wl), &small("cxl", media)).run();
        let pooled = System::new(spec(wl), &small("cxl-pool", media)).run();
        assert_eq!(
            fingerprint(&direct),
            fingerprint(&pooled),
            "cxl-pool/{wl} on {media:?} is not a bit-identical passthrough"
        );
        assert_eq!(pooled.ingress_hwm, 0, "passthrough must not track ingress");
    }
}

/// The zero-capacity identity (DESIGN.md §14): a `cxl-cache` whose
/// device cache has zero capacity builds *no cache object at all*, so
/// every port path must be byte-identical to plain `cxl` — same event
/// counts, same latched latency bits, all cache counters zero. Same for
/// the `cxl-cache-bypass` ablation with admission forced off. This is
/// the determinism carry-over guarantee: enabling the config without
/// giving it capacity cannot perturb a single bit.
#[test]
fn zero_capacity_cache_reproduces_cxl_bit_identically() {
    for (media, wl) in [(MediaKind::Znand, "hot90"), (MediaKind::Znand, "bfs")] {
        let direct = System::new(spec(wl), &small("cxl", media)).run();
        for name in ["cxl-cache", "cxl-cache-bypass"] {
            let mut cfg = small(name, media);
            cfg.cache.capacity_bytes = 0;
            let cached = System::new(spec(wl), &cfg).run();
            assert_eq!(
                fingerprint(&direct),
                fingerprint(&cached),
                "{name}/{wl} at zero capacity is not bit-identical to cxl"
            );
            assert_eq!(cached.cache_hits + cached.cache_misses, 0);
        }
    }
}

/// The zero-rate identity (DESIGN.md §15): a `cxl-ras` whose every fault
/// rate is zero and whose degradation is unscheduled builds *no RAS
/// state at all* — the spec is inert even with `enabled` left on — so
/// every port path must be byte-identical to plain `cxl`: same event
/// counts, same latched latency bits, all RAS counters zero. Same for
/// `cxl-pool-ras` against `cxl-pool`. Arming the config family without
/// giving it a fault to inject cannot perturb a single bit.
#[test]
fn zero_rate_ras_reproduces_baselines_bit_identically() {
    for (armed, baseline, media, wl) in [
        ("cxl-ras", "cxl", MediaKind::Znand, "bfs"),
        ("cxl-ras", "cxl", MediaKind::Ddr5, "gnn"),
        ("cxl-pool-ras", "cxl-pool", MediaKind::Znand, "bfs"),
    ] {
        let base = System::new(spec(wl), &small(baseline, media)).run();
        let mut cfg = small(armed, media);
        cfg.ras.crc_error_rate = 0.0;
        cfg.ras.media_spike_rate = 0.0;
        cfg.ras.timeout_rate = 0.0;
        cfg.ras.degrade_at = cxl_gpu::sim::Time::MAX;
        assert!(cfg.ras.enabled && cfg.ras.is_inert(), "zeroed spec must be inert");
        let ras = System::new(spec(wl), &cfg).run();
        assert_eq!(
            fingerprint(&base),
            fingerprint(&ras),
            "{armed}/{wl} on {media:?} at zero rates is not bit-identical to {baseline}"
        );
        assert_eq!(
            ras.ras_retries + ras.ras_poisons + ras.ras_timeouts + ras.ras_failovers,
            0
        );
    }
}

/// The zero-rate serve identity (DESIGN.md §16): a `cxl-serve` whose
/// arrival rate is zero builds *no front door at all* — the spec is
/// inert even with `enabled` left on — so the run takes the exact
/// closed-loop code path and must be byte-identical to plain `cxl`:
/// same event counts, same latched latency bits, all serve counters
/// zero. Same for `cxl-pool-serve` against `cxl-pool-qos` (its base
/// topology). Arming the config family without offering it a single
/// request cannot perturb a bit.
#[test]
fn zero_rate_serve_reproduces_baselines_bit_identically() {
    for (armed, baseline, media, wl) in [
        ("cxl-serve", "cxl", MediaKind::Ddr5, "vadd"),
        ("cxl-serve", "cxl", MediaKind::Znand, "bfs"),
        ("cxl-pool-serve", "cxl-pool-qos", MediaKind::Znand, "bfs"),
    ] {
        let base = System::new(spec(wl), &small(baseline, media)).run();
        let mut cfg = small(armed, media);
        cfg.serve.rate_rps = 0.0;
        assert!(cfg.serve.enabled && cfg.serve.is_inert(), "zero-rate spec must be inert");
        let served = System::new(spec(wl), &cfg).run();
        assert_eq!(
            fingerprint(&base),
            fingerprint(&served),
            "{armed}/{wl} on {media:?} at zero rate is not bit-identical to {baseline}"
        );
        assert_eq!(served.serve_arrivals, 0);
        assert_eq!(served.req_latency.count(), 0);
    }
}

/// Fixed-seed open-loop reproducibility: with a real arrival rate armed,
/// the request sequence — every arrival draw, admission verdict, warp
/// expansion and end-to-end latency sample — must replay bit-for-bit,
/// and the counters must show requests actually flowed.
#[test]
fn armed_serve_requests_replay_bit_for_bit() {
    let mut cfg = small("cxl-serve", MediaKind::Ddr5);
    let a = System::new(spec("vadd"), &cfg).run();
    let b = System::new(spec("vadd"), &cfg).run();
    assert_eq!(fingerprint(&a), fingerprint(&b), "cxl-serve request sequence diverged");
    assert!(a.serve_arrivals > 0, "armed rate must draw arrivals");
    assert_eq!(a.serve_completed, a.req_latency.count(), "one latency sample per completion");
    // Overloaded variant: shedding/timeout decisions replay too.
    cfg.serve.rate_rps = 5e6;
    cfg.serve.slo = 20 * cxl_gpu::sim::US;
    cfg.serve.queue_cap = 8;
    let oa = System::new(spec("vadd"), &cfg).run();
    let ob = System::new(spec("vadd"), &cfg).run();
    assert_eq!(fingerprint(&oa), fingerprint(&ob), "overloaded serve run diverged");
    assert!(oa.serve_shed + oa.serve_timed_out > 0, "overload must shed or time out");
}

/// Fixed-seed fault reproducibility: with real fault rates armed, the
/// injected sequence — every retry, poison and timeout — must replay
/// bit-for-bit across runs, and the counters must show the faults
/// actually fired (the reproducibility claim is empty on a quiet run).
#[test]
fn armed_ras_faults_replay_bit_for_bit() {
    let mut cfg = small("cxl-ras", MediaKind::Znand);
    // Hot enough that a 6k-op debug run draws retries for certain.
    cfg.ras.crc_error_rate = 1e-3;
    cfg.ras.timeout_rate = 1e-3;
    cfg.ras.timeout = 2 * cxl_gpu::sim::US;
    let a = System::new(spec("bfs"), &cfg).run();
    let b = System::new(spec("bfs"), &cfg).run();
    assert_eq!(fingerprint(&a), fingerprint(&b), "cxl-ras fault sequence diverged");
    assert!(a.ras_retries > 0, "armed CRC rate must draw retries");
    assert!(a.ras_timeouts > 0, "armed timeout rate must draw timeouts");
    assert!(a.ras_replays >= a.ras_retries, "each retry replays >= 1 flit");
}

/// Multi-tenant pool runs — the merged event order, the shared switch
/// state, the QoS controller's AIMD walk — must be bit-reproducible.
#[test]
fn pool_runs_are_bit_reproducible() {
    use cxl_gpu::fabric::{run_pool, Tenant};
    let tenants = || -> Vec<Tenant> {
        [("path", 4usize, 2usize), ("sort", 16, 8), ("sort", 16, 8)]
            .iter()
            .map(|&(wl, warps, mlp)| {
                let mut cfg = SystemConfig::named("cxl-pool-qos", MediaKind::Znand);
                cfg.total_ops = 6_000;
                cfg.ssd_scale();
                cfg.warps = warps;
                cfg.mlp = mlp;
                Tenant { workload: spec(wl), cfg }
            })
            .collect()
    };
    let a = run_pool(&tenants()).expect("pool run");
    let b = run_pool(&tenants()).expect("pool run");
    assert_eq!(a.events, b.events, "merged event count diverged");
    assert_eq!(a.pool.loads, b.pool.loads);
    assert_eq!(a.pool.queue_hwm, b.pool.queue_hwm);
    for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(ta.workload, tb.workload);
        assert_eq!(
            fingerprint(&ta.metrics),
            fingerprint(&tb.metrics),
            "tenant {} diverged across pool runs",
            ta.workload
        );
    }
    // And the pool genuinely interleaved: every tenant transited the
    // switch.
    assert!(a.tenants.iter().all(|t| t.metrics.ingress_hwm >= 1));
}

/// The shard identity at its degenerate point (DESIGN.md §17): a
/// `cxl-pool-shard` pool collapsed to one shard takes the serial
/// coordinator verbatim, and the config differs from `cxl-pool` only in
/// name — so the sharded entry point must reproduce `run_pool` over the
/// plain `cxl-pool` config bit-for-bit: tenants, pool sums, event count.
#[test]
fn one_shard_pool_shard_reproduces_cxl_pool_bit_identically() {
    use cxl_gpu::fabric::{run_pool, run_pool_sharded, Tenant};
    let tenants = |name: &str| -> Vec<Tenant> {
        [("bfs", 8usize, 4usize), ("vadd", 16, 2), ("sort", 4, 8)]
            .iter()
            .map(|&(wl, warps, mlp)| {
                let mut cfg = SystemConfig::named(name, MediaKind::Ddr5);
                cfg.total_ops = 6_000;
                cfg.warps = warps;
                cfg.mlp = mlp;
                cfg.footprint = 4 << 20;
                cfg.local_bytes = 256 << 10;
                Tenant { workload: spec(wl), cfg }
            })
            .collect()
    };
    let serial = run_pool(&tenants("cxl-pool")).expect("serial pool");
    let sharded = run_pool_sharded(&tenants("cxl-pool-shard"), 1, None).expect("sharded pool");
    assert_eq!(serial.events, sharded.events, "merged event count diverged");
    assert_eq!(format!("{:?}", serial.pool), format!("{:?}", sharded.pool));
    for (ta, tb) in serial.tenants.iter().zip(&sharded.tenants) {
        assert_eq!(
            fingerprint(&ta.metrics),
            fingerprint(&tb.metrics),
            "tenant {} diverged between cxl-pool and 1-shard cxl-pool-shard",
            ta.workload
        );
    }
    assert!(serial.tenants.iter().all(|t| t.metrics.expander_loads > 0));
}

/// Worker-count independence: repeated sharded runs must be
/// bit-identical to each other at 1 worker thread and at 4 — the thread
/// count is pure wall-clock, never semantics. The explicit `Some(n)`
/// pins the knob that `CXL_GPU_THREADS` feeds through `thread_count()`
/// when callers pass `None` (mutating the env var in-process would race
/// other tests, so the override path is exercised by value here).
#[test]
fn sharded_pool_runs_are_bit_reproducible_across_thread_counts() {
    use cxl_gpu::fabric::{run_pool_sharded, Tenant};
    let tenants = || -> Vec<Tenant> {
        [("path", 4usize, 2usize), ("sort", 16, 8), ("bfs", 8, 4), ("vadd", 8, 2)]
            .iter()
            .map(|&(wl, warps, mlp)| {
                let mut cfg = SystemConfig::named("cxl-pool-shard", MediaKind::Ddr5);
                cfg.total_ops = 6_000;
                cfg.warps = warps;
                cfg.mlp = mlp;
                cfg.footprint = 4 << 20;
                cfg.local_bytes = 256 << 10;
                Tenant { workload: spec(wl), cfg }
            })
            .collect()
    };
    let runs: Vec<_> = [1usize, 1, 4, 4]
        .iter()
        .map(|&threads| run_pool_sharded(&tenants(), 4, Some(threads)).expect("sharded pool"))
        .collect();
    let first = &runs[0];
    assert!(first.tenants.iter().all(|t| t.metrics.expander_loads > 0));
    for (i, r) in runs.iter().enumerate().skip(1) {
        assert_eq!(first.events, r.events, "run {i}: merged event count diverged");
        assert_eq!(format!("{:?}", first.pool), format!("{:?}", r.pool), "run {i}");
        for (ta, tb) in first.tenants.iter().zip(&r.tenants) {
            assert_eq!(
                fingerprint(&ta.metrics),
                fingerprint(&tb.metrics),
                "run {i}: tenant {} diverged across thread counts",
                ta.workload
            );
        }
    }
}

#[test]
fn suite_is_deterministic_and_table_ordered() {
    let a = run_suite("cxl", MediaKind::Ddr5, Some(3_000));
    let b = run_suite("cxl", MediaKind::Ddr5, Some(3_000));
    assert_eq!(a.len(), ALL_WORKLOADS.len());
    for ((ra, rb), w) in a.iter().zip(&b).zip(ALL_WORKLOADS) {
        assert_eq!(ra.workload, w.name, "suite order must match Table 1b");
        assert_eq!(
            fingerprint(&ra.metrics),
            fingerprint(&rb.metrics),
            "{} diverged across suite runs",
            w.name
        );
    }
}

/// The tracing-inertness identity (DESIGN.md §18): arming the span
/// tracer changes *no bit* of any fingerprinted metric. Tracing draws no
/// RNG and adds no latency — it only reads timestamps the run already
/// produced — so an armed run at full sampling must be fingerprint-
/// identical to the disabled run on every config family it instruments:
/// direct, cached, pooled+QoS, fault-injected, served. The observability
/// report itself is fingerprint-exempt (it measures; it must not
/// perturb).
#[test]
fn armed_tracing_is_fingerprint_identical_to_disabled() {
    for (name, media, wl) in [
        ("cxl", MediaKind::Ddr5, "gnn"),
        ("cxl-cache", MediaKind::Znand, "hot75"),
        ("cxl-pool-qos", MediaKind::Znand, "bfs"),
        ("cxl-ras", MediaKind::Znand, "bfs"),
        ("cxl-serve", MediaKind::Ddr5, "vadd"),
    ] {
        let off = System::new(spec(wl), &small(name, media)).run();
        let mut cfg = small(name, media);
        cfg.obs.enabled = true;
        cfg.obs.sample_shift = 0; // trace every sampled-kind op
        let on = System::new(spec(wl), &cfg).run();
        assert_eq!(
            fingerprint(&off),
            fingerprint(&on),
            "{name}/{wl} on {media:?}: armed tracing perturbed the run"
        );
        assert!(off.obs.is_none(), "disabled run must carry no obs report");
        let rep = on.obs.as_ref().expect("armed run must carry an obs report");
        assert!(rep.spans > 0, "{name}/{wl}: armed tracing saw no spans");
        assert_eq!(rep.violations, 0, "{name}/{wl}: ledger conservation violated");
    }
}

/// The telemetry-inertness identity (DESIGN.md §19): arming the flight
/// recorder changes *no bit* of any fingerprinted metric at any
/// cadence. The recorder draws no RNG and adds no latency — its tick
/// events only read state, and `harvest` subtracts them from the
/// fingerprinted event count — so an armed run must be fingerprint-
/// identical to the disabled run on every config family it samples.
/// The telemetry report itself is fingerprint-exempt.
#[test]
fn armed_telemetry_is_fingerprint_identical_to_disabled() {
    for (name, media, wl) in [
        ("cxl", MediaKind::Ddr5, "gnn"),
        ("cxl-cache", MediaKind::Znand, "hot75"),
        ("cxl-pool-qos", MediaKind::Znand, "bfs"),
        ("cxl-ras", MediaKind::Znand, "bfs"),
        ("cxl-serve", MediaKind::Ddr5, "vadd"),
    ] {
        let off = System::new(spec(wl), &small(name, media)).run();
        for epoch in [5 * cxl_gpu::sim::US, 50 * cxl_gpu::sim::US, cxl_gpu::sim::MS] {
            let mut cfg = small(name, media);
            cfg.telemetry.enabled = true;
            cfg.telemetry.epoch = epoch;
            let on = System::new(spec(wl), &cfg).run();
            assert_eq!(
                fingerprint(&off),
                fingerprint(&on),
                "{name}/{wl} on {media:?}: telemetry at {epoch} ps perturbed the run"
            );
            assert!(off.telemetry.is_none(), "disabled run must carry no report");
            let rep = on.telemetry.as_ref().expect("armed run must carry a report");
            assert!(!rep.frames.is_empty(), "{name}/{wl}: armed recorder saw no frames");
        }
    }
}

/// Armed telemetry itself replays bit-for-bit: same frames (every gauge,
/// delta and f64 latency accumulator compared through `Frame`'s
/// `PartialEq`), same alerts, across repeated runs — the report is
/// fingerprint-exempt, so it gets its own reproducibility check.
#[test]
fn armed_telemetry_reports_replay_bit_for_bit() {
    let mut cfg = small("cxl-ras", MediaKind::Znand);
    cfg.ras.crc_error_rate = 1e-3;
    cfg.telemetry.enabled = true;
    cfg.telemetry.epoch = 10 * cxl_gpu::sim::US;
    let a = System::new(spec("bfs"), &cfg).run();
    let b = System::new(spec("bfs"), &cfg).run();
    assert_eq!(fingerprint(&a), fingerprint(&b), "armed cxl-ras run diverged");
    let (ra, rb) = (a.telemetry.as_ref().unwrap(), b.telemetry.as_ref().unwrap());
    assert_eq!(ra.ticks, rb.ticks);
    assert_eq!(ra.frames, rb.frames, "frame streams diverged");
    assert_eq!(ra.alerts.len(), rb.alerts.len());
    for (aa, ab) in ra.alerts.iter().zip(&rb.alerts) {
        assert_eq!((aa.at, aa.frame, aa.kind), (ab.at, ab.frame, ab.kind));
    }
}

/// Sharded-pool telemetry equivalence: the deferred fabric half of each
/// frame replays at the same global (time, tenant) slot the serial
/// interleave samples at, so every tenant's frame stream — gauges,
/// deltas, f64 latency sums — must be bit-identical between the serial
/// pool and the sharded runner at any thread count.
#[test]
fn sharded_pool_telemetry_frames_match_serial_bit_for_bit() {
    use cxl_gpu::fabric::{run_pool, run_pool_sharded, Tenant};
    let tenants = |name: &str| -> Vec<Tenant> {
        [("bfs", 8usize, 4usize), ("vadd", 16, 2), ("sort", 4, 8)]
            .iter()
            .map(|&(wl, warps, mlp)| {
                let mut cfg = SystemConfig::named(name, MediaKind::Ddr5);
                cfg.total_ops = 6_000;
                cfg.warps = warps;
                cfg.mlp = mlp;
                cfg.footprint = 4 << 20;
                cfg.local_bytes = 256 << 10;
                cfg.telemetry.enabled = true;
                cfg.telemetry.epoch = 10 * cxl_gpu::sim::US;
                Tenant { workload: spec(wl), cfg }
            })
            .collect()
    };
    let serial = run_pool(&tenants("cxl-pool")).expect("serial pool");
    let sharded =
        run_pool_sharded(&tenants("cxl-pool-shard"), 4, Some(4)).expect("sharded pool");
    for (ta, tb) in serial.tenants.iter().zip(&sharded.tenants) {
        assert_eq!(
            fingerprint(&ta.metrics),
            fingerprint(&tb.metrics),
            "tenant {} metrics diverged",
            ta.workload
        );
        let (ra, rb) = (
            ta.metrics.telemetry.as_ref().expect("serial tenant report"),
            tb.metrics.telemetry.as_ref().expect("sharded tenant report"),
        );
        assert!(!ra.frames.is_empty(), "tenant {} recorded no frames", ta.workload);
        assert_eq!(
            ra.frames, rb.frames,
            "tenant {} frame streams diverged between serial and sharded",
            ta.workload
        );
    }
}

/// Armed tracing itself replays bit-for-bit: same spans, same stage
/// sums, same ring contents across repeated runs (the report is exempt
/// from the fingerprint, so it gets its own reproducibility check).
#[test]
fn armed_tracing_reports_replay_bit_for_bit() {
    let mut cfg = small("cxl-ras", MediaKind::Znand);
    cfg.ras.crc_error_rate = 1e-3;
    cfg.obs.enabled = true;
    cfg.obs.sample_shift = 2;
    let a = System::new(spec("bfs"), &cfg).run();
    let b = System::new(spec("bfs"), &cfg).run();
    assert_eq!(fingerprint(&a), fingerprint(&b), "armed cxl-ras run diverged");
    let (ra, rb) = (a.obs.as_ref().unwrap(), b.obs.as_ref().unwrap());
    assert_eq!(ra.spans, rb.spans);
    assert_eq!(ra.ops_seen, rb.ops_seen);
    assert_eq!(ra.violations, 0);
    assert_eq!(ra.ring.len(), rb.ring.len());
    for (sa, sb) in ra.ring.iter().zip(&rb.ring) {
        assert_eq!((sa.id, sa.kind, sa.start, sa.end), (sb.id, sb.kind, sb.start, sb.end));
        assert_eq!(sa.stages, sb.stages);
    }
}
