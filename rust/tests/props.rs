//! Property-based tests over coordinator invariants, using the in-tree
//! prop-test runner (`cxl_gpu::util::prop`).

use std::collections::{BTreeMap, VecDeque};

use cxl_gpu::cxl::DevLoad;
use cxl_gpu::gpu::{AccessResult, Llc, LlcConfig, LINE};
use cxl_gpu::rootcomplex::det_store::DetStoreEngine;
use cxl_gpu::rootcomplex::hdm::{HdmDecoder, HdmEntry};
use cxl_gpu::rootcomplex::rbtree::RbTree;
use cxl_gpu::rootcomplex::spec_read::{SpecReadEngine, SrPolicy};
use cxl_gpu::sim::{EventQueue, NS};
use cxl_gpu::util::prop::check;
use cxl_gpu::workloads::{collect_trace, OpStream, TraceParams, ALL_WORKLOADS};

#[test]
fn prop_event_queue_pops_in_nondecreasing_time() {
    check("event-queue-order", 0xE1, 100, |g| {
        let mut q = EventQueue::new();
        let n = g.usize("events", 1, 200);
        for i in 0..n {
            q.push_at(g.u64(&format!("t{i}"), 0, 10_000), i);
        }
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            if t < last {
                return Err(format!("time went backwards: {t} < {last}"));
            }
            last = t;
        }
        Ok(())
    });
}

/// The bucketed calendar queue must be observationally identical to a
/// plain (time, seq)-keyed min-heap: same pop order under random
/// interleaved push/pop (ties broken by insertion sequence), same `now`,
/// same pushed/popped counters. Delays are drawn from three regimes so
/// cases exercise the active bucket (0), the near-horizon ring, and the
/// overflow heap + migration (far future).
#[test]
fn prop_bucketed_queue_matches_reference_heap() {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    check("queue-vs-heap", 0xCA1E, 120, |g| {
        let mut q = EventQueue::new();
        let mut model: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut now = 0u64;
        let ops = g.usize("ops", 1, 300);
        for i in 0..ops {
            let push = g.bool(&format!("push{i}"), 0.6);
            if push || model.is_empty() {
                let regime = g.u64(&format!("regime{i}"), 0, 9);
                let delay = match regime {
                    0..=1 => 0,                                          // active bucket
                    2..=7 => g.u64(&format!("near{i}"), 1, 60_000),      // ring
                    _ => g.u64(&format!("far{i}"), 60_000, 300_000_000), // overflow
                };
                // A burst of same-time pushes stresses tie-breaking.
                let burst = g.usize(&format!("burst{i}"), 1, 3);
                for _ in 0..burst {
                    q.push_at(now + delay, seq);
                    model.push(Reverse((now + delay, seq)));
                    seq += 1;
                }
            } else {
                let got = q.pop();
                let want = model.pop().map(|Reverse((t, s))| (t, s));
                if got != want {
                    return Err(format!("pop diverged: got {got:?}, want {want:?}"));
                }
                if let Some((t, _)) = got {
                    now = t;
                }
                if q.now() != now {
                    return Err(format!("now diverged: {} vs {}", q.now(), now));
                }
            }
            if q.len() != model.len() {
                return Err(format!("len diverged: {} vs {}", q.len(), model.len()));
            }
        }
        // Drain: the tail order must match exactly too.
        while let Some(Reverse((t, s))) = model.pop() {
            let got = q.pop();
            if got != Some((t, s)) {
                return Err(format!("drain diverged: got {got:?}, want ({t}, {s})"));
            }
        }
        if q.pop().is_some() {
            return Err("queue held events the reference did not".into());
        }
        if q.pushed() != seq || q.popped() != seq {
            return Err(format!(
                "counters diverged: pushed {} popped {} expected {seq}",
                q.pushed(),
                q.popped()
            ));
        }
        Ok(())
    });
}

/// The streaming trace generator must be *bit-identical* to the eager
/// reference (`collect_trace` keeps the original generator loop as the
/// executable spec): every workload in Table 1b, random seeds, warp
/// counts, footprints and op budgets. This is the equivalence contract
/// that lets `System` stream traces while the tests and table analyses
/// keep materializing them (DESIGN.md §11).
#[test]
fn prop_stream_matches_materialized_trace() {
    check("stream-vs-materialized", 0x57EA, 24, |g| {
        let p = TraceParams {
            footprint: (g.u64("footprint_mb", 2, 16) << 20),
            warps: g.usize("warps", 1, 32),
            total_ops: g.usize("ops", 100, 12_000),
            seed: g.u64("seed", 0, u64::MAX / 2),
            ..Default::default()
        };
        for spec in ALL_WORKLOADS {
            let reference = collect_trace(spec, &p);
            for (w, row) in reference.iter().enumerate() {
                let mut stream = OpStream::new(spec, &p, w);
                for (i, op) in row.iter().enumerate() {
                    match stream.next() {
                        Some(got) if got == *op => {}
                        other => {
                            return Err(format!(
                                "{} warp {w} op {i}: stream {other:?} != trace {op:?}",
                                spec.name
                            ))
                        }
                    }
                }
                if let Some(extra) = stream.next() {
                    return Err(format!(
                        "{} warp {w}: stream yields {extra:?} past the trace end",
                        spec.name
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hdm_decode_is_total_and_consistent_over_programmed_space() {
    check("hdm-total", 0xD0, 100, |g| {
        let mut d = HdmDecoder::new();
        let ports = g.usize("ports", 1, 8);
        let size = g.u64("win", 1, 64) * 4096;
        for p in 0..ports {
            d.program(HdmEntry::direct(p, p as u64 * size, size))
                .map_err(|e| e.to_string())?;
        }
        let total = ports as u64 * size;
        for i in 0..32 {
            let hpa = g.u64(&format!("hpa{i}"), 0, total - 1);
            let (port, off) = d.decode(hpa).ok_or("decode hole inside programmed space")?;
            if port as u64 != hpa / size {
                return Err(format!("wrong port for {hpa:#x}"));
            }
            if off != hpa % size {
                return Err(format!("wrong offset for {hpa:#x}"));
            }
        }
        if d.decode(total).is_some() {
            return Err("decoded past the programmed space".into());
        }
        Ok(())
    });
}

/// Decode edges: for any pair of adjacent windows plus a detached one,
/// the boundary addresses land in the right window, the first address
/// past a window's end either misses or belongs to its neighbour, and
/// everything outside all windows misses.
#[test]
fn prop_hdm_decode_edges_are_exact() {
    check("hdm-edges", 0xED6E, 100, |g| {
        let mut d = HdmDecoder::new();
        let a_size = g.u64("a", 1, 64) * 4096;
        let b_size = g.u64("b", 1, 64) * 4096;
        let gap = g.u64("gap", 1, 16) * 4096;
        // [0, a) and [a, a+b) adjacent; [a+b+gap, ...) detached.
        d.program(HdmEntry::direct(0, 0, a_size)).map_err(|e| e.to_string())?;
        d.program(HdmEntry::direct(1, a_size, b_size)).map_err(|e| e.to_string())?;
        let c_base = a_size + b_size + gap;
        let c_size = g.u64("c", 1, 16) * 4096;
        d.program(HdmEntry::direct(2, c_base, c_size)).map_err(|e| e.to_string())?;
        let cases = [
            (a_size - 1, Some((0, a_size - 1))),    // last byte of A
            (a_size, Some((1, 0))),                 // first byte of B
            (a_size + b_size - 1, Some((1, b_size - 1))),
            (a_size + b_size, None),                // gap starts
            (c_base - 1, None),                     // last gap byte
            (c_base, Some((2, 0))),
            (c_base + c_size - 1, Some((2, c_size - 1))),
            (c_base + c_size, None),                // past everything
        ];
        for (hpa, want) in cases {
            let got = d.decode(hpa);
            if got != want {
                return Err(format!("decode({hpa:#x}) = {got:?}, want {want:?}"));
            }
        }
        Ok(())
    });
}

/// Interleaved decode round-trip: decode is stable, covers the window
/// totally, balances granules exactly across the ways, and inverts
/// through `hpa_of`.
#[test]
fn prop_hdm_interleaved_decode_round_trips_and_balances() {
    check("hdm-interleave", 0x11EA, 100, |g| {
        let ways = *g.choose("ways", &[2usize, 4, 8]);
        let gran_bits = g.u64("gran", 6, 13) as u32;
        let gran = 1u64 << gran_bits;
        let stripes = g.u64("stripes", 1, 32);
        let base = g.u64("base", 0, 1 << 30) & !(gran - 1);
        let size = stripes * ways as u64 * gran;
        // Distinct, not-necessarily-contiguous target ports.
        let first = g.usize("port0", 0, 4);
        let step = g.usize("step", 1, 3);
        let ports: Vec<usize> = (0..ways).map(|k| first + k * step).collect();
        let e = HdmEntry::interleaved(&ports, base, size, gran_bits);
        let mut d = HdmDecoder::new();
        d.program(e).map_err(|err| err.to_string())?;

        // Balance: one full sweep at granule steps hits each way exactly
        // `stripes` times.
        let mut per_way = vec![0u64; ways];
        for gidx in 0..(size / gran) {
            let hpa = base + gidx * gran;
            let (port, _) = d.decode(hpa).ok_or("decode hole inside the window")?;
            let way = ports.iter().position(|&p| p == port).ok_or("unknown port")?;
            per_way[way] += 1;
        }
        if per_way.iter().any(|&c| c != stripes) {
            return Err(format!("unbalanced stripe: {per_way:?}, want {stripes} each"));
        }

        for i in 0..24 {
            let hpa = base + g.u64(&format!("hpa{i}"), 0, size - 1);
            let (port, dpa) = d.decode(hpa).ok_or("decode hole inside the window")?;
            // Stability: the same HPA decodes identically.
            if d.decode(hpa) != Some((port, dpa)) {
                return Err(format!("decode({hpa:#x}) is not stable"));
            }
            // Each way owns size/ways bytes.
            if dpa >= e.per_way() {
                return Err(format!("dpa {dpa:#x} beyond the per-way capacity"));
            }
            // Round trip through the inverse.
            let way = ports.iter().position(|&p| p == port).unwrap();
            if e.hpa_of(way, dpa) != hpa {
                return Err(format!(
                    "hpa_of(way {way}, {dpa:#x}) != {hpa:#x}",
                ));
            }
        }
        if d.decode(base + size).is_some() {
            return Err("decoded past the interleaved window".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rbtree_matches_btreemap() {
    check("rbtree-model", 0xB3, 60, |g| {
        let mut t: RbTree<u64> = RbTree::new();
        let mut model = BTreeMap::new();
        let ops = g.usize("ops", 1, 300);
        for i in 0..ops {
            let key = g.u64(&format!("k{i}"), 0, 64);
            if g.bool(&format!("ins{i}"), 0.6) {
                let prev_t = t.insert(key, i as u64);
                let prev_m = model.insert(key, i as u64);
                if prev_t != prev_m {
                    return Err(format!("insert mismatch at {key}"));
                }
            } else if t.remove(key) != model.remove(&key) {
                return Err(format!("remove mismatch at {key}"));
            }
        }
        t.check_invariants().map_err(|e| e)?;
        let keys: Vec<u64> = model.keys().copied().collect();
        if t.keys() != keys {
            return Err("in-order keys diverge from model".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ds_never_loses_or_duplicates_stores() {
    check("ds-conservation", 0xD5, 60, |g| {
        let mut ds = DetStoreEngine::new(true, 1 << 20);
        let mut live = std::collections::HashSet::new();
        let ops = g.usize("ops", 1, 200);
        for i in 0..ops {
            let addr = g.u64(&format!("a{i}"), 0, 2_000) * LINE;
            let dl = *g.choose(
                &format!("dl{i}"),
                &[DevLoad::Light, DevLoad::Optimal, DevLoad::Moderate, DevLoad::Severe],
            );
            match ds.on_store(0, addr, 64, dl) {
                cxl_gpu::rootcomplex::StoreAction::Buffer => {
                    live.insert(addr);
                }
                _ => {}
            }
            if g.bool(&format!("flush{i}"), 0.3) {
                let mut batch = Vec::new();
                ds.flush_batch_into(4, &mut batch);
                for &(line, _) in &batch {
                    ds.flush_done(line);
                    live.remove(&line);
                }
            }
            ds.check_invariants()?;
        }
        // Everything still live must intercept; everything flushed must not.
        for &addr in &live {
            if !ds.intercept_read(addr) {
                return Err(format!("lost buffered store at {addr:#x}"));
            }
        }
        if ds.buffered_entries() != live.len() {
            return Err(format!(
                "entry count {} != live {}",
                ds.buffered_entries(),
                live.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_sr_windows_are_aligned_and_bounded() {
    check("sr-window-bounds", 0x5A, 80, |g| {
        let mut e = SpecReadEngine::new(SrPolicy::Window);
        for _ in 0..g.usize("warmup", 0, 6) {
            e.observe_devload(DevLoad::Light);
        }
        let mut queue = VecDeque::new();
        let qlen = g.usize("qlen", 0, 32);
        for i in 0..qlen {
            queue.push_back(g.u64(&format!("q{i}"), 0, 1 << 24));
        }
        for i in 0..16 {
            let addr = g.u64(&format!("addr{i}"), 0, 1 << 24);
            if let Some(f) = e.on_load(0, addr, &queue, i) {
                if f.addr % 256 != 0 {
                    return Err(format!("window start {:#x} not 256B aligned", f.addr));
                }
                if !(64..=1024).contains(&f.len) {
                    return Err(format!("window len {} out of range", f.len));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_llc_hit_after_fill_and_capacity_bounded() {
    check("llc-fill-hit", 0x77C, 60, |g| {
        let mut llc = Llc::new(LlcConfig {
            capacity: 64 * LINE * 4,
            ways: 4,
            hit_lat: 5 * NS,
            mshrs: 8,
        });
        let ops = g.usize("ops", 1, 200);
        let mut now = 0;
        for i in 0..ops {
            let addr = g.u64(&format!("a{i}"), 0, 512) * LINE;
            let is_write = g.bool(&format!("w{i}"), 0.3);
            now += 10 * NS;
            match llc.access(now, addr, is_write, 1) {
                AccessResult::Miss { .. } if !is_write => {
                    llc.fill(addr, now);
                    // Immediately after the fill, the line must hit.
                    match llc.access(now + NS, addr, false, 2) {
                        AccessResult::Hit { .. } => {}
                        r => return Err(format!("no hit after fill: {r:?}")),
                    }
                }
                _ => {}
            }
            if llc.resident_lines() > 256 {
                return Err("LLC exceeded its capacity".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulation_is_deterministic_across_runs() {
    use cxl_gpu::coordinator::config::SystemConfig;
    use cxl_gpu::coordinator::system::System;
    use cxl_gpu::media::MediaKind;
    use cxl_gpu::workloads::table1b::ALL_WORKLOADS;
    check("sim-determinism", 0xDE7, 6, |g| {
        let wl = g.choose("workload", &["vadd", "bfs", "sort", "gnn"]);
        let spec = ALL_WORKLOADS.iter().find(|w| w.name == *wl).unwrap();
        let cfg_name = g.choose("config", &["cxl", "cxl-sr", "cxl-ds"]);
        let mut cfg = SystemConfig::named(cfg_name, MediaKind::Znand);
        cfg.total_ops = 6_000;
        cfg.ssd_scale();
        cfg.seed = g.u64("seed", 0, 1 << 30);
        let a = System::new(spec, &cfg).run();
        let b = System::new(spec, &cfg).run();
        if a.exec_time != b.exec_time || a.events != b.events {
            return Err(format!(
                "nondeterminism: {} vs {} exec, {} vs {} events",
                a.exec_time, b.exec_time, a.events, b.events
            ));
        }
        Ok(())
    });
}

/// DevLoad telemetry (satellite of the fabric PR): the 2-bit wire
/// encoding must round-trip over every variant (junk high bits
/// ignored), and `classify` must be monotone in occupancy — a higher
/// ingress occupancy never reports a *lighter* load class, with or
/// without the internal-task announcement.
#[test]
fn prop_devload_roundtrip_and_classify_monotonic() {
    check("devload", 0xDE7710AD, 150, |g| {
        for d in [DevLoad::Light, DevLoad::Optimal, DevLoad::Moderate, DevLoad::Severe] {
            if DevLoad::decode(d.encode()) != d {
                return Err(format!("{d:?} does not round-trip"));
            }
            let junk = (g.u64("junk", 0, 63) as u8) << 2;
            if DevLoad::decode(d.encode() | junk) != d {
                return Err(format!("{d:?} decode must mask to 2 bits"));
            }
        }
        let cap = g.usize("cap", 1, 256);
        let task = g.bool("task", 0.3);
        let mut prev = DevLoad::Light;
        for occ in 0..=cap {
            let d = DevLoad::classify(occ, cap, task);
            if d < prev {
                return Err(format!(
                    "classify regressed at occ {occ}/{cap} (task={task}): {d:?} < {prev:?}"
                ));
            }
            prev = d;
        }
        if task && DevLoad::classify(0, cap, true) != DevLoad::Severe {
            return Err("internal task must pre-announce as Severe".into());
        }
        Ok(())
    });
}

/// The fabric QoS token bucket must (a) hand out monotone ready times
/// for monotone arrivals and (b) never admit more than burst + rate x
/// elapsed bytes — the pacing contract the victim-protection bound
/// rests on. Fixed rate (min = max) so AIMD stays out of the picture.
#[test]
fn prop_token_bucket_never_exceeds_its_rate() {
    use cxl_gpu::fabric::TokenBucket;
    check("token-bucket-pace", 0x70CE2, 120, |g| {
        let rate = g.u64("rate_bps", 1 << 20, 1 << 38);
        let burst = g.u64("burst", 64, 1 << 20);
        let mut tb = TokenBucket::new(rate, rate, rate, burst);
        let mut now = 0u64;
        let mut last_ready = 0u64;
        let mut admitted: u128 = 0;
        let ops = g.usize("ops", 1, 200);
        for i in 0..ops {
            now += g.u64(&format!("dt{i}"), 0, 10_000_000); // up to 10 µs apart
            let len = g.u64(&format!("len{i}"), 1, 4096);
            let ready = tb.ready_at(now, len);
            if ready < now {
                return Err(format!("ready {ready} before arrival {now}"));
            }
            if ready < last_ready {
                return Err(format!("ready times regressed: {ready} < {last_ready}"));
            }
            last_ready = ready;
            admitted += len as u128;
            // Everything admitted by `ready` fits in burst + rate x t
            // (+1 byte/op rounding slack).
            let bound = burst.max(64) as u128
                + (rate as u128 * ready as u128) / 1_000_000_000_000
                + (i as u128 + 1);
            if admitted > bound {
                return Err(format!(
                    "admitted {admitted} B > bound {bound} B at t={ready} (rate {rate}, burst {burst})"
                ));
            }
        }
        Ok(())
    });
}

/// Expander device cache (DESIGN.md §14), invariant sweep under random
/// read/write/drain/invalidate interleavings:
/// * exactly one of hits/misses increments per demand lookup,
/// * writeback byte conservation (`writeback_bytes == writebacks x
///   line_bytes`, and every enqueued writeback is either drained or
///   still pending),
/// * dirty-line conservation: every clean→dirty transition is matched
///   by a queued writeback, an invalidation drop, or a still-resident
///   dirty line.
#[test]
fn prop_device_cache_accounting_and_conservation() {
    use cxl_gpu::expander::{CacheSpec, DeviceCache, Lookup};
    check("device-cache-conservation", 0xCAC4E, 120, |g| {
        let ways = *g.choose("ways", &[1usize, 2, 4, 8]);
        let cap_kib = *g.choose("cap", &[1u64, 2, 4, 8]);
        let mut spec = CacheSpec {
            enabled: true,
            capacity_bytes: cap_kib << 10,
            ways,
            ..CacheSpec::default()
        };
        if g.bool("admit-all", 0.5) {
            spec = spec.admit_all();
        }
        let Some(mut c) = DeviceCache::new(spec) else {
            return Err("nonzero capacity must build a cache".into());
        };
        let ops = g.usize("ops", 1, 400);
        let mut lookups = 0u64;
        let mut drained = 0u64;
        for i in 0..ops {
            let addr = g.u64(&format!("a{i}"), 0, 1 << 16) & !63;
            match g.u64(&format!("op{i}"), 0, 9) {
                0..=4 => {
                    lookups += 1;
                    if c.lookup(i as u64, addr, 64, false) == Lookup::Miss
                        && c.should_admit(addr, i as u64)
                    {
                        let (base, span) = c.span(addr, 64);
                        c.install(base, span, i as u64, false);
                    }
                }
                5..=7 => {
                    // Store: writeback-on-hit, no-allocate on miss.
                    lookups += 1;
                    let _ = c.lookup(i as u64, addr, 64, true);
                }
                8 => {
                    if c.pop_writeback().is_some() {
                        drained += 1;
                    }
                }
                _ => c.invalidate_span(addr, g.u64(&format!("inv{i}"), 64, 4096)),
            }
        }
        let s = c.stats;
        if s.hits + s.misses != lookups {
            return Err(format!(
                "hits {} + misses {} != lookups {lookups}",
                s.hits, s.misses
            ));
        }
        if s.writeback_bytes != s.writebacks * c.line_bytes() {
            return Err(format!(
                "writeback bytes {} != {} writebacks x {} B lines",
                s.writeback_bytes,
                s.writebacks,
                c.line_bytes()
            ));
        }
        if drained + c.wb_pending() as u64 + s.wb_cancelled != s.writebacks {
            return Err(format!(
                "writeback flow broken: drained {drained} + pending {} + cancelled {} != queued {}",
                c.wb_pending(),
                s.wb_cancelled,
                s.writebacks
            ));
        }
        if s.dirtied != s.writebacks + s.dirty_dropped + c.dirty_lines() {
            return Err(format!(
                "dirty conservation: dirtied {} != wb {} + dropped {} + resident {}",
                s.dirtied,
                s.writebacks,
                s.dirty_dropped,
                c.dirty_lines()
            ));
        }
        Ok(())
    });
}

/// RAS link layer (DESIGN.md §15): the go-back replay buffer must
/// deliver every sent transfer *exactly once, in send order* under an
/// arbitrary interleaving of sends and corrupted/clean attempts — each
/// sequence number retires once (as a delivery or a poison, never both),
/// completions pop in strictly consecutive order, and flit conservation
/// `sent == delivered + poisoned + in_flight` holds after every step.
#[test]
fn prop_replay_buffer_exactly_once_in_order_under_arbitrary_loss() {
    use cxl_gpu::cxl::{Attempt, ReplayBuffer};
    check("replay-exactly-once", 0x4EA7, 150, |g| {
        let max_retries = g.u64("retries", 0, 5) as u32;
        let mut b = ReplayBuffer::new(max_retries);
        let mut next_complete = 0u64;
        let mut sent_flits = 0u64;
        let ops = g.usize("ops", 1, 300);
        for i in 0..ops {
            if g.bool(&format!("send{i}"), 0.5) || b.pending_transfers() == 0 {
                let flits = g.u64(&format!("f{i}"), 1, 9);
                b.send(flits);
                sent_flits += flits;
            } else {
                let corrupted = g.bool(&format!("crc{i}"), 0.4);
                match b.attempt(corrupted) {
                    Attempt::Delivered { seq, .. } | Attempt::Poisoned { seq, .. } => {
                        if seq != next_complete {
                            return Err(format!(
                                "completion out of order: seq {seq}, want {next_complete}"
                            ));
                        }
                        next_complete += 1;
                    }
                    Attempt::Retried { seq } => {
                        if seq != next_complete {
                            return Err(format!("retried a non-head transfer: {seq}"));
                        }
                    }
                    Attempt::Idle => return Err("Idle with transfers pending".into()),
                }
            }
            let s = b.stats;
            if s.sent != s.delivered + s.poisoned + b.in_flight() {
                return Err(format!(
                    "conservation broke at op {i}: sent {} != delivered {} + poisoned {} + in-flight {}",
                    s.sent, s.delivered, s.poisoned, b.in_flight()
                ));
            }
        }
        // Drain with clean passes: everything left delivers, in order.
        while b.pending_transfers() > 0 {
            match b.attempt(false) {
                Attempt::Delivered { seq, .. } => {
                    if seq != next_complete {
                        return Err(format!("drain out of order: {seq} != {next_complete}"));
                    }
                    next_complete += 1;
                }
                other => return Err(format!("clean drain must deliver, got {other:?}")),
            }
        }
        let s = b.stats;
        if s.sent != sent_flits || s.sent != s.delivered + s.poisoned || b.in_flight() != 0 {
            return Err(format!(
                "final conservation: sent {} delivered {} poisoned {} in-flight {}",
                s.sent, s.delivered, s.poisoned, b.in_flight()
            ));
        }
        Ok(())
    });
}

/// RAS fault injection: for any CRC rate, every [`RasState::link_transfer`]
/// retires its transfer before returning (nothing in flight), flit
/// accounting conserves (`sent == delivered + poisoned`), the charged
/// extra is exactly `retry-legs x leg` on a delivery and bounded by the
/// retry budget always, and the whole sequence replays bit-for-bit under
/// the same seed.
#[test]
fn prop_link_transfer_conserves_flits_and_replays_deterministically() {
    use cxl_gpu::ras::{FaultSpec, RasState};
    use cxl_gpu::sim::NS;
    check("link-transfer-conservation", 0x11FA, 100, |g| {
        let rate = *g.choose("rate", &[0.0f64, 1e-4, 0.05, 0.3, 0.9]);
        let max_retries = g.u64("retries", 0, 4) as u32;
        let seed = g.u64("seed", 0, 1 << 40);
        let spec = FaultSpec {
            enabled: true,
            crc_error_rate: rate.max(1e-12), // keep the spec non-inert
            max_retries,
            ..FaultSpec::default()
        };
        let leg = 10 * NS;
        let run = |n: usize| -> Result<(Vec<u64>, u64, u64), String> {
            let mut r =
                RasState::new(spec, seed, 0).ok_or_else(|| "armed spec must build".to_string())?;
            let mut extras = Vec::new();
            let mut total_flits = 0u64;
            for i in 0..n {
                let flits = 1 + (i as u64 % 8);
                total_flits += flits;
                let out = r.link_transfer(i as u64 * NS, flits, leg);
                if out.extra > max_retries as u64 * leg {
                    return Err(format!(
                        "extra {} exceeds the retry budget {} x {leg}",
                        out.extra, max_retries
                    ));
                }
                if !out.poisoned && out.extra % leg != 0 {
                    return Err(format!("delivery extra {} is not whole legs", out.extra));
                }
                if r.replay.in_flight() != 0 {
                    return Err("transfer returned with flits in flight".into());
                }
                extras.push(out.extra);
            }
            let s = r.replay.stats;
            if s.sent != total_flits || s.sent != s.delivered + s.poisoned {
                return Err(format!(
                    "flit conservation: sent {} (pushed {total_flits}) delivered {} poisoned {}",
                    s.sent, s.delivered, s.poisoned
                ));
            }
            if r.stats.poisons > 0 && max_retries > 0 && r.stats.retries == 0 {
                return Err("poisons without any retry under a nonzero budget".into());
            }
            Ok((extras, r.stats.retries, r.stats.poisons))
        };
        let n = g.usize("n", 1, 400);
        let (a, ra, pa) = run(n)?;
        let (b, rb, pb) = run(n)?;
        if a != b || ra != rb || pa != pb {
            return Err("fixed-seed fault sequence did not replay bit-for-bit".into());
        }
        Ok(())
    });
}

/// Graceful degradation (DESIGN.md §15): across a random load/store
/// history on a cached SSD port, a scheduled endpoint degradation must
/// rescue *every* dirty device-cache byte — the pre-latch drain leaves
/// zero dirty lines and an empty writeback queue, rescues exactly
/// `(queued + resident-dirty) x line_bytes` bytes, and the cache's dirty
/// conservation ledger (`dirtied == writebacks + dropped + resident`)
/// still balances afterwards.
#[test]
fn prop_dirty_bytes_conserved_across_forced_degradation() {
    use cxl_gpu::cxl::ControllerKind;
    use cxl_gpu::expander::CacheSpec;
    use cxl_gpu::media::{SsdModel, SsdParams};
    use cxl_gpu::ras::FaultSpec;
    use cxl_gpu::rootcomplex::{EpBackend, RootPort, SrPolicy};
    use cxl_gpu::util::prng::Pcg32;
    check("dirty-rescue-conservation", 0xD127, 60, |g| {
        // Degradation deadline far past any pre-phase timestamp.
        let degrade_at: u64 = 1 << 40;
        let ways = *g.choose("ways", &[1usize, 2, 4]);
        let spec = CacheSpec {
            enabled: true,
            capacity_bytes: *g.choose("cap", &[4u64, 8, 16]) << 10,
            ways,
            ..CacheSpec::default()
        }
        .admit_all();
        let fault = FaultSpec {
            enabled: true,
            degrade_at,
            degrade_port: 0,
            degrade_penalty: 1000,
            ..FaultSpec::default()
        };
        let mut p = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            SrPolicy::Off,
            false,
            0,
        )
        .with_cache(spec)
        .with_ras(fault, g.u64("seed", 0, 1 << 30));
        let mut rng = Pcg32::new(g.u64("rng", 0, 1 << 30), 77);
        let mut now = 0u64;
        let ops = g.usize("ops", 1, 200);
        for i in 0..ops {
            let addr = g.u64(&format!("a{i}"), 0, 127) * 64;
            if g.bool(&format!("st{i}"), 0.5) {
                now = p.store(now, addr, 64, &mut rng).ack;
            } else {
                now = p.load(now, addr, 64).done;
            }
            if now >= degrade_at {
                return Err("pre-phase ran past the degradation deadline".into());
            }
        }
        let line = {
            let c = p.cache.as_ref().ok_or_else(|| "cache must attach".to_string())?;
            c.line_bytes()
        };
        let (queued, resident) = {
            let c = p.cache.as_ref().unwrap();
            (c.wb_pending() as u64, c.dirty_lines())
        };
        // The first access past the deadline triggers rescue-then-latch.
        p.load(degrade_at, 1 << 20, 64);
        if !p.is_degraded() {
            return Err("the port must latch degraded past the deadline".into());
        }
        let r = p.ras.as_ref().unwrap();
        if r.stats.failovers != 1 {
            return Err(format!("one latch, one failover: {}", r.stats.failovers));
        }
        if r.stats.dirty_rescued_bytes != (queued + resident) * line {
            return Err(format!(
                "rescued {} B, want ({queued} queued + {resident} resident) x {line} B",
                r.stats.dirty_rescued_bytes
            ));
        }
        let c = p.cache.as_ref().unwrap();
        if c.dirty_lines() != 0 || c.wb_pending() != 0 {
            return Err(format!(
                "dirty state survived the rescue: {} lines, {} queued",
                c.dirty_lines(),
                c.wb_pending()
            ));
        }
        let s = c.stats;
        if s.dirtied != s.writebacks + s.dirty_dropped + c.dirty_lines() {
            return Err(format!(
                "dirty ledger broke: dirtied {} != wb {} + dropped {} + resident {}",
                s.dirtied,
                s.writebacks,
                s.dirty_dropped,
                c.dirty_lines()
            ));
        }
        Ok(())
    });
}

/// Device-cache victim selection must be true LRU: against a per-set
/// reference list (front = least recent), every eviction must name the
/// reference's front, refreshes must never evict, and sets only evict
/// when full.
#[test]
fn prop_device_cache_lru_victim_matches_reference() {
    use cxl_gpu::expander::{CacheSpec, DeviceCache, Lookup};
    check("device-cache-lru", 0x17CA, 100, |g| {
        let ways = *g.choose("ways", &[2usize, 4, 8]);
        // 8 sets of `ways` 256 B lines.
        let spec = CacheSpec {
            enabled: true,
            capacity_bytes: ways as u64 * 8 * 256,
            ways,
            ..CacheSpec::default()
        }
        .admit_all();
        let mut c = DeviceCache::new(spec).expect("nonzero capacity");
        let sets = (c.capacity_lines() as usize) / ways;
        if sets != 8 {
            return Err(format!("expected 8 sets, geometry gave {sets}"));
        }
        let mut shadow: Vec<Vec<u64>> = vec![Vec::new(); sets]; // front = LRU
        let ops = g.usize("ops", 1, 300);
        for i in 0..ops {
            let line = g.u64(&format!("l{i}"), 0, 64);
            let addr = line * 256;
            let set = (line as usize) % sets;
            if g.bool(&format!("rd{i}"), 0.5) {
                let hit = matches!(c.lookup(0, addr, 64, false), Lookup::Hit { .. });
                let sh = &mut shadow[set];
                let pos = sh.iter().position(|&l| l == line);
                if hit != pos.is_some() {
                    return Err(format!("residency diverged for line {line} at op {i}"));
                }
                if let Some(p) = pos {
                    let l = sh.remove(p);
                    sh.push(l); // hit refreshes recency
                }
            } else {
                let ev = c.install_line(addr, 0, false);
                let sh = &mut shadow[set];
                if let Some(p) = sh.iter().position(|&l| l == line) {
                    if ev.is_some() {
                        return Err(format!("refresh of line {line} evicted {ev:?}"));
                    }
                    let l = sh.remove(p);
                    sh.push(l);
                } else {
                    if sh.len() == ways {
                        let lru = sh.remove(0);
                        match ev {
                            Some(e) if e.addr == lru * 256 => {}
                            other => {
                                return Err(format!(
                                    "victim mismatch in set {set}: want line {lru}, got {other:?}"
                                ))
                            }
                        }
                    } else if let Some(e) = ev {
                        return Err(format!("eviction {e:?} from a non-full set"));
                    }
                    sh.push(line);
                }
            }
        }
        Ok(())
    });
}

/// Arrival generators (DESIGN.md §16) are pure functions of (kind, rate,
/// seed): two generators built alike must emit bit-identical gap
/// sequences under an identically-advancing clock, every gap at least
/// one tick, over all three processes and a wide rate range.
#[test]
fn prop_arrival_generators_replay_bit_for_bit() {
    use cxl_gpu::serve::{ArrivalGen, ArrivalKind};
    use cxl_gpu::sim::{MS, US};
    check("arrivals-replay", 0x5EAF, 80, |g| {
        let kind = match g.usize("kind", 0, 2) {
            0 => ArrivalKind::Poisson,
            1 => ArrivalKind::Mmpp {
                burst_mult: 1.0 + g.u64("burst", 1, 16) as f64,
                enter: g.unit_f64("enter").max(0.01),
                exit: g.unit_f64("exit").max(0.01),
            },
            _ => ArrivalKind::Diurnal {
                amp: g.unit_f64("amp"),
                period: g.u64("period", 10 * US, 5 * MS),
            },
        };
        let rate = g.u64("rate", 1_000, 5_000_000) as f64;
        let seed = g.u64("seed", 0, u64::MAX / 2);
        let mut a = ArrivalGen::new(kind, rate, seed);
        let mut b = ArrivalGen::new(kind, rate, seed);
        let (mut ta, mut tb) = (0u64, 0u64);
        for i in 0..500 {
            let (ga, gb) = (a.next_gap(ta), b.next_gap(tb));
            if ga != gb {
                return Err(format!("gap {i} diverged: {ga} vs {gb}"));
            }
            if ga == 0 {
                return Err(format!("gap {i} is zero (arrivals must advance time)"));
            }
            ta += ga;
            tb += gb;
        }
        Ok(())
    });
}

/// Poisson arrivals must actually realize the configured offered load:
/// the empirical mean gap over a long draw converges to 1/rate (within
/// 6% — far outside the ~1% standard error at this sample size).
#[test]
fn prop_poisson_empirical_mean_matches_rate() {
    use cxl_gpu::serve::{ArrivalGen, ArrivalKind};
    check("poisson-mean", 0xA11E, 40, |g| {
        let rate = g.u64("rate", 50_000, 2_000_000) as f64;
        let seed = g.u64("seed", 0, u64::MAX / 2);
        let mut gen = ArrivalGen::new(ArrivalKind::Poisson, rate, seed);
        let n = 10_000u64;
        let (mut now, mut sum) = (0u64, 0u64);
        for _ in 0..n {
            let gap = gen.next_gap(now);
            now += gap;
            sum += gap;
        }
        let want = 1e12 / rate;
        let got = sum as f64 / n as f64;
        if (got - want).abs() > 0.06 * want {
            return Err(format!(
                "mean gap off at {rate} rps: got {got:.0} ps, want {want:.0} ps"
            ));
        }
        Ok(())
    });
}

/// Sharded-pool equivalence (DESIGN.md §17), the tentpole contract as a
/// property: for random tenant mixes (workload, warps, MLP, WRR weight,
/// seed), random tenant counts {2, 4, 8} and shard counts {1, 2, 3, 4}
/// — 3 never divides the tenant count, so shard widths are uneven —
/// the conservative-lookahead coordinator must reproduce the serial
/// `run_pool` bit-for-bit: every tenant's metrics fingerprint, the
/// shared pool sums, and the merged event count.
#[test]
fn prop_sharded_pool_matches_serial_bit_for_bit() {
    use cxl_gpu::coordinator::config::SystemConfig;
    use cxl_gpu::fabric::{run_pool, run_pool_sharded, Tenant};
    use cxl_gpu::media::MediaKind;
    use cxl_gpu::workloads::table1b::spec;
    check("sharded-pool-identity", 0x54A2D, 6, |g| {
        let cfg_name = *g.choose("config", &["cxl-pool-shard", "cxl-pool-qos"]);
        let n = *g.choose("tenants", &[2usize, 4, 8]);
        let tenants: Vec<Tenant> = (0..n)
            .map(|i| {
                let wl = g.choose(&format!("wl{i}"), &["vadd", "bfs", "sort", "path"]);
                let mut cfg = SystemConfig::named(cfg_name, MediaKind::Ddr5);
                cfg.total_ops = 3_000;
                cfg.warps = g.usize(&format!("warps{i}"), 2, 16);
                cfg.mlp = g.usize(&format!("mlp{i}"), 1, 8);
                cfg.seed = g.u64(&format!("seed{i}"), 0, 1 << 40);
                cfg.fabric.weight = g.u64(&format!("weight{i}"), 1, 4) as u32;
                cfg.footprint = 4 << 20;
                cfg.local_bytes = 64 << 10; // mostly-expander: heavy coupling
                Tenant { workload: spec(wl), cfg }
            })
            .collect();
        let serial = run_pool(&tenants).map_err(|e| e.to_string())?;
        if serial.tenants.iter().all(|t| t.metrics.expander_loads == 0) {
            return Err("mix never crossed the fabric: the identity would be vacuous".into());
        }
        let serial_fps: Vec<Vec<u64>> =
            serial.tenants.iter().map(|t| t.metrics.fingerprint()).collect();
        for shards in [1usize, 2, 3, 4] {
            let threads = g.usize(&format!("threads{shards}"), 1, 4);
            let sharded =
                run_pool_sharded(&tenants, shards, Some(threads)).map_err(|e| e.to_string())?;
            if sharded.events != serial.events {
                return Err(format!(
                    "{n} tenants / {shards} shards: events {} != serial {}",
                    sharded.events, serial.events
                ));
            }
            if format!("{:?}", sharded.pool) != format!("{:?}", serial.pool) {
                return Err(format!("{n} tenants / {shards} shards: pool sums diverged"));
            }
            for (i, t) in sharded.tenants.iter().enumerate() {
                if t.metrics.fingerprint() != serial_fps[i] {
                    return Err(format!(
                        "{n} tenants / {shards} shards: tenant {i} ({}) diverged from serial",
                        t.workload
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Front-door conservation under arbitrary overload, end to end through
/// the simulator: every arrival is admitted or rejected, and every
/// admitted request exits exactly once — completed, shed, or timed out
/// (the run drains its queue before retiring, so nothing stays queued or
/// in flight). The queue must respect its configured bound throughout.
#[test]
fn prop_front_door_conserves_requests_under_overload() {
    use cxl_gpu::coordinator::config::SystemConfig;
    use cxl_gpu::coordinator::system::System;
    use cxl_gpu::media::MediaKind;
    use cxl_gpu::sim::US;
    use cxl_gpu::workloads::table1b::spec;
    check("serve-conservation", 0x5E12, 6, |g| {
        let mut cfg = SystemConfig::named("cxl-serve", MediaKind::Ddr5);
        cfg.total_ops = 6_000;
        cfg.ssd_scale();
        cfg.seed = g.u64("seed", 0, 1 << 30);
        cfg.warps = g.usize("warps", 1, 8);
        cfg.serve.rate_rps = g.u64("rate_krps", 100, 10_000) as f64 * 1e3;
        cfg.serve.slo = g.u64("slo_us", 10, 1_000) * US;
        cfg.serve.queue_cap = g.usize("queue_cap", 1, 64);
        cfg.serve.max_retries = g.u64("retries", 0, 4) as u32;
        if g.bool("bucket", 0.5) {
            cfg.serve.bucket_rps = g.u64("bucket_krps", 50, 5_000) as f64 * 1e3;
        }
        let m = System::new(spec("vadd"), &cfg).run();
        if m.serve_arrivals == 0 {
            return Err("armed front door generated no arrivals".into());
        }
        if m.serve_arrivals != m.serve_admitted + m.serve_rejected {
            return Err(format!(
                "admission books off: {} arrivals vs {} + {}",
                m.serve_arrivals, m.serve_admitted, m.serve_rejected
            ));
        }
        if m.serve_admitted != m.serve_completed + m.serve_shed + m.serve_timed_out {
            return Err(format!(
                "exit books off: {} admitted vs {} completed + {} shed + {} timed out",
                m.serve_admitted, m.serve_completed, m.serve_shed, m.serve_timed_out
            ));
        }
        if m.serve_completed_in_slo > m.serve_completed {
            return Err("in-SLO completions exceed completions".into());
        }
        if m.req_latency.count() != m.serve_completed {
            return Err(format!(
                "latency samples ({}) != completions ({})",
                m.req_latency.count(),
                m.serve_completed
            ));
        }
        if m.serve_queue_hwm > cfg.serve.queue_cap as u64 {
            return Err(format!(
                "queue hwm {} exceeds cap {}",
                m.serve_queue_hwm, cfg.serve.queue_cap
            ));
        }
        Ok(())
    });
}

/// Ledger conservation end to end through the simulator (DESIGN.md §18):
/// under randomized configs spanning every instrumented family — direct,
/// device-cache (admission + drains), pooled fabric with QoS arbitration,
/// RAS with armed CRC/timeout rates — tracing every op must attribute
/// each span's full end-to-end latency: the per-stage ledger sums
/// *bit-exactly* (u64 picoseconds, no epsilon) to `end - start` on every
/// retained span, and the tracer's violation counter stays at zero
/// across the whole run.
#[test]
fn prop_span_ledger_conserves_end_to_end_latency() {
    use cxl_gpu::coordinator::config::SystemConfig;
    use cxl_gpu::coordinator::system::System;
    use cxl_gpu::media::MediaKind;
    use cxl_gpu::sim::US;
    use cxl_gpu::workloads::table1b::spec;
    check("obs-ledger-conservation", 0x0B5E, 8, |g| {
        const FAMILIES: [&str; 4] = ["cxl", "cxl-cache", "cxl-pool-qos", "cxl-ras"];
        let name = FAMILIES[g.usize("family", 0, FAMILIES.len() - 1)];
        let media = if g.bool("znand", 0.7) { MediaKind::Znand } else { MediaKind::Ddr5 };
        let wl = if g.bool("hot", 0.5) { "hot75" } else { "bfs" };
        let mut cfg = SystemConfig::named(name, media);
        cfg.total_ops = 6_000;
        cfg.ssd_scale();
        cfg.seed = g.u64("seed", 0, 1 << 30);
        cfg.warps = g.usize("warps", 1, 8);
        cfg.mlp = g.usize("mlp", 1, 8);
        if name == "cxl-ras" {
            // Hot enough that retry legs actually fire in 6k ops.
            cfg.ras.crc_error_rate = g.u64("crc_ppm", 100, 2_000) as f64 * 1e-6;
            cfg.ras.timeout_rate = g.u64("to_ppm", 0, 1_000) as f64 * 1e-6;
            cfg.ras.timeout = 2 * US;
        }
        cfg.obs.enabled = true;
        cfg.obs.sample_shift = 0; // every op of every kind
        let m = System::new(spec(wl), &cfg).run();
        let rep = m.obs.as_ref().ok_or("armed run produced no obs report")?;
        if rep.spans == 0 {
            return Err(format!("{name}/{wl}: no spans traced"));
        }
        if rep.violations != 0 {
            return Err(format!(
                "{name}/{wl}: {} of {} spans violated ledger conservation",
                rep.violations, rep.spans
            ));
        }
        // Re-verify the retained ring independently of the counter:
        // stage picoseconds must telescope to the span bounds exactly.
        for s in &rep.ring {
            let attributed: u64 = s.stages.iter().sum();
            if attributed != s.end - s.start {
                return Err(format!(
                    "{name}/{wl}: span {} attributes {} ps of {} ps e2e",
                    s.id,
                    attributed,
                    s.end - s.start
                ));
            }
        }
        Ok(())
    });
}

/// Frame-delta conservation end to end through the simulator (DESIGN.md
/// §19): under randomized configs spanning every instrumented family —
/// direct, SR, device-cache, pooled fabric with QoS, RAS with armed
/// fault rates, the serving front door, tiering, UVM — the flight
/// recorder's per-frame counter deltas must sum *exactly* (u64, no
/// epsilon) to the run-final `RunMetrics` totals for every sampled
/// counter, with zero frames dropped. The residual frame appended at
/// harvest is what closes the books; any double count or missed source
/// breaks this for some config family.
#[test]
fn prop_telemetry_frame_deltas_sum_to_run_totals() {
    use cxl_gpu::coordinator::config::SystemConfig;
    use cxl_gpu::coordinator::system::System;
    use cxl_gpu::media::MediaKind;
    use cxl_gpu::sim::US;
    use cxl_gpu::workloads::table1b::spec;
    check("telemetry-conservation", 0x7E1E, 10, |g| {
        const FAMILIES: [&str; 8] = [
            "cxl", "cxl-sr", "cxl-cache", "cxl-pool-qos", "cxl-ras", "cxl-serve", "cxl-tier",
            "uvm",
        ];
        let name = FAMILIES[g.usize("family", 0, FAMILIES.len() - 1)];
        let media = if g.bool("znand", 0.7) { MediaKind::Znand } else { MediaKind::Ddr5 };
        let wl = if g.bool("hot", 0.5) { "hot75" } else { "bfs" };
        let mut cfg = SystemConfig::named(name, media);
        cfg.total_ops = 6_000;
        cfg.ssd_scale();
        cfg.seed = g.u64("seed", 0, 1 << 30);
        cfg.warps = g.usize("warps", 1, 8);
        cfg.mlp = g.usize("mlp", 1, 8);
        if name == "cxl-ras" {
            // Hot enough that retries and failovers actually fire.
            cfg.ras.crc_error_rate = g.u64("crc_ppm", 100, 2_000) as f64 * 1e-6;
            cfg.ras.degrade_at = g.u64("degrade_us", 20, 500) * US;
            cfg.ras.degrade_penalty = 10 * US;
        }
        cfg.telemetry.enabled = true;
        cfg.telemetry.epoch = *g.choose("epoch_us", &[2u64, 10, 50]) * US;
        let m = System::new(spec(wl), &cfg).run();
        let rep = m.telemetry.as_ref().ok_or("armed run produced no telemetry report")?;
        if rep.frames.is_empty() {
            return Err(format!("{name}/{wl}: no frames recorded"));
        }
        if rep.dropped != 0 {
            return Err(format!("{name}/{wl}: {} frames dropped", rep.dropped));
        }
        use cxl_gpu::telemetry::Frame;
        let pairs: [(&str, fn(&Frame) -> u64, u64); 20] = [
            ("loads", |f| f.d_loads, m.expander_loads),
            ("stores", |f| f.d_stores, m.expander_stores),
            ("llc_hits", |f| f.d_llc_hits, m.llc.hits),
            ("llc_misses", |f| f.d_llc_misses, m.llc.misses),
            ("mshr_stalls", |f| f.d_mshr_stalls, m.llc.mshr_stalls),
            ("ds_intercepts", |f| f.d_ds_intercepts, m.ds_intercepts),
            ("ep_cache_hits", |f| f.d_ep_cache_hits, m.ep_cache_hits),
            ("media_reads", |f| f.d_media_reads, m.media_reads),
            ("faults", |f| f.d_faults, m.faults),
            ("gc_episodes", |f| f.d_gc_episodes, m.gc_episodes),
            ("sr_issued", |f| f.d_sr_issued, m.sr_issued),
            ("cache_hits", |f| f.d_cache_hits, m.cache_hits),
            ("cache_misses", |f| f.d_cache_misses, m.cache_misses),
            ("cache_writebacks", |f| f.d_cache_writebacks, m.cache_writebacks),
            ("ras_retries", |f| f.d_ras_retries, m.ras_retries),
            ("ras_failovers", |f| f.d_ras_failovers, m.ras_failovers),
            ("tier_promotions", |f| f.d_tier_promotions, m.tier_promotions),
            ("tier_demotions", |f| f.d_tier_demotions, m.tier_demotions),
            ("serve_arrivals", |f| f.d_serve_arrivals, m.serve_arrivals),
            ("serve_completed", |f| f.d_serve_completed, m.serve_completed),
        ];
        for (field, get, want) in pairs {
            let got = rep.total(get);
            if got != want {
                return Err(format!(
                    "{name}/{wl}/{media:?}: frame deltas for {field} sum to {got}, run total is {want}"
                ));
            }
        }
        // The latency sample counts ride the same path as the sums.
        if rep.total(|f| f.d_load_count) != m.expander_loads {
            return Err(format!("{name}/{wl}: load latency sample count diverged"));
        }
        if rep.total(|f| f.d_store_count) != m.expander_stores {
            return Err(format!("{name}/{wl}: store latency sample count diverged"));
        }
        Ok(())
    });
}
