//! Integration: whole-system edge cases and cross-config invariants.

use cxl_gpu::coordinator::config::{MemStrategy, SystemConfig};
use cxl_gpu::coordinator::runner::run_with;
use cxl_gpu::coordinator::system::System;
use cxl_gpu::media::MediaKind;
use cxl_gpu::workloads::table1b::{spec, ALL_WORKLOADS};

fn small(name: &str, media: MediaKind) -> SystemConfig {
    let mut c = SystemConfig::named(name, media);
    c.total_ops = 6_000;
    c.ssd_scale();
    c
}

#[test]
fn every_workload_completes_under_every_strategy() {
    for w in ALL_WORKLOADS {
        for name in ["gpu-dram", "uvm", "gds", "cxl", "cxl-sr", "cxl-ds", "cxl-hybrid"] {
            let cfg = small(name, MediaKind::Znand);
            let m = System::new(w, &cfg).run();
            assert!(m.exec_time > 0, "{}/{name}: no progress", w.name);
            assert!(m.events > 0, "{}/{name}: no events", w.name);
        }
    }
}

#[test]
fn hybrid_sits_between_pure_configs() {
    let dram = run_with(spec("vadd"), &small("cxl", MediaKind::Ddr5));
    let ssd = run_with(spec("vadd"), &small("cxl-ds", MediaKind::Znand));
    let hybrid = run_with(spec("vadd"), &small("cxl-hybrid", MediaKind::Znand));
    assert!(
        hybrid.metrics.exec_time >= dram.metrics.exec_time,
        "hybrid cannot beat pure DRAM"
    );
    assert!(
        hybrid.metrics.exec_time <= ssd.metrics.exec_time * 11 / 10,
        "hybrid should roughly match or beat pure SSD"
    );
}

#[test]
fn seed_changes_results_but_preserves_shape() {
    let mut a_cfg = small("cxl-sr", MediaKind::Znand);
    let mut b_cfg = a_cfg.clone();
    b_cfg.seed = a_cfg.seed + 1;
    let a = System::new(spec("bfs"), &a_cfg).run();
    let b = System::new(spec("bfs"), &b_cfg).run();
    assert_ne!(a.exec_time, b.exec_time, "different seeds should differ");
    let ratio = a.exec_time as f64 / b.exec_time as f64;
    assert!((0.5..2.0).contains(&ratio), "seed variance too large: {ratio}");
    // Shape invariant across seeds: SR still speculates.
    assert!(a.sr_issued > 0 && b.sr_issued > 0);
    let _ = a_cfg.seed; // silence unused-mut lint paths
    a_cfg.seed += 0;
}

#[test]
fn zero_expander_config_degenerates_to_local() {
    // Footprint == local: the CXL machinery must never be touched.
    let mut cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
    cfg.total_ops = 4_000;
    cfg.footprint = 1 << 20;
    cfg.local_bytes = 1 << 20;
    let m = System::new(spec("vadd"), &cfg).run();
    assert_eq!(m.expander_loads, 0);
    assert_eq!(m.expander_stores, 0);
}

#[test]
fn uvm_strategy_never_uses_cxl_counters() {
    let m = System::new(spec("vadd"), &small("uvm", MediaKind::Ddr5)).run();
    assert_eq!(m.sr_issued, 0);
    assert_eq!(m.ds_intercepts, 0);
    assert!(m.faults > 0);
}

#[test]
fn gds_pays_more_than_uvm_for_the_same_trace() {
    let uvm = System::new(spec("vadd"), &small("uvm", MediaKind::Ddr5)).run();
    let gds = System::new(spec("vadd"), &small("gds", MediaKind::Znand)).run();
    // GDS = the UVM control path + an SSD read per migration; at tiny
    // scale the two can tie (writeback-only traffic), but GDS must never
    // be meaningfully faster.
    assert!(
        gds.exec_time * 10 >= uvm.exec_time * 9,
        "GDS cannot beat UVM: {} vs {}",
        gds.exec_time,
        uvm.exec_time
    );
}

#[test]
fn ds_backlog_is_eventually_flushed() {
    // After a run completes, the DS stack should be mostly drained by the
    // background flush (anything left is bounded by the reserved space).
    let cfg = small("cxl-ds", MediaKind::Znand);
    let spec = spec("bfs");
    let m = System::new(spec, &cfg).run();
    // intercepts and flushes happened; the run ends without losing stores
    // (conservation is asserted in the DS property test; here we check
    // the engine actually engaged on a GC-prone workload).
    assert!(m.exec_time > 0);
}

#[test]
fn strategies_report_consistent_memmap() {
    for name in ["gpu-dram", "uvm", "cxl"] {
        let cfg = small(name, MediaKind::Ddr5);
        match cfg.strategy {
            MemStrategy::GpuDram => assert_eq!(cfg.local_bytes, cfg.footprint),
            _ => assert!(cfg.local_bytes < cfg.footprint),
        }
    }
}
