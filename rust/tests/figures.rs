//! Integration: the paper's qualitative figure shapes at reduced scale.
//! (The full-scale sweeps live in `cargo bench`.)

use cxl_gpu::coordinator::experiments::{self, Scale};

#[test]
fn fig3b_controller_ordering() {
    let r = experiments::fig3b(false);
    assert!(r.ours_ns < 100.0, "two-digit ns");
    assert!(r.smt_ns / r.ours_ns > 3.0);
    assert!(r.tpp_ns / r.ours_ns > 3.0);
}

#[test]
fn table1b_mixes_track_paper() {
    let rows = experiments::table1b(false);
    assert_eq!(rows.len(), 13);
    for (name, c, l) in rows {
        let s = cxl_gpu::workloads::table1b::spec(name);
        assert!((c - s.compute_ratio).abs() < 0.05, "{name}");
        assert!((l - s.load_ratio).abs() < 0.06, "{name}");
    }
}

#[test]
fn fig9a_shape_uvm_much_worse_cxl_close() {
    // Quick scale: per-workload coverage is partial (short traces barely
    // leave local memory for some workloads), so assert the aggregate
    // ordering; the per-workload sweep runs at full scale in the bench.
    let r = experiments::fig9a(Scale::quick(), false);
    assert!(r.uvm_over_ideal > 10.0, "UVM {}", r.uvm_over_ideal);
    let uvm_over_cxl =
        cxl_gpu::coordinator::runner::overall_geomean(&r.uvm, &r.cxl);
    assert!(uvm_over_cxl > 5.0, "CXL should beat UVM broadly: {uvm_over_cxl}");
}

#[test]
fn fig9b_shape_sr_and_ds_help() {
    let r = experiments::fig9b(Scale::quick(), false);
    assert!(r.sr_over_cxl > 1.1, "SR {}", r.sr_over_cxl);
    assert!(r.ds_over_sr_store > 0.0, "DS store {}", r.ds_over_sr_store);
}

#[test]
fn fig9e_ds_hides_store_tail() {
    let r = experiments::fig9e(Scale::quick(), false);
    assert!(r.ds_peak_store_us < r.sr_peak_store_us);
}

#[test]
fn expander_cache_sweep_exercises_the_cache() {
    // Quick scale is warmup-dominated, so the latency *win* is asserted
    // only at full scale (benches/expander_cache.rs); here the sweep's
    // structure and the cache's vital signs must hold.
    let r = experiments::expander_cache(Scale::quick(), false);
    assert_eq!(r.rows.len(), 5 * 3, "5 workloads x 3 capacities");
    assert!(r.rows.iter().any(|row| row.hit_rate > 0.0), "no cell ever hit the cache");
    assert!(
        r.rows.iter().any(|row| row.bypasses > 0),
        "the admission predictor never bypassed"
    );
    assert!(r.cached_read_speedup.is_finite() && r.cached_read_speedup > 0.0);
    assert!(r.admit_speedup.is_finite() && r.admit_speedup > 0.0);
}

#[test]
fn headline_direction() {
    let r = experiments::headline(Scale::quick(), false);
    assert!(r.cxl_over_uvm > 1.5);
    assert!(r.cxl_over_smt > 1.0);
}
