//! GPUDirect-Storage (GDS) baseline: direct DMA between storage and GPU
//! memory, but fault translation still transits the host runtime.
//!
//! As the paper notes (Background §Direct DMA), GPUDirect/NVMMU map the
//! GPU BAR so the SSD's DMA engine can write GPU memory directly — the
//! data path skips host DRAM — yet every on-demand fault must still be
//! translated into storage I/O by the host runtime, so the control-path
//! overhead is comparable to UVM's. We therefore compose the UVM
//! resident-set machinery with an SSD backing read per fault.

use crate::media::SsdModel;
use crate::sim::Time;
use crate::util::prng::Pcg32;

use super::uvm::{FaultStats, UvmManager};

/// GDS manager: UVM-style residency + SSD backing store.
#[derive(Debug)]
pub struct GdsManager {
    pub inner: UvmManager,
    pub ssd: SsdModel,
}

impl GdsManager {
    pub fn new(block_bytes: u64, capacity: u64, ssd: SsdModel) -> GdsManager {
        GdsManager { inner: UvmManager::new(block_bytes, capacity), ssd }
    }

    pub fn is_resident(&self, addr: u64) -> bool {
        self.inner.is_resident(addr)
    }

    pub fn is_ready(&self, addr: u64, now: crate::sim::Time) -> bool {
        self.inner.is_ready(addr, now)
    }

    pub fn touch(&mut self, addr: u64, is_write: bool) {
        self.inner.touch(addr, is_write)
    }

    /// Fault service: host runtime + SSD read of the block + direct DMA.
    pub fn fault(&mut self, now: Time, addr: u64, is_write: bool, rng: &mut Pcg32) -> Time {
        let block_addr = addr / self.inner.block_bytes * self.inner.block_bytes;
        // The SSD reads the whole migration block; its internal cache
        // barely helps at this granularity (cold streaming reads).
        let (read_done, _hit) = self.ssd.read(now, block_addr, self.inner.block_bytes);
        let backing = read_done.saturating_sub(now);
        let _ = rng;
        self.inner.fault(now, addr, is_write, backing)
    }

    pub fn stats(&self) -> &FaultStats {
        &self.inner.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::media::SsdParams;
    use crate::sim::US;

    #[test]
    fn gds_fault_includes_storage_read() {
        let mut g = GdsManager::new(1 << 20, 4 << 20, SsdModel::new(SsdParams::znand()));
        let mut rng = Pcg32::new(1, 1);
        let done = g.fault(0, 0x100, false, &mut rng);
        // Host runtime (500µs) + media read: strictly above UVM's cost.
        assert!(done > 500 * US);
        assert!(g.is_resident(0x100));
    }

    #[test]
    fn residency_machinery_shared_with_uvm() {
        let mut g = GdsManager::new(1 << 20, 2 << 20, SsdModel::new(SsdParams::nand()));
        let mut rng = Pcg32::new(2, 2);
        let mut now = 0;
        for i in 0..3u64 {
            now = g.fault(now, i << 20, false, &mut rng);
        }
        assert_eq!(g.inner.resident_blocks(), 2);
        assert_eq!(g.stats().evictions, 1);
    }
}
