//! Baseline GPU memory-expansion strategies the paper compares against:
//! NVIDIA-style unified virtual memory ([`uvm`]) and GPUDirect-Storage-
//! style direct DMA ([`gds`]). Both route expander-region misses through
//! a host-runtime fault handler costed at ~500 µs per intervention
//! (the paper's own figure, after Allen & Ge).

pub mod gds;
pub mod uvm;

pub use gds::GdsManager;
pub use uvm::{FaultStats, UvmManager};

use crate::sim::{Time, US};

/// Host runtime intervention cost per fault batch (paper: ~500 µs).
pub const HOST_RUNTIME: Time = 500 * US;
