//! UVM baseline: on-demand paging with batched host-runtime fault service
//! and adaptive migration granularity.
//!
//! Expander data lives in host DRAM. A GPU access to a non-resident page
//! raises a fault over PCIe; the host runtime resolves faults in
//! *intervention windows* of ~500 µs (the paper's figure, after Allen &
//! Ge): every fault raised while a window is open is served when it
//! closes — NVIDIA's fault servicing batches the buffered faults of all
//! SMs per runtime invocation. Migration granularity is adaptive, like
//! the driver's tree-based prefetcher: sequential fault streams migrate
//! whole 256 KiB regions; isolated faults migrate a single 16 KiB page.
//! Old pages are evicted FIFO, dirty victims write back over PCIe.

use std::collections::VecDeque;

use crate::sim::{transfer_time, Time};
use crate::util::hash::FxHashMap;
use crate::util::stats::Summary;

use super::HOST_RUNTIME;

/// Base residency/migration unit.
pub const PAGE: u64 = 16 << 10;
/// Prefetch region for sequential fault streams.
pub const REGION: u64 = 128 << 10;

/// Fault-path statistics.
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    pub faults: u64,
    pub interventions: u64,
    pub migrated_bytes: u64,
    pub evictions: u64,
    pub writeback_bytes: u64,
    pub fault_latency: Summary,
}

#[derive(Debug, Clone, Copy)]
struct PageState {
    dirty: bool,
    /// Migration completes at this time (pending until then).
    ready: Time,
}

/// UVM resident-set manager.
#[derive(Debug)]
pub struct UvmManager {
    /// Base page size (config `uvm_block`; default [`PAGE`]).
    pub block_bytes: u64,
    /// GPU memory budget for migrated pages.
    pub capacity: u64,
    /// PCIe bandwidth, GB/s.
    pub pcie_gbps: f64,
    pages: FxHashMap<u64, PageState>,
    fifo: VecDeque<u64>,
    /// Current intervention window's close time.
    win_end: Time,
    /// PCIe transfer serialization cursor.
    pcie_free: Time,
    /// Last faulting prefetch-region id (sequential-stream detector).
    last_region: u64,
    pub stats: FaultStats,
}

impl UvmManager {
    pub fn new(block_bytes: u64, capacity: u64) -> UvmManager {
        UvmManager {
            block_bytes: block_bytes.max(4096),
            capacity,
            pcie_gbps: 32.0,
            pages: FxHashMap::default(),
            fifo: VecDeque::new(),
            win_end: 0,
            pcie_free: 0,
            last_region: u64::MAX - 8,
            stats: FaultStats::default(),
        }
    }

    fn page_of(&self, addr: u64) -> u64 {
        addr / self.block_bytes
    }

    fn pages_per_region(&self) -> u64 {
        (REGION / self.block_bytes).max(1)
    }

    fn max_pages(&self) -> usize {
        (self.capacity / self.block_bytes).max(1) as usize
    }

    /// Is the address resident *and* its migration complete at `now`?
    pub fn is_ready(&self, addr: u64, now: Time) -> bool {
        self.pages.get(&self.page_of(addr)).is_some_and(|p| p.ready <= now)
    }

    /// Resident (possibly still migrating)?
    pub fn is_resident(&self, addr: u64) -> bool {
        self.pages.contains_key(&self.page_of(addr))
    }

    /// Mark dirty on write (resident pages only).
    pub fn touch(&mut self, addr: u64, is_write: bool) {
        let page = self.page_of(addr);
        if let Some(p) = self.pages.get_mut(&page) {
            p.dirty |= is_write;
        }
    }

    /// The intervention window that serves a fault raised at `now`.
    fn window_end(&mut self, now: Time) -> Time {
        if now >= self.win_end {
            // Runtime idle: a new intervention opens now.
            self.win_end = now + HOST_RUNTIME;
            self.stats.interventions += 1;
        }
        self.win_end
    }

    /// Service an access to a faulting address at `now`. Returns when the
    /// access may proceed. `backing_read` adds the backing store's read
    /// time per migration (0 for host DRAM; the SSD read for GDS).
    pub fn fault(&mut self, now: Time, addr: u64, is_write: bool, backing_read: Time) -> Time {
        let page = self.page_of(addr);
        if let Some(p) = self.pages.get_mut(&page) {
            // Already migrating or resident: wait for readiness.
            p.dirty |= is_write;
            return p.ready.max(now);
        }
        self.stats.faults += 1;

        // Sequential-stream detection over prefetch regions: the driver's
        // tree prefetcher widens migrations for streaming access.
        let region = addr / REGION;
        let sequential =
            region == self.last_region || region == self.last_region.wrapping_add(1);
        self.last_region = region;

        // Batched host intervention + serialized PCIe transfer(s).
        let host_done = self.window_end(now);
        let first_page = if sequential { region * self.pages_per_region() } else { page };
        let n_pages = if sequential { self.pages_per_region() } else { 1 };

        self.pcie_free = self.pcie_free.max(host_done);
        let mut migrated = 0u64;
        for p in first_page..first_page + n_pages {
            if self.pages.contains_key(&p) {
                continue;
            }
            migrated += self.block_bytes;
            // Insert with placeholder readiness; fixed below.
            self.pages.insert(p, PageState { dirty: is_write && p == page, ready: Time::MAX });
            self.fifo.push_back(p);
        }
        self.pcie_free += transfer_time(migrated.max(self.block_bytes), self.pcie_gbps);
        let done = self.pcie_free + backing_read;
        for p in first_page..first_page + n_pages {
            if let Some(st) = self.pages.get_mut(&p) {
                if st.ready == Time::MAX {
                    st.ready = done;
                }
            }
        }
        self.stats.migrated_bytes += migrated;

        // Eviction (FIFO): dirty victims write back over PCIe first.
        // Pages still migrating are never evicted — kicking a pending
        // page would make its waiters refault forever (a livelock the
        // system-edge tests caught); they rotate to the back instead.
        let mut attempts = self.fifo.len();
        while self.pages.len() > self.max_pages() && attempts > 0 {
            attempts -= 1;
            let Some(victim) = self.fifo.pop_front() else { break };
            // Single-lookup eviction: `remove` hands over the entry (a
            // stale FIFO slot simply has none), and a still-pending page
            // is re-inserted untouched — no get-then-remove window for
            // an unwrap to bite.
            let Some(v) = self.pages.remove(&victim) else { continue };
            if v.ready > done {
                self.pages.insert(victim, v);
                self.fifo.push_back(victim); // pending: not evictable
                continue;
            }
            self.stats.evictions += 1;
            if v.dirty {
                self.pcie_free += transfer_time(self.block_bytes, self.pcie_gbps);
                self.stats.writeback_bytes += self.block_bytes;
            }
        }

        self.stats.fault_latency.add((done - now) as f64);
        done
    }

    pub fn resident_blocks(&self) -> usize {
        self.pages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MS, US};

    fn mgr() -> UvmManager {
        UvmManager::new(PAGE, 64 * PAGE) // 16 KiB pages, 64-page budget
    }

    #[test]
    fn first_touch_faults_then_ready() {
        let mut m = mgr();
        assert!(!m.is_resident(0x100));
        let done = m.fault(0, 0x100, false, 0);
        assert!(done >= 500 * US, "fault must cost the host window");
        assert!(m.is_resident(0x100));
        assert!(!m.is_ready(0x100, done - 1));
        assert!(m.is_ready(0x100, done));
    }

    #[test]
    fn faults_in_one_window_batch() {
        let mut m = mgr();
        // Use far-apart regions so no prefetch merging.
        let d1 = m.fault(0, 0, false, 0);
        let d2 = m.fault(10, 10 * REGION, false, 0); // same window
        assert!(d2 < d1 + 100 * US, "second fault must batch: {d1} vs {d2}");
        assert_eq!(m.stats.interventions, 1);
        let d3 = m.fault(d1 + 1, 20 * REGION, false, 0);
        assert!(d3 >= d1 + 500 * US);
        assert_eq!(m.stats.interventions, 2);
    }

    #[test]
    fn sequential_faults_prefetch_whole_region() {
        let mut m = mgr();
        m.fault(0, 0, false, 0); // region 0 (counts as sequential from init? no)
        let before = m.stats.faults;
        let d = m.fault(0, REGION, false, 0); // region 1: sequential
        assert_eq!(m.stats.faults, before + 1);
        // The whole next region became resident: accesses inside it wait
        // for the same migration but fault no further.
        assert!(m.is_resident(REGION + 5 * PAGE));
        assert!(m.is_ready(REGION + 5 * PAGE, d));
    }

    #[test]
    fn isolated_fault_migrates_one_page() {
        let mut m = mgr();
        m.fault(0, 0, false, 0);
        m.fault(0, 50 * REGION, false, 0); // jump: not sequential
        assert!(m.is_resident(50 * REGION));
        assert!(
            !m.is_resident(50 * REGION + PAGE),
            "isolated fault must not prefetch the region"
        );
    }

    #[test]
    fn refault_of_pending_page_waits() {
        let mut m = mgr();
        let d1 = m.fault(0, 0x0, false, 0);
        let d2 = m.fault(100, 0x40, false, 0);
        assert_eq!(d1, d2);
        assert_eq!(m.stats.faults, 1, "one migration, one fault");
    }

    #[test]
    fn capacity_forces_fifo_eviction() {
        let mut m = mgr();
        let mut now = 0;
        for i in 0..65u64 {
            now = m.fault(now, i * 31 * REGION, false, 0); // isolated pages
        }
        assert_eq!(m.resident_blocks(), 64);
        assert_eq!(m.stats.evictions, 1);
        assert!(!m.is_resident(0), "page 0 was first in");
    }

    #[test]
    fn dirty_eviction_pays_writeback() {
        let mut m = mgr();
        let mut now = 0;
        now = m.fault(now, 0, true, 0); // dirty page 0
        for i in 1..64u64 {
            now = m.fault(now, i * 31 * REGION, false, 0);
        }
        let before = m.stats.writeback_bytes;
        m.fault(now, 64 * 31 * REGION, false, 0); // evicts dirty page 0
        assert_eq!(m.stats.writeback_bytes, before + PAGE);
    }

    #[test]
    fn backing_read_extends_fault() {
        let mut m = mgr();
        let plain = m.fault(0, 0, false, 0);
        let mut m2 = mgr();
        let with_ssd = m2.fault(0, 0, false, 3 * MS);
        assert!(with_ssd >= plain + 3 * MS);
    }
}
