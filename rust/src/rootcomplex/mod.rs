//! The GPU's CXL root complex (Fig. 5): host bridge + HDM decoder +
//! multiple root ports, each fronting a DRAM- or SSD-backed endpoint.
//!
//! This module is the paper's *system contribution*: the piece that lets
//! GPU compute units reach memory expanders with plain loads/stores, no
//! host intervention — plus the two controller optimizations, SR
//! ([`spec_read`]) and DS ([`det_store`]), and the tiering subsystem
//! ([`tiering`]) that keeps hot pages on the DRAM ports of a
//! heterogeneous (DRAM + SSD) topology.

pub mod det_store;
pub mod hdm;
pub mod rbtree;
pub mod rootport;
pub mod spec_read;
pub mod tiering;

pub use det_store::{DetStoreEngine, DsStats, StoreAction};
pub use hdm::{HdmDecoder, HdmEntry, MAX_INTERLEAVE_WAYS};
pub use rbtree::RbTree;
pub use rootport::{EpBackend, LoadOutcome, LoadPath, PortStats, RootPort, StoreOutcome};
pub use spec_read::{SpecReadEngine, SrPolicy, SrStats};
pub use tiering::{TierConfig, TierStats, Tiering};

use crate::fabric::{FabricLink, PoolSums, TenantFabricStats};
use crate::media::MediaKind;
use crate::obs::{Stage, StageTrace};
use crate::sim::{Time, NS};
use crate::util::prng::Pcg32;

/// Where one HDM decode target routes: a local (direct-attached) root
/// port, or a downstream endpoint of the shared pooled fabric. The
/// indirection is what lets every expander request take the same decode
/// path regardless of topology — `RootComplex::load`/`store` resolve
/// the decoded index through `targets` and never assume exclusive
/// endpoint ownership.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortTarget {
    /// Index into this root complex's own [`RootPort`] vector.
    Direct(usize),
    /// Downstream port index of the attached fabric switch.
    Fabric(usize),
}

/// The attached pool, for fabric-routed topologies.
#[derive(Debug)]
struct FabricAttachment {
    link: FabricLink,
    /// This tenant's upstream port on the shared switch.
    upstream: usize,
}

/// The root complex: host-bridge decode + port fan-out, with an optional
/// tiering layer between the HPA space and the HDM decoder, and an
/// optional fabric attachment replacing the local ports.
#[derive(Debug)]
pub struct RootComplex {
    pub hdm: HdmDecoder,
    pub ports: Vec<RootPort>,
    /// Host-bridge + HDM-decode traversal cost.
    pub bridge_lat: Time,
    /// Hot-page tracker + migration engine ([`tiering`]); `None` for the
    /// statically-partitioned configurations.
    pub tier: Option<Tiering>,
    /// HDM decode-target indirection: entry `i` says where decoded
    /// target index `i` routes (identity onto `ports` for direct
    /// topologies, fabric downstream ports for pooled ones).
    targets: Vec<PortTarget>,
    fabric: Option<FabricAttachment>,
}

/// Per-tenant fabric counters harvested into `RunMetrics` after a run,
/// plus — when this tenant is the pool's sole upstream — the pooled
/// endpoints' own sums (so a single-tenant pool reports exactly what
/// the direct topology reports).
#[derive(Debug, Clone)]
pub struct FabricHarvest {
    pub upstream: TenantFabricStats,
    pub sole_pool: Option<PoolSums>,
}

impl RootComplex {
    pub fn new(ports: Vec<RootPort>) -> RootComplex {
        let targets = (0..ports.len()).map(PortTarget::Direct).collect();
        RootComplex {
            hdm: HdmDecoder::new(),
            ports,
            bridge_lat: 2 * NS,
            tier: None,
            targets,
            fabric: None,
        }
    }

    /// Attach this root complex to a pooled fabric as upstream port
    /// `upstream`: every decode target now routes to the switch's
    /// downstream endpoints instead of local ports.
    pub fn attach_fabric(&mut self, link: FabricLink, upstream: usize) {
        let n = link.lock().expect("fabric mutex poisoned").downstream.len();
        self.targets = (0..n).map(PortTarget::Fabric).collect();
        self.fabric = Some(FabricAttachment { link, upstream });
    }

    /// The decode-target routing table (identity over local ports for
    /// direct topologies).
    pub fn targets(&self) -> &[PortTarget] {
        &self.targets
    }

    /// Firmware init: carve the HDM space evenly across ports (the
    /// simplified core's enumeration pass). `total` bytes of expander.
    pub fn enumerate(&mut self, total: u64) -> Result<(), String> {
        let n = self.ports.len() as u64;
        if n == 0 {
            return Err("root complex has no ports to enumerate".into());
        }
        let per = total / n;
        self.enumerate_sized(&vec![per; n as usize])
    }

    /// Firmware init against the pooled fabric's downstream endpoints:
    /// the same per-EP CXL.io config-space walk as
    /// [`RootComplex::enumerate_sized`], but each window targets a
    /// fabric downstream port and offsets its device addresses by
    /// `dpa_base` — the tenant's slice of the shared pool, so co-tenant
    /// address spaces never alias on the endpoints.
    pub fn enumerate_fabric(&mut self, total: u64, dpa_base: u64) -> Result<(), String> {
        use crate::cxl::ConfigSpace;
        let att = self.fabric.as_ref().ok_or("no fabric attached to enumerate")?;
        let kinds: Vec<MediaKind> =
            att.link.lock().expect("fabric mutex poisoned").downstream_kinds();
        if kinds.is_empty() {
            return Err("fabric has no downstream endpoints".into());
        }
        let per = total / kinds.len() as u64;
        let mut base = 0;
        for (i, media) in kinds.iter().enumerate() {
            let raw = if media.is_ssd() {
                ConfigSpace::ssd_ep(per, *media)
            } else {
                ConfigSpace::dram_ep(per)
            };
            let cs = ConfigSpace::from_dwords(
                raw.read_dword(0),
                raw.read_dword(1),
                raw.read_dword(2),
                raw.read_dword(3),
                *media,
            );
            if !cs.is_hdm_capable() {
                return Err(format!("fabric endpoint {i}: EP is not HDM-capable"));
            }
            self.hdm
                .program(HdmEntry::direct(i, base, cs.hdm_size).with_dpa_base(dpa_base))?;
            base += cs.hdm_size;
        }
        Ok(())
    }

    /// Firmware init against per-port HDM sizes, walking each EP's
    /// CXL.io configuration space exactly as the paper's simplified core
    /// does: read identity + HDM capability registers over CXL.io,
    /// reject non-HDM devices, then program base/size into the host
    /// bridge's decoder in port order.
    pub fn enumerate_sized(&mut self, sizes: &[u64]) -> Result<(), String> {
        use crate::cxl::ConfigSpace;
        if sizes.len() != self.ports.len() {
            return Err(format!(
                "{} sizes for {} ports",
                sizes.len(),
                self.ports.len()
            ));
        }
        let mut base = 0;
        for (i, port) in self.ports.iter().enumerate() {
            let media = port.backend.kind();
            let raw = if media.is_ssd() {
                ConfigSpace::ssd_ep(sizes[i], media)
            } else {
                ConfigSpace::dram_ep(sizes[i])
            };
            // CXL.io config read round trip (4 dwords), as firmware sees it.
            let cs = ConfigSpace::from_dwords(
                raw.read_dword(0),
                raw.read_dword(1),
                raw.read_dword(2),
                raw.read_dword(3),
                media,
            );
            if !cs.is_hdm_capable() {
                return Err(format!("port {i}: EP is not HDM-capable"));
            }
            self.hdm.program(HdmEntry::direct(i, base, cs.hdm_size))?;
            base += cs.hdm_size;
        }
        Ok(())
    }

    /// Firmware init for the tiered hybrid topology: group the ports by
    /// media class (DRAM = fast tier, SSD = slow tier), give each group a
    /// share of the `total` decode space proportional to its port count,
    /// and stripe each group's window across its members with `2^gran_bits`
    /// granules (IW/IG interleaving, [`hdm`]) — DRAM group first, so the
    /// fast tier occupies the bottom of the decode space.
    ///
    /// Returns the fast-tier size in bytes (0 when every port is an SSD;
    /// `total` when every port is DRAM). Group shares that don't divide
    /// into whole stripes leave their remainder as a small direct window
    /// on the group's first port, so the decode space covers exactly
    /// `total` bytes. Non-power-of-two groups fall back to per-port
    /// direct windows.
    pub fn enumerate_interleaved(&mut self, total: u64, gran_bits: u32) -> Result<u64, String> {
        let n = self.ports.len() as u64;
        if n == 0 {
            return Err("root complex has no ports to enumerate".into());
        }
        let fast: Vec<usize> =
            (0..self.ports.len()).filter(|&i| !self.ports[i].backend.is_ssd()).collect();
        let slow: Vec<usize> =
            (0..self.ports.len()).filter(|&i| self.ports[i].backend.is_ssd()).collect();
        // Proportional split; the slow group absorbs the rounding
        // remainder so the decode space covers exactly `total` bytes
        // (System panics on decode misses).
        let fast_bytes = if slow.is_empty() {
            total
        } else if fast.is_empty() {
            0
        } else {
            total * fast.len() as u64 / n
        };
        if fast_bytes > 0 {
            self.program_group(&fast, 0, fast_bytes, gran_bits)?;
        }
        if total > fast_bytes {
            self.program_group(&slow, fast_bytes, total - fast_bytes, gran_bits)?;
        }
        Ok(fast_bytes)
    }

    /// Program one media group's `[base, base+share)` window: one
    /// interleaved entry for the stripe-aligned bulk (power-of-two
    /// groups), direct per-port windows otherwise, and a direct remainder
    /// window on the first port for any unaligned tail.
    fn program_group(
        &mut self,
        group: &[usize],
        base: u64,
        share: u64,
        gran_bits: u32,
    ) -> Result<(), String> {
        let ways = group.len();
        if ways > 1 && ways.is_power_of_two() && ways <= MAX_INTERLEAVE_WAYS {
            let stripe = (ways as u64) << gran_bits;
            let aligned = share / stripe * stripe;
            if aligned > 0 {
                self.hdm.program(HdmEntry::interleaved(group, base, aligned, gran_bits))?;
            }
            if share > aligned {
                // The tail window continues the first port's DPA space
                // past the bulk window's per-way span — without the
                // offset, DPA 0 would alias between the two windows.
                self.hdm.program(
                    HdmEntry::direct(group[0], base + aligned, share - aligned)
                        .with_dpa_base(aligned / ways as u64),
                )?;
            }
        } else {
            let per = share / ways as u64;
            let mut b = base;
            for (k, &port) in group.iter().enumerate() {
                let sz = if k + 1 == ways { base + share - b } else { per };
                if sz > 0 {
                    self.hdm.program(HdmEntry::direct(port, b, sz))?;
                }
                b += sz;
            }
        }
        Ok(())
    }

    /// Attach the hot-page tracker + migration engine. `fast_bytes` is
    /// what [`RootComplex::enumerate_interleaved`] returned.
    pub fn attach_tiering(&mut self, cfg: TierConfig, fast_bytes: u64, total: u64) {
        self.tier = Some(Tiering::new(cfg, fast_bytes, total));
    }

    /// Route a load at HDM-relative address `hpa_off` through the
    /// decode-target indirection (direct port or fabric endpoint).
    pub fn load(&mut self, now: Time, hpa_off: u64, len: u64) -> LoadOutcome {
        self.load_traced(now, hpa_off, len, None)
    }

    /// [`load`](RootComplex::load) with an optional span ledger: both
    /// bridge traversals are attributed to `HostBridge` and the ledger
    /// is threaded through the switch (fabric) or port (direct), whose
    /// stages telescope with this one to `done - now` exactly.
    pub fn load_traced(
        &mut self,
        now: Time,
        hpa_off: u64,
        len: u64,
        mut trace: Option<&mut StageTrace>,
    ) -> LoadOutcome {
        let addr = match &mut self.tier {
            Some(t) => t.translate(hpa_off),
            None => hpa_off,
        };
        let (idx, off) = self
            .hdm
            .decode(addr)
            .unwrap_or_else(|| panic!("HDM decode miss at {:#x}", addr));
        if let Some(t) = trace.as_deref_mut() {
            t.add(Stage::HostBridge, 2 * self.bridge_lat);
        }
        let mut out = match self.targets[idx] {
            PortTarget::Direct(p) => {
                self.ports[p].load_traced(now + self.bridge_lat, off, len, trace)
            }
            PortTarget::Fabric(d) => {
                let att = self.fabric.as_ref().expect("fabric target without attachment");
                att.link.lock().expect("fabric mutex poisoned").load_traced(
                    att.upstream,
                    d,
                    now + self.bridge_lat,
                    off,
                    len,
                    trace,
                )
            }
        };
        out.done += self.bridge_lat;
        out
    }

    /// Route a store at HDM-relative address `hpa_off` through the
    /// decode-target indirection.
    pub fn store(&mut self, now: Time, hpa_off: u64, len: u64, rng: &mut Pcg32) -> StoreOutcome {
        self.store_traced(now, hpa_off, len, rng, None)
    }

    /// [`store`](RootComplex::store) with an optional span ledger (same
    /// attribution as [`load_traced`](RootComplex::load_traced)).
    pub fn store_traced(
        &mut self,
        now: Time,
        hpa_off: u64,
        len: u64,
        rng: &mut Pcg32,
        mut trace: Option<&mut StageTrace>,
    ) -> StoreOutcome {
        let addr = match &mut self.tier {
            Some(t) => t.translate(hpa_off),
            None => hpa_off,
        };
        let (idx, off) = self
            .hdm
            .decode(addr)
            .unwrap_or_else(|| panic!("HDM decode miss at {:#x}", addr));
        if let Some(t) = trace.as_deref_mut() {
            t.add(Stage::HostBridge, 2 * self.bridge_lat);
        }
        let mut out = match self.targets[idx] {
            PortTarget::Direct(p) => {
                self.ports[p].store_traced(now + self.bridge_lat, off, len, rng, trace)
            }
            PortTarget::Fabric(d) => {
                let att = self.fabric.as_ref().expect("fabric target without attachment");
                att.link.lock().expect("fabric mutex poisoned").store_traced(
                    att.upstream,
                    d,
                    now + self.bridge_lat,
                    off,
                    len,
                    rng,
                    trace,
                )
            }
        };
        out.ack += self.bridge_lat;
        out
    }

    /// Epoch tick for the migration engine: scan the access counters,
    /// then execute the planned swaps. Every transferred chunk goes
    /// through [`RootPort::migrate`], consuming a memory-queue slot and
    /// real media time on both the source and destination ports — the
    /// bandwidth cost of tiering is charged, not assumed away.
    pub fn tier_tick(&mut self, now: Time, rng: &mut Pcg32) {
        let RootComplex { hdm, ports, tier, bridge_lat } = self;
        let Some(t) = tier.as_mut() else { return };
        t.plan_epoch();
        let page = t.config().page_bytes;
        // Move data in granule-sized chunks so interleaved frames charge
        // every port in their stripe.
        let chunk = page.min(1u64 << t.config().gran_bits);
        while let Some((hot_page, cold_page)) = t.pop_move() {
            let hot_frame = t.frame_base(hot_page);
            let cold_frame = t.frame_base(cold_page);
            // RAS steering (DESIGN.md §15): both sides of a swap receive
            // writes, so a swap whose stripe touches a degraded port is
            // vetoed for this epoch — hot pages are never migrated onto
            // a failing endpoint, and the veto counts as a failover.
            let mut degraded = None;
            let mut probe = 0;
            while probe < page && degraded.is_none() {
                let (sp, _) = hdm
                    .decode(hot_frame + probe)
                    .unwrap_or_else(|| panic!("tier decode miss at {:#x}", hot_frame + probe));
                let (fp, _) = hdm
                    .decode(cold_frame + probe)
                    .unwrap_or_else(|| panic!("tier decode miss at {:#x}", cold_frame + probe));
                degraded = [sp, fp].into_iter().find(|&p| ports[p].is_degraded());
                probe += chunk;
            }
            if let Some(dp) = degraded {
                if let Some(r) = &mut ports[dp].ras {
                    r.stats.failovers += 1;
                }
                continue;
            }
            let start = now + *bridge_lat;
            let mut off = 0;
            while off < page {
                let (sp, s_dpa) = hdm
                    .decode(hot_frame + off)
                    .unwrap_or_else(|| panic!("tier decode miss at {:#x}", hot_frame + off));
                let (fp, f_dpa) = hdm
                    .decode(cold_frame + off)
                    .unwrap_or_else(|| panic!("tier decode miss at {:#x}", cold_frame + off));
                // Any DS-buffered lines in either frame are subsumed by
                // the page copy (which carries the freshest data) and
                // must not intercept reads of the page that will occupy
                // these device addresses after the swap. The same goes
                // for lines in the expander-side device cache (§14):
                // stale residents must not serve hits post-swap.
                ports[sp].ds.invalidate_range(s_dpa, s_dpa + chunk);
                ports[fp].ds.invalidate_range(f_dpa, f_dpa + chunk);
                ports[sp].invalidate_cache_range(s_dpa, s_dpa + chunk);
                ports[fp].invalidate_cache_range(f_dpa, f_dpa + chunk);
                // Promotion leg: slow read → fast write.
                ports[sp].migrate(start, s_dpa, chunk, false, rng);
                ports[fp].migrate(start, f_dpa, chunk, true, rng);
                // Demotion leg: fast read → slow write.
                ports[fp].migrate(start, f_dpa, chunk, false, rng);
                ports[sp].migrate(start, s_dpa, chunk, true, rng);
                off += chunk;
            }
            t.commit_swap(hot_page, cold_page);
        }
    }

    /// Background DS flush across ports. For a fabric-routed topology,
    /// every tenant's tick forwards to the pool and the *switch* dedupes
    /// to one sweep per cadence — so the pool keeps flushing even after
    /// any particular tenant (including tenant 0) retires.
    pub fn flush_tick(&mut self, now: Time, rng: &mut Pcg32) {
        for p in &mut self.ports {
            p.flush_step(now, 8, rng);
        }
        if let Some(att) = &self.fabric {
            att.link.lock().expect("fabric mutex poisoned").flush_tick(now, rng);
        }
    }

    /// Total buffered DS bytes (for end-of-run draining checks),
    /// including the attached pool's endpoints.
    pub fn ds_backlog(&self) -> u64 {
        let local: u64 = self.ports.iter().map(|p| p.ds.buffered_bytes()).sum();
        let pooled = self
            .fabric
            .as_ref()
            .map_or(0, |att| att.link.lock().expect("fabric mutex poisoned").ds_backlog());
        local + pooled
    }

    /// Ingress occupancy seen by this system's timeline series: the
    /// first local port's memory queue (direct), or this tenant's
    /// upstream ingress queue (fabric).
    pub fn ingress_occupancy(&self, now: Time) -> usize {
        if let Some(att) = &self.fabric {
            return att
                .link
                .lock()
                .expect("fabric mutex poisoned")
                .ingress_occupancy(att.upstream, now);
        }
        self.ports.first().map_or(0, |p| p.occupancy(now))
    }

    /// Fabric counters for this tenant (None for direct topologies).
    pub fn fabric_harvest(&self) -> Option<FabricHarvest> {
        let att = self.fabric.as_ref()?;
        let sw = att.link.lock().expect("fabric mutex poisoned");
        Some(FabricHarvest {
            upstream: sw.upstream_stats(att.upstream).clone(),
            sole_pool: (sw.upstreams() == 1).then(|| sw.pool_sums()),
        })
    }

    /// Expander-side snapshot for the telemetry flight recorder (§19):
    /// gauges at `at` plus the cumulative counters the frame deltas are
    /// computed from. Counter sourcing mirrors `System::harvest` exactly
    /// — local ports always, pooled endpoints only when this tenant is
    /// the pool's sole upstream — so frame deltas sum to the run-final
    /// `RunMetrics` totals. One fabric lock per call.
    pub fn telemetry_snapshot(&self, at: Time) -> FabricTelemetry {
        let mut t = FabricTelemetry::default();
        for p in &self.ports {
            t.port_queue += p.occupancy(at) as u64;
            t.devload = t.devload.max(p.devload(at).encode());
            t.ds_buffered += p.ds.buffered_bytes();
            t.ds_intercepts += p.ds.stats.read_intercepts;
            t.ras_degraded += p.is_degraded() as u64;
            t.sr_issued += p.sr.stats.sr_issued;
            t.sr_suppressed += p.sr.stats.cache_suppressed;
            if let Some(c) = &p.cache {
                t.cache_lines += c.lines() as u64;
                t.cache_dirty += c.dirty_lines() as u64;
                t.cache_wb_pending += c.wb_pending() as u64;
                t.cache_hits += c.stats.hits;
                t.cache_misses += c.stats.misses;
                t.cache_writebacks += c.stats.writebacks;
            }
            if let Some(r) = &p.ras {
                t.ras_retries += r.stats.retries;
                t.ras_failovers += r.stats.failovers;
            }
            if let EpBackend::Ssd(m) = &p.backend {
                t.gc_episodes += m.stats.gc_episodes;
            }
        }
        if let Some(att) = &self.fabric {
            let sw = att.link.lock().expect("fabric mutex poisoned");
            t.ingress = sw.ingress_occupancy(att.upstream, at) as u64;
            t.port_queue = t.ingress;
            t.devload = sw.worst_devload(at);
            t.ds_buffered += sw.ds_backlog();
            t.ras_degraded += sw.degraded_endpoints();
            t.qos_rate = sw.qos_rate(att.upstream);
            let st = sw.upstream_stats(att.upstream);
            t.throttle_waits = st.throttle_waits;
            t.backpressure = st.backpressure;
            if sw.upstreams() == 1 {
                let ps = sw.pool_sums();
                t.sr_issued += ps.sr_issued;
                t.ds_intercepts += ps.ds_intercepts;
                t.gc_episodes += ps.gc_episodes;
                t.cache_hits += ps.cache_hits;
                t.cache_misses += ps.cache_misses;
                t.cache_writebacks += ps.cache_writebacks;
                t.ras_retries += ps.ras_retries;
                t.ras_failovers += ps.ras_failovers;
                for p in &sw.downstream {
                    t.sr_suppressed += p.sr.stats.cache_suppressed;
                    if let Some(c) = &p.cache {
                        t.cache_lines += c.lines() as u64;
                        t.cache_dirty += c.dirty_lines() as u64;
                        t.cache_wb_pending += c.wb_pending() as u64;
                    }
                }
            }
        }
        t
    }
}

/// One root complex's expander-side telemetry snapshot — see
/// [`RootComplex::telemetry_snapshot`]. Gauge fields are instantaneous;
/// the rest are cumulative counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FabricTelemetry {
    /// Summed local-port queue occupancy (direct) or this tenant's
    /// switch ingress occupancy (pooled).
    pub port_queue: u64,
    /// Worst DevLoad class across endpoints (0=Light .. 3=Severe).
    pub devload: u8,
    pub ds_buffered: u64,
    pub cache_lines: u64,
    pub cache_dirty: u64,
    pub cache_wb_pending: u64,
    pub ras_degraded: u64,
    pub qos_rate: u64,
    pub ingress: u64,
    pub sr_issued: u64,
    pub sr_suppressed: u64,
    /// Port/pool-side DS read-intercept count; `System` adds its own
    /// per-load count on top, mirroring the two harvest sources.
    pub ds_intercepts: u64,
    pub gc_episodes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_writebacks: u64,
    pub ras_retries: u64,
    pub ras_failovers: u64,
    pub throttle_waits: u64,
    pub backpressure: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::ControllerKind;
    use crate::media::{DramModel, DramTimings, SsdModel, SsdParams};

    fn complex(nports: usize) -> RootComplex {
        let ports = (0..nports)
            .map(|i| {
                RootPort::new(
                    i,
                    ControllerKind::Panmnesia,
                    EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
                    SrPolicy::Off,
                    false,
                    0,
                )
            })
            .collect();
        let mut rc = RootComplex::new(ports);
        rc.enumerate(64 << 20).unwrap();
        rc
    }

    /// Alternating DRAM/SSD ports (the hybrid topology).
    fn hybrid(nports: usize) -> RootComplex {
        let ports = (0..nports)
            .map(|i| {
                let ep = if i % 2 == 0 {
                    EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600()))
                } else {
                    EpBackend::Ssd(SsdModel::new(SsdParams::znand()))
                };
                RootPort::new(i, ControllerKind::Panmnesia, ep, SrPolicy::Off, false, 0)
            })
            .collect();
        RootComplex::new(ports)
    }

    #[test]
    fn enumerate_partitions_evenly() {
        let rc = complex(4);
        assert_eq!(rc.hdm.entries().len(), 4);
        assert_eq!(rc.hdm.total_size(), 64 << 20);
        assert_eq!(rc.hdm.decode(0).unwrap().0, 0);
        assert_eq!(rc.hdm.decode(16 << 20).unwrap().0, 1);
        assert_eq!(rc.hdm.decode(63 << 20).unwrap().0, 3);
    }

    #[test]
    fn loads_route_to_the_right_port() {
        let mut rc = complex(2);
        rc.load(0, 0, 64);
        rc.load(0, 32 << 20, 64);
        assert_eq!(rc.ports[0].stats.loads, 1);
        assert_eq!(rc.ports[1].stats.loads, 1);
    }

    #[test]
    fn bridge_latency_is_added() {
        let mut rc = complex(1);
        let with_bridge = rc.load(0, 0x100, 64).done;
        let mut port = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        );
        let without = port.load(0, 0x100, 64).done;
        assert_eq!(with_bridge, without + 2 * rc.bridge_lat);
    }

    #[test]
    #[should_panic(expected = "HDM decode miss")]
    fn out_of_range_panics() {
        let mut rc = complex(1);
        rc.load(0, 128 << 20, 64);
    }

    #[test]
    fn interleaved_enumeration_splits_tiers_dram_first() {
        let mut rc = hybrid(4);
        let total = 64u64 << 20;
        let fast = rc.enumerate_interleaved(total, 12).unwrap();
        assert_eq!(fast, 32 << 20, "2 of 4 ports are DRAM: half the space is fast");
        assert_eq!(rc.hdm.total_size(), total, "decode space must cover the expander");
        // Bottom half stripes over the DRAM ports (0, 2), top half over
        // the SSD ports (1, 3).
        assert_eq!(rc.hdm.decode(0).unwrap().0, 0);
        assert_eq!(rc.hdm.decode(4 << 10).unwrap().0, 2);
        let (p_lo, _) = rc.hdm.decode(32 << 20).unwrap();
        assert!(p_lo == 1 || p_lo == 3);
        for probe in 0..64u64 {
            let (p, _) = rc.hdm.decode(probe * (1 << 20)).unwrap();
            if probe < 32 {
                assert!(p % 2 == 0, "fast half decoded to SSD port {p}");
            } else {
                assert!(p % 2 == 1, "slow half decoded to DRAM port {p}");
            }
        }
    }

    #[test]
    fn interleaved_enumeration_stripes_bandwidth() {
        let mut rc = hybrid(4);
        rc.enumerate_interleaved(64 << 20, 12).unwrap();
        // A dense 64 KiB scan of the fast tier must hit both DRAM ports.
        for g in 0..16u64 {
            rc.load(0, g * 4096, 64);
        }
        assert_eq!(rc.ports[0].stats.loads, 8);
        assert_eq!(rc.ports[2].stats.loads, 8);
    }

    #[test]
    fn unaligned_group_tail_does_not_alias_device_addresses() {
        let mut rc = hybrid(4); // DRAM ports 0/2, SSD ports 1/3
        // Fast share = 1 MiB + 4 KiB: the 4 KiB tail can't stripe over
        // the two DRAM ports, so it becomes a direct window on port 0 —
        // whose DPAs must continue past the bulk window's per-way span
        // (512 KiB), not restart at zero.
        let total = (2 << 20) + (8 << 10);
        let fast = rc.enumerate_interleaved(total, 12).unwrap();
        assert_eq!(fast, (1 << 20) + (4 << 10));
        assert_eq!(rc.hdm.total_size(), total, "decode space must cover the expander");
        assert_eq!(rc.hdm.decode(0), Some((0, 0)));
        // Tail starts at the stripe-aligned bulk's end (1 MiB).
        let (pt, dpat) = rc.hdm.decode(1 << 20).unwrap();
        assert_eq!(pt, 0, "tail stays on the group's first port");
        assert_eq!(
            dpat,
            (1 << 20) / 2,
            "tail DPAs continue past the bulk per-way span"
        );
    }

    #[test]
    fn all_dram_group_interleaves_every_port() {
        let mut rc = complex(4);
        rc.hdm = HdmDecoder::new();
        let fast = rc.enumerate_interleaved(64 << 20, 12).unwrap();
        assert_eq!(fast, 64 << 20, "homogeneous DRAM: everything is fast tier");
        let mut seen = [false; 4];
        for g in 0..8u64 {
            seen[rc.hdm.decode(g * 4096).unwrap().0] = true;
        }
        assert!(seen.iter().all(|&s| s), "4-way stripe must touch all ports: {seen:?}");
    }

    #[test]
    fn tiered_migration_moves_hot_page_to_dram_and_charges_ports() {
        let mut rc = hybrid(2); // port 0 DRAM, port 1 SSD
        let total = 4u64 << 20;
        let fast = rc.enumerate_interleaved(total, 12).unwrap();
        assert_eq!(fast, 2 << 20);
        let cfg = TierConfig { enabled: true, migrate: true, ..TierConfig::default() };
        rc.attach_tiering(cfg, fast, total);
        let mut rng = Pcg32::new(9, 9);
        // Hammer one slow-tier page.
        let hot = 3u64 << 20;
        for i in 0..32 {
            rc.load(i * 1000, hot + (i % 4) * 64, 64);
        }
        assert!(rc.ports[1].stats.loads > 0, "hot page starts on the SSD port");
        let before = rc.ports[0].stats.migrations + rc.ports[1].stats.migrations;
        assert_eq!(before, 0);
        rc.tier_tick(1_000_000, &mut rng);
        let t = rc.tier.as_ref().unwrap();
        assert_eq!(t.stats.promotions, 1);
        assert!(rc.ports[0].stats.migrations > 0, "DRAM port must absorb the migration");
        assert!(rc.ports[1].stats.migrations > 0, "SSD port must source the migration");
        // Post-migration, the same HPA routes to the DRAM port.
        let dram_loads = rc.ports[0].stats.loads;
        rc.load(10_000_000, hot, 64);
        assert_eq!(rc.ports[0].stats.loads, dram_loads + 1);
    }

    #[test]
    fn tier_swaps_are_vetoed_onto_a_degraded_port() {
        use crate::ras::{FaultSpec, RasState};
        let mut rc = hybrid(2); // port 0 DRAM (fast), port 1 SSD (slow)
        let total = 4u64 << 20;
        let fast = rc.enumerate_interleaved(total, 12).unwrap();
        let cfg = TierConfig { enabled: true, migrate: true, ..TierConfig::default() };
        rc.attach_tiering(cfg, fast, total);
        let spec = FaultSpec {
            enabled: true,
            degrade_at: 1,
            degrade_port: 0,
            ..FaultSpec::default()
        };
        rc.ports[0].ras = RasState::new(spec, 42, 0);
        let mut rng = Pcg32::new(9, 9);
        // Hammer one slow-tier page so the epoch plans a promotion.
        let hot = 3u64 << 20;
        for i in 0..32 {
            rc.load(i * 1000, hot + (i % 4) * 64, 64);
        }
        // An access past the deadline latches the fast port's degradation.
        rc.load(500_000, 0, 64);
        assert!(rc.ports[0].is_degraded());
        rc.tier_tick(1_000_000, &mut rng);
        let t = rc.tier.as_ref().unwrap();
        assert_eq!(t.stats.promotions, 0, "no page may move onto the degraded port");
        assert_eq!(rc.ports[0].stats.migrations, 0);
        assert_eq!(rc.ports[1].stats.migrations, 0);
        let r = rc.ports[0].ras.as_ref().unwrap();
        assert!(r.stats.failovers >= 2, "degrade latch + swap veto both count");
    }

    #[test]
    fn fabric_attachment_routes_decodes_through_the_switch() {
        use crate::fabric::{CxlSwitch, FabricSpec};
        use std::sync::{Arc, Mutex};
        // Direct topology as the reference.
        let mut direct = complex(2);
        // Same two endpoints behind a single-upstream, no-QoS switch:
        // the passthrough invariant says identical completion times.
        let eps = (0..2)
            .map(|i| {
                RootPort::new(
                    i,
                    ControllerKind::Panmnesia,
                    EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
                    SrPolicy::Off,
                    false,
                    0,
                )
            })
            .collect();
        let link = Arc::new(Mutex::new(CxlSwitch::new(
            eps,
            FabricSpec { enabled: true, ..FabricSpec::default() },
            &[1],
        )));
        let mut rc = RootComplex::new(Vec::new());
        rc.attach_fabric(link.clone(), 0);
        rc.enumerate_fabric(64 << 20, 0).unwrap();
        assert!(rc.targets().iter().all(|t| matches!(t, PortTarget::Fabric(_))));
        assert_eq!(rc.hdm.total_size(), direct.hdm.total_size());
        for addr in [0u64, 1 << 20, 33 << 20, (64 << 20) - 64] {
            let a = rc.load(0, addr, 64).done;
            let b = direct.load(0, addr, 64).done;
            assert_eq!(a, b, "passthrough fabric diverged at {addr:#x}");
        }
        let sw = link.lock().unwrap();
        assert_eq!(sw.pool_sums().loads, 4);
        assert!(sw.downstream[0].stats.loads > 0 && sw.downstream[1].stats.loads > 0);
    }

    #[test]
    fn enumerate_rejects_portless_topologies_with_a_message() {
        let mut rc = RootComplex::new(Vec::new());
        let err = rc.enumerate(64 << 20).unwrap_err();
        assert!(err.contains("no ports"), "unhelpful error: {err}");
        let err = rc.enumerate_interleaved(64 << 20, 12).unwrap_err();
        assert!(err.contains("no ports"), "unhelpful error: {err}");
        let err = rc.enumerate_fabric(64 << 20, 0).unwrap_err();
        assert!(err.contains("no fabric"), "unhelpful error: {err}");
    }

    #[test]
    fn static_tiering_counts_but_never_migrates() {
        let mut rc = hybrid(2);
        let total = 4u64 << 20;
        let fast = rc.enumerate_interleaved(total, 12).unwrap();
        let cfg = TierConfig { enabled: true, migrate: false, ..TierConfig::default() };
        rc.attach_tiering(cfg, fast, total);
        for i in 0..32 {
            rc.load(i * 1000, (3u64 << 20) + (i % 4) * 64, 64);
        }
        // The ablation never ticks; placement stays frozen.
        let t = rc.tier.as_ref().unwrap();
        assert_eq!(t.stats.promotions, 0);
        assert!(t.stats.slow_accesses > 0);
    }
}
