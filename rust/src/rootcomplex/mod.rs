//! The GPU's CXL root complex (Fig. 5): host bridge + HDM decoder +
//! multiple root ports, each fronting a DRAM- or SSD-backed endpoint.
//!
//! This module is the paper's *system contribution*: the piece that lets
//! GPU compute units reach memory expanders with plain loads/stores, no
//! host intervention — plus the two controller optimizations, SR
//! ([`spec_read`]) and DS ([`det_store`]).

pub mod det_store;
pub mod hdm;
pub mod rbtree;
pub mod rootport;
pub mod spec_read;

pub use det_store::{DetStoreEngine, DsStats, StoreAction};
pub use hdm::{HdmDecoder, HdmEntry};
pub use rbtree::RbTree;
pub use rootport::{EpBackend, LoadOutcome, LoadPath, PortStats, RootPort, StoreOutcome};
pub use spec_read::{SpecReadEngine, SrPolicy, SrStats};

use crate::sim::{Time, NS};
use crate::util::prng::Pcg32;

/// The root complex: host-bridge decode + port fan-out.
#[derive(Debug)]
pub struct RootComplex {
    pub hdm: HdmDecoder,
    pub ports: Vec<RootPort>,
    /// Host-bridge + HDM-decode traversal cost.
    pub bridge_lat: Time,
}

impl RootComplex {
    pub fn new(ports: Vec<RootPort>) -> RootComplex {
        RootComplex { hdm: HdmDecoder::new(), ports, bridge_lat: 2 * NS }
    }

    /// Firmware init: carve the HDM space evenly across ports (the
    /// simplified core's enumeration pass). `total` bytes of expander.
    pub fn enumerate(&mut self, total: u64) -> Result<(), String> {
        let n = self.ports.len() as u64;
        assert!(n > 0);
        let per = total / n;
        self.enumerate_sized(&vec![per; n as usize])
    }

    /// Firmware init against per-port HDM sizes, walking each EP's
    /// CXL.io configuration space exactly as the paper's simplified core
    /// does: read identity + HDM capability registers over CXL.io,
    /// reject non-HDM devices, then program base/size into the host
    /// bridge's decoder in port order.
    pub fn enumerate_sized(&mut self, sizes: &[u64]) -> Result<(), String> {
        use crate::cxl::ConfigSpace;
        if sizes.len() != self.ports.len() {
            return Err(format!(
                "{} sizes for {} ports",
                sizes.len(),
                self.ports.len()
            ));
        }
        let mut base = 0;
        for (i, port) in self.ports.iter().enumerate() {
            let media = port.backend.kind();
            let raw = if media.is_ssd() {
                ConfigSpace::ssd_ep(sizes[i], media)
            } else {
                ConfigSpace::dram_ep(sizes[i])
            };
            // CXL.io config read round trip (4 dwords), as firmware sees it.
            let cs = ConfigSpace::from_dwords(
                raw.read_dword(0),
                raw.read_dword(1),
                raw.read_dword(2),
                raw.read_dword(3),
                media,
            );
            if !cs.is_hdm_capable() {
                return Err(format!("port {i}: EP is not HDM-capable"));
            }
            self.hdm.program(HdmEntry { port: i, base, size: cs.hdm_size })?;
            base += cs.hdm_size;
        }
        Ok(())
    }

    /// Route a load at HDM-relative address `hpa_off`.
    pub fn load(&mut self, now: Time, hpa_off: u64, len: u64) -> LoadOutcome {
        let (port, off) = self
            .hdm
            .decode(hpa_off)
            .unwrap_or_else(|| panic!("HDM decode miss at {:#x}", hpa_off));
        let mut out = self.ports[port].load(now + self.bridge_lat, off, len);
        out.done += self.bridge_lat;
        out
    }

    /// Route a store at HDM-relative address `hpa_off`.
    pub fn store(&mut self, now: Time, hpa_off: u64, len: u64, rng: &mut Pcg32) -> StoreOutcome {
        let (port, off) = self
            .hdm
            .decode(hpa_off)
            .unwrap_or_else(|| panic!("HDM decode miss at {:#x}", hpa_off));
        let mut out = self.ports[port].store(now + self.bridge_lat, off, len, rng);
        out.ack += self.bridge_lat;
        out
    }

    /// Background DS flush across ports.
    pub fn flush_tick(&mut self, now: Time, rng: &mut Pcg32) {
        for p in &mut self.ports {
            p.flush_step(now, 8, rng);
        }
    }

    /// Total buffered DS bytes (for end-of-run draining checks).
    pub fn ds_backlog(&self) -> u64 {
        self.ports.iter().map(|p| p.ds.buffered_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::ControllerKind;
    use crate::media::{DramModel, DramTimings};

    fn complex(nports: usize) -> RootComplex {
        let ports = (0..nports)
            .map(|i| {
                RootPort::new(
                    i,
                    ControllerKind::Panmnesia,
                    EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
                    SrPolicy::Off,
                    false,
                    0,
                )
            })
            .collect();
        let mut rc = RootComplex::new(ports);
        rc.enumerate(64 << 20).unwrap();
        rc
    }

    #[test]
    fn enumerate_partitions_evenly() {
        let rc = complex(4);
        assert_eq!(rc.hdm.entries().len(), 4);
        assert_eq!(rc.hdm.total_size(), 64 << 20);
        assert_eq!(rc.hdm.decode(0).unwrap().0, 0);
        assert_eq!(rc.hdm.decode(16 << 20).unwrap().0, 1);
        assert_eq!(rc.hdm.decode(63 << 20).unwrap().0, 3);
    }

    #[test]
    fn loads_route_to_the_right_port() {
        let mut rc = complex(2);
        rc.load(0, 0, 64);
        rc.load(0, 32 << 20, 64);
        assert_eq!(rc.ports[0].stats.loads, 1);
        assert_eq!(rc.ports[1].stats.loads, 1);
    }

    #[test]
    fn bridge_latency_is_added() {
        let mut rc = complex(1);
        let with_bridge = rc.load(0, 0x100, 64).done;
        let mut port = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        );
        let without = port.load(0, 0x100, 64).done;
        assert_eq!(with_bridge, without + 2 * rc.bridge_lat);
    }

    #[test]
    #[should_panic(expected = "HDM decode miss")]
    fn out_of_range_panics() {
        let mut rc = complex(1);
        rc.load(0, 128 << 20, 64);
    }
}
