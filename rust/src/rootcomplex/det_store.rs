//! Deterministic Store (DS) engine (Fig. 8).
//!
//! Stores to an SSD EP are acknowledged at GPU-local-memory speed: the
//! request is sent concurrently to GPU memory and the SSD and released
//! immediately ("fire and forget"). When the SSD reports congestion or an
//! internal task through DevLoad, incoming stores are absorbed into a
//! stack in reserved GPU memory instead; each entry's location is tracked
//! in the system bus's internal SRAM as a red-black tree. A background
//! flush drains the stack once the EP recovers, and demand reads are
//! intercepted: if the address sits in the buffer, the read is served
//! from GPU memory, bypassing the congested backend entirely.

use crate::cxl::DevLoad;
use crate::gpu::line_of;
use crate::sim::Time;

use super::rbtree::RbTree;

/// What the root complex must do with an incoming store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreAction {
    /// Mirror to GPU memory and forward to the EP now (fast ack).
    DualWrite,
    /// Absorb into the GPU-memory stack only (EP congested); a background
    /// flush will forward it later.
    Buffer,
    /// Reserved region exhausted: the store must block on the EP (tail
    /// case the paper accepts as unavoidable).
    Block,
}

#[derive(Debug, Clone, Default)]
pub struct DsStats {
    pub stores_seen: u64,
    pub dual_writes: u64,
    pub buffered: u64,
    pub blocked: u64,
    pub flushed: u64,
    pub read_intercepts: u64,
    pub max_stack_bytes: u64,
    /// Entries dropped because a page migration subsumed them
    /// ([`DetStoreEngine::invalidate_range`]).
    pub invalidated: u64,
}

/// The per-port DS engine.
#[derive(Debug, Default)]
pub struct DetStoreEngine {
    pub enabled: bool,
    /// Reserved GPU-memory capacity for the stack, bytes.
    capacity: u64,
    /// Current buffered bytes.
    stack_bytes: u64,
    /// Stack entries (LIFO order), line address + bytes.
    stack: Vec<(u64, u64)>,
    /// SRAM address list: line -> buffered bytes (red-black tree).
    sram: RbTree<u64>,
    pub stats: DsStats,
}

impl DetStoreEngine {
    pub fn new(enabled: bool, capacity: u64) -> DetStoreEngine {
        DetStoreEngine {
            enabled,
            capacity,
            stack_bytes: 0,
            stack: Vec::new(),
            sram: RbTree::new(),
            stats: DsStats::default(),
        }
    }

    pub fn buffered_bytes(&self) -> u64 {
        self.stack_bytes
    }

    pub fn buffered_entries(&self) -> usize {
        self.sram.len()
    }

    /// Classify an incoming store given the EP's telemetry.
    pub fn on_store(&mut self, _now: Time, addr: u64, len: u64, devload: DevLoad) -> StoreAction {
        self.stats.stores_seen += 1;
        if !self.enabled {
            // Without DS every store behaves like a dual write whose ack
            // still waits on the EP — the caller models that.
            return StoreAction::DualWrite;
        }
        let line = line_of(addr);
        // Re-buffering an already-buffered line just updates it in place.
        if self.sram.contains(line) {
            self.stats.buffered += 1;
            return StoreAction::Buffer;
        }
        // Buffer only on Severe: the paper diverts writes when DevLoad
        // indicates congestion or an announced internal task; buffering
        // at Moderate would starve the EP of writes it can still absorb.
        if devload == DevLoad::Severe {
            if self.stack_bytes + len > self.capacity {
                self.stats.blocked += 1;
                return StoreAction::Block;
            }
            self.push(line, len);
            self.stats.buffered += 1;
            StoreAction::Buffer
        } else {
            self.stats.dual_writes += 1;
            StoreAction::DualWrite
        }
    }

    fn push(&mut self, line: u64, len: u64) {
        self.stack.push((line, len));
        self.stack_bytes += len;
        self.sram.insert(line, len);
        self.stats.max_stack_bytes = self.stats.max_stack_bytes.max(self.stack_bytes);
    }

    /// Does a read at `addr` hit the buffer? (Served from GPU memory.)
    pub fn intercept_read(&mut self, addr: u64) -> bool {
        let hit = self.sram.contains(line_of(addr));
        if hit {
            self.stats.read_intercepts += 1;
        }
        hit
    }

    /// Fill `out` with up to `max` entries for a background flush, in
    /// ascending address order (friendlier to the flash translation layer
    /// than the LIFO stack order). `out` is cleared first and its
    /// capacity reused — the flush tick fires every 10 µs of sim time, so
    /// a fresh `Vec` per tick was the DS path's last steady-state
    /// allocation. Entries stay tracked until `flush_done`.
    pub fn flush_batch_into(&mut self, max: usize, out: &mut Vec<(u64, u64)>) {
        out.clear();
        let mut key = 0u64;
        while out.len() < max {
            match self.sram.ceiling(key) {
                Some(k) => {
                    let len = *self.sram.get(k).unwrap();
                    out.push((k, len));
                    key = k + 1;
                }
                None => break,
            }
        }
    }

    /// A flushed entry has reached the EP: drop it from the stack/SRAM.
    pub fn flush_done(&mut self, line: u64) {
        if let Some(len) = self.sram.remove(line) {
            self.stack_bytes -= len;
            self.stats.flushed += 1;
            // Lazy stack compaction: remove a matching entry.
            if let Some(pos) = self.stack.iter().rposition(|&(l, _)| l == line) {
                self.stack.swap_remove(pos);
            }
        }
    }

    /// Drop every buffered line whose address falls in `[lo, hi)`.
    ///
    /// Used by the tiering engine when it migrates the underlying frame:
    /// the page copy carries the freshest (GPU-memory-resident) data to
    /// the page's new location, so the buffered entries are subsumed by
    /// the migration transfer — and after the frame swap the same device
    /// addresses belong to a *different* page, which stale entries must
    /// not intercept. Returns the bytes dropped.
    pub fn invalidate_range(&mut self, lo: u64, hi: u64) -> u64 {
        let mut dropped = 0;
        while let Some(line) = self.sram.ceiling(lo) {
            if line >= hi {
                break;
            }
            let len = self.sram.remove(line).expect("ceiling key present");
            self.stack_bytes -= len;
            dropped += len;
            self.stats.invalidated += 1;
            if let Some(pos) = self.stack.iter().rposition(|&(l, _)| l == line) {
                self.stack.swap_remove(pos);
            }
        }
        dropped
    }

    /// Consistency probe for property tests: buffered accounting matches.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.sram.check_invariants().map_err(|e| format!("sram rbtree: {e}"))?;
        if self.sram.len() != self.stack.len() {
            return Err(format!(
                "sram has {} entries but stack has {}",
                self.sram.len(),
                self.stack.len()
            ));
        }
        let sum: u64 = self.stack.iter().map(|&(_, l)| l).sum();
        if sum != self.stack_bytes {
            return Err(format!("stack bytes {sum} != accounted {}", self.stack_bytes));
        }
        if self.stack_bytes > self.capacity {
            return Err("stack exceeds reserved capacity".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> DetStoreEngine {
        DetStoreEngine::new(true, 1 << 20)
    }

    #[test]
    fn healthy_ep_gets_dual_writes() {
        let mut e = engine();
        assert_eq!(e.on_store(0, 0x40, 64, DevLoad::Light), StoreAction::DualWrite);
        assert_eq!(e.on_store(0, 0x80, 64, DevLoad::Optimal), StoreAction::DualWrite);
        assert_eq!(e.buffered_entries(), 0);
    }

    #[test]
    fn overloaded_ep_buffers() {
        let mut e = engine();
        assert_eq!(e.on_store(0, 0x100, 64, DevLoad::Severe), StoreAction::Buffer);
        assert_eq!(e.buffered_entries(), 1);
        assert_eq!(e.buffered_bytes(), 64);
        e.check_invariants().unwrap();
    }

    #[test]
    fn rewrites_to_buffered_line_merge() {
        let mut e = engine();
        e.on_store(0, 0x100, 64, DevLoad::Severe);
        e.on_store(1, 0x100, 64, DevLoad::Severe);
        assert_eq!(e.buffered_entries(), 1, "same line buffers once");
        e.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhaustion_blocks() {
        let mut e = DetStoreEngine::new(true, 128);
        assert_eq!(e.on_store(0, 0x0, 64, DevLoad::Severe), StoreAction::Buffer);
        assert_eq!(e.on_store(0, 0x40, 64, DevLoad::Severe), StoreAction::Buffer);
        assert_eq!(e.on_store(0, 0x80, 64, DevLoad::Severe), StoreAction::Block);
        assert_eq!(e.stats.blocked, 1);
    }

    #[test]
    fn reads_intercepted_while_buffered() {
        let mut e = engine();
        e.on_store(0, 0x2000, 64, DevLoad::Severe);
        assert!(e.intercept_read(0x2020), "same line, different offset");
        assert!(!e.intercept_read(0x3000));
        assert_eq!(e.stats.read_intercepts, 1);
    }

    #[test]
    fn flush_drains_in_address_order() {
        let mut e = engine();
        for addr in [0x300u64, 0x100, 0x200] {
            e.on_store(0, addr, 64, DevLoad::Severe);
        }
        let mut batch = Vec::new();
        e.flush_batch_into(10, &mut batch);
        let addrs: Vec<u64> = batch.iter().map(|&(a, _)| a).collect();
        assert_eq!(addrs, vec![0x100, 0x200, 0x300]);
        for &(line, _) in &batch {
            e.flush_done(line);
        }
        assert_eq!(e.buffered_entries(), 0);
        assert_eq!(e.buffered_bytes(), 0);
        assert!(!e.intercept_read(0x100), "flushed entries no longer intercept");
        e.check_invariants().unwrap();
    }

    #[test]
    fn flush_batch_respects_max_and_reuses_buffer() {
        let mut e = engine();
        for i in 0..10u64 {
            e.on_store(0, i * 64, 64, DevLoad::Severe);
        }
        let mut batch = vec![(0xdead, 0xbeef)]; // stale content must be cleared
        e.flush_batch_into(4, &mut batch);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0], (0x0, 64));
        e.flush_batch_into(0, &mut batch);
        assert!(batch.is_empty(), "max=0 leaves a cleared buffer");
    }

    #[test]
    fn invalidate_range_drops_only_covered_lines() {
        let mut e = engine();
        for addr in [0x1000u64, 0x2000, 0x3000] {
            e.on_store(0, addr, 64, DevLoad::Severe);
        }
        let dropped = e.invalidate_range(0x1000, 0x3000);
        assert_eq!(dropped, 128, "two 64 B lines covered");
        assert_eq!(e.buffered_entries(), 1);
        assert_eq!(e.buffered_bytes(), 64);
        assert!(!e.intercept_read(0x1000), "invalidated line must not intercept");
        assert!(!e.intercept_read(0x2000));
        assert!(e.intercept_read(0x3000), "uncovered line survives");
        assert_eq!(e.stats.invalidated, 2);
        e.check_invariants().unwrap();
        // Empty range is a no-op.
        assert_eq!(e.invalidate_range(0x5000, 0x6000), 0);
    }

    #[test]
    fn disabled_engine_never_buffers() {
        let mut e = DetStoreEngine::new(false, 1 << 20);
        assert_eq!(e.on_store(0, 0x0, 64, DevLoad::Severe), StoreAction::DualWrite);
        assert_eq!(e.buffered_entries(), 0);
    }
}
