//! Hot-page tiering across heterogeneous root ports.
//!
//! The paper's headline topology — one host bridge fronting "DRAMs
//! and/or SSDs" — only pays off if hot data lives on the DRAM ports and
//! cold capacity spills to the SSD ports. A static HDM split (the
//! `cxl-hybrid` configuration) freezes that placement at enumeration
//! time; this module makes it adaptive:
//!
//! * **Tracker** — the decode path bumps a per-page access counter
//!   ([`Tiering::translate`]); counters are epoch-scoped and reset after
//!   every scan, so hotness is *recent* hotness.
//! * **Migration engine** — at each epoch tick the tracker pairs the
//!   hottest slow-tier (SSD-resident) pages with the coldest fast-tier
//!   (DRAM-resident) pages and swaps them. A swap moves both pages
//!   through the real port machinery ([`super::RootPort::migrate`]), so
//!   migration traffic occupies memory-queue slots and media bandwidth —
//!   it delays demand requests exactly the way a DMA engine would, no
//!   free lunch.
//!
//! Placement is a page→frame permutation: HPA page `p` lives in frame
//! `page_frame[p]`, and the frame address (not the HPA) is what the HDM
//! decoder routes. Frames below [`Tiering::fast_bytes`] decode to the
//! DRAM interleave set; the permutation starts as identity and every
//! swap transposes two entries, so it stays a bijection — capacity on
//! each tier is conserved by construction.
//!
//! Determinism and allocation discipline: decisions depend only on
//! counters and sim time (no wall clock, no randomness beyond the
//! System's seeded RNG used for SSD write jitter), and epoch scans reuse
//! the `hot`/`cold`/`moves` scratch vectors — after the first epoch the
//! steady state allocates nothing (DESIGN.md §7, §12).

use crate::sim::{Time, US};

/// Tiering knobs carried by `SystemConfig` (`coordinator/config.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierConfig {
    /// Build the tiering subsystem (interleaved hybrid enumeration,
    /// tracker, remap table). Off for every pre-tiering configuration.
    pub enabled: bool,
    /// Run the migration engine. `false` is the `cxl-tier-static`
    /// ablation: same topology and tracker, placement frozen.
    pub migrate: bool,
    /// Migration unit (power of two). 16 KiB matches the UVM block: big
    /// enough to amortize per-transfer protocol cost, small enough that
    /// one swap doesn't monopolize a port.
    pub page_bytes: u64,
    /// Epoch length between scans of the access counters.
    pub epoch: Time,
    /// Minimum per-epoch accesses before a slow-tier page is a promotion
    /// candidate.
    pub promote_min: u32,
    /// Migration budget: page swaps per epoch.
    pub max_moves: usize,
    /// HDM interleave granularity (IG, log2 bytes) used when enumerating
    /// the tiered topology.
    pub gran_bits: u32,
}

impl Default for TierConfig {
    fn default() -> TierConfig {
        TierConfig {
            enabled: false,
            migrate: false,
            page_bytes: 16 << 10,
            epoch: 100 * US,
            promote_min: 4,
            max_moves: 8,
            gran_bits: 12,
        }
    }
}

/// Counters the tiering subsystem exports into `RunMetrics`.
#[derive(Debug, Clone, Copy, Default)]
pub struct TierStats {
    /// Pages moved slow→fast.
    pub promotions: u64,
    /// Pages moved fast→slow (always equal to promotions: swaps).
    pub demotions: u64,
    /// Bytes transferred by the migration engine (both directions).
    pub migrated_bytes: u64,
    /// Decoded accesses that landed on a fast-tier frame.
    pub fast_accesses: u64,
    /// Decoded accesses that landed on a slow-tier frame.
    pub slow_accesses: u64,
    /// Epoch scans performed.
    pub epochs: u64,
}

/// Epoch-based hot-page tracker + page→frame remap table.
#[derive(Debug)]
pub struct Tiering {
    cfg: TierConfig,
    page_shift: u32,
    page_mask: u64,
    /// Pages fully covered by the remap table; the tail of the decode
    /// space past `n_pages * page_bytes` passes through untranslated.
    n_pages: usize,
    /// Frames strictly below this index decode into the fast (DRAM)
    /// interleave set.
    fast_frames: u32,
    /// Bytes of fast tier at the bottom of the decoded space.
    pub fast_bytes: u64,
    /// page → frame permutation (identity at enumeration).
    page_frame: Vec<u32>,
    /// frame → page inverse, kept in lock-step.
    frame_page: Vec<u32>,
    /// Per-page accesses this epoch.
    counts: Vec<u32>,
    /// Scratch: (count, page) promotion candidates, hottest first.
    hot: Vec<(u32, u32)>,
    /// Scratch: (count, page) fast-tier residents, coldest first.
    cold: Vec<(u32, u32)>,
    /// Scratch: planned (hot_page, cold_page) swaps for this epoch.
    moves: Vec<(u32, u32)>,
    move_cursor: usize,
    pub stats: TierStats,
}

impl Tiering {
    /// Tracker over `total` decoded bytes of which the first
    /// `fast_bytes` decode to the fast tier.
    pub fn new(cfg: TierConfig, fast_bytes: u64, total: u64) -> Tiering {
        assert!(cfg.page_bytes.is_power_of_two(), "tier page must be a power of two");
        let page_shift = cfg.page_bytes.trailing_zeros();
        let n_pages = (total >> page_shift) as usize;
        Tiering {
            cfg,
            page_shift,
            page_mask: cfg.page_bytes - 1,
            n_pages,
            fast_frames: (fast_bytes >> page_shift) as u32,
            fast_bytes,
            page_frame: (0..n_pages as u32).collect(),
            frame_page: (0..n_pages as u32).collect(),
            counts: vec![0; n_pages],
            hot: Vec::new(),
            cold: Vec::new(),
            moves: Vec::new(),
            move_cursor: 0,
            stats: TierStats::default(),
        }
    }

    pub fn config(&self) -> &TierConfig {
        &self.cfg
    }

    /// Translate a decode-space address through the page remap, counting
    /// the access. Hot path: shift/mask plus two array reads.
    pub fn translate(&mut self, hpa: u64) -> u64 {
        let page = (hpa >> self.page_shift) as usize;
        if page >= self.n_pages {
            return hpa;
        }
        self.counts[page] = self.counts[page].saturating_add(1);
        let frame = self.page_frame[page];
        if frame < self.fast_frames {
            self.stats.fast_accesses += 1;
        } else {
            self.stats.slow_accesses += 1;
        }
        ((frame as u64) << self.page_shift) | (hpa & self.page_mask)
    }

    /// Current frame base address of `page` (decode-space bytes).
    pub fn frame_base(&self, page: u32) -> u64 {
        (self.page_frame[page as usize] as u64) << self.page_shift
    }

    /// Whether `page` currently resides on the fast tier.
    pub fn on_fast_tier(&self, page: u32) -> bool {
        self.page_frame[page as usize] < self.fast_frames
    }

    /// Epoch boundary: rank pages, plan this epoch's swaps, reset the
    /// counters. Scratch vectors are reused — no steady-state allocation.
    pub fn plan_epoch(&mut self) {
        self.stats.epochs += 1;
        self.hot.clear();
        self.cold.clear();
        self.moves.clear();
        self.move_cursor = 0;
        for page in 0..self.n_pages {
            let c = self.counts[page];
            if self.page_frame[page] < self.fast_frames {
                self.cold.push((c, page as u32));
            } else if c >= self.cfg.promote_min {
                self.hot.push((c, page as u32));
            }
        }
        // Hottest slow pages first; coldest fast pages first. Ties break
        // on page index so the plan is independent of scan incidentals.
        self.hot.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        self.cold.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        let n = self.hot.len().min(self.cold.len()).min(self.cfg.max_moves);
        for k in 0..n {
            let (hc, hp) = self.hot[k];
            let (cc, cp) = self.cold[k];
            // Swap only when clearly profitable; a 2x margin damps
            // ping-pong between pages of similar temperature.
            if hc <= cc.saturating_mul(2) {
                break;
            }
            self.moves.push((hp, cp));
        }
        self.counts.fill(0);
    }

    /// Next planned swap of the current epoch, if any.
    pub fn pop_move(&mut self) -> Option<(u32, u32)> {
        let m = self.moves.get(self.move_cursor).copied();
        self.move_cursor += m.is_some() as usize;
        m
    }

    /// Transpose the two pages' frames after their data has been moved.
    pub fn commit_swap(&mut self, hot_page: u32, cold_page: u32) {
        let hf = self.page_frame[hot_page as usize];
        let cf = self.page_frame[cold_page as usize];
        self.page_frame[hot_page as usize] = cf;
        self.page_frame[cold_page as usize] = hf;
        self.frame_page[hf as usize] = cold_page;
        self.frame_page[cf as usize] = hot_page;
        self.stats.promotions += 1;
        self.stats.demotions += 1;
        self.stats.migrated_bytes += 2 * self.cfg.page_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiering(fast_pages: u64, total_pages: u64) -> Tiering {
        let cfg = TierConfig { enabled: true, migrate: true, ..TierConfig::default() };
        Tiering::new(cfg, fast_pages * cfg.page_bytes, total_pages * cfg.page_bytes)
    }

    #[test]
    fn identity_before_any_migration() {
        let mut t = tiering(4, 16);
        for hpa in [0u64, 0x3fff, 0x4000, (16 << 14) - 1] {
            assert_eq!(t.translate(hpa), hpa);
        }
        // Tail past the last whole page passes through.
        let tail = 16 * t.cfg.page_bytes + 5;
        assert_eq!(t.translate(tail), tail);
    }

    #[test]
    fn accesses_split_by_tier() {
        let mut t = tiering(4, 16);
        t.translate(0); // frame 0: fast
        t.translate(10 * t.cfg.page_bytes); // frame 10: slow
        assert_eq!(t.stats.fast_accesses, 1);
        assert_eq!(t.stats.slow_accesses, 1);
    }

    #[test]
    fn hot_slow_page_gets_promoted_over_cold_fast_page() {
        let mut t = tiering(4, 16);
        let page = t.cfg.page_bytes;
        // Page 9 (slow) is hammered; fast pages 0..4 stay cold.
        for _ in 0..50 {
            t.translate(9 * page);
        }
        t.plan_epoch();
        let (hot, cold) = t.pop_move().expect("one swap planned");
        assert_eq!(hot, 9);
        assert!(cold < 4, "victim must come from the fast tier, got {cold}");
        t.commit_swap(hot, cold);
        assert!(t.on_fast_tier(9));
        assert!(!t.on_fast_tier(cold));
        // The remap now routes page 9 into the victim's old frame.
        assert_eq!(t.translate(9 * page + 7), (cold as u64) * page + 7);
        assert_eq!(t.translate(cold as u64 * page), 9 * page);
        assert_eq!(t.stats.promotions, 1);
        assert_eq!(t.stats.demotions, 1);
        assert_eq!(t.stats.migrated_bytes, 2 * page);
    }

    #[test]
    fn lukewarm_pages_do_not_thrash() {
        let mut t = tiering(2, 4);
        let page = t.cfg.page_bytes;
        // Slow page 3 is no hotter than either fast resident: swapping
        // would only churn bandwidth, so no move may be planned.
        for _ in 0..10 {
            t.translate(3 * page);
            t.translate(0);
            t.translate(page);
        }
        t.plan_epoch();
        assert_eq!(t.pop_move(), None);
    }

    #[test]
    fn counts_reset_each_epoch() {
        let mut t = tiering(2, 8);
        let page = t.cfg.page_bytes;
        for _ in 0..50 {
            t.translate(5 * page);
        }
        t.plan_epoch();
        while let Some((h, c)) = t.pop_move() {
            t.commit_swap(h, c);
        }
        // Next epoch starts cold: nothing qualifies.
        t.plan_epoch();
        assert_eq!(t.pop_move(), None);
    }

    #[test]
    fn move_budget_is_respected() {
        let mut t = tiering(8, 32);
        let page = t.cfg.page_bytes;
        // Make every slow page hot.
        for p in 8..32u64 {
            for _ in 0..20 {
                t.translate(p * page);
            }
        }
        t.plan_epoch();
        let mut n = 0;
        while t.pop_move().is_some() {
            n += 1;
        }
        assert_eq!(n, t.cfg.max_moves);
    }

    #[test]
    fn permutation_stays_a_bijection() {
        let mut t = tiering(4, 16);
        let page = t.cfg.page_bytes;
        for round in 0..6u64 {
            for p in 4..16u64 {
                for _ in 0..(p + round) % 7 * 3 {
                    t.translate(p * page);
                }
            }
            t.plan_epoch();
            while let Some((h, c)) = t.pop_move() {
                t.commit_swap(h, c);
            }
            let mut seen = vec![false; 16];
            for p in 0..16u32 {
                let f = t.frame_base(p) / page;
                assert!(!seen[f as usize], "frame {f} mapped twice");
                seen[f as usize] = true;
                assert_eq!(t.frame_page[f as usize], p);
            }
        }
    }

    #[test]
    fn planning_is_deterministic() {
        let run = || {
            let mut t = tiering(4, 32);
            let page = t.cfg.page_bytes;
            for p in 4..32u64 {
                for _ in 0..(p * 7) % 13 {
                    t.translate(p * page);
                }
            }
            t.plan_epoch();
            let mut out = Vec::new();
            while let Some(m) = t.pop_move() {
                out.push(m);
            }
            out
        };
        assert_eq!(run(), run());
    }
}
