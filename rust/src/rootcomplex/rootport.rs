//! A CXL root port: queue logic + controller + endpoint.
//!
//! Each port (Fig. 5a) owns a [`CxlController`] pair (root-port side and
//! EP side share the latency model), a DRAM- or SSD-backed endpoint, the
//! SR engine, the DS engine, and the 32-entry memory queue that bounds
//! outstanding demand requests (backpressure to the LLC/MSHRs).

use std::collections::VecDeque;

use crate::cxl::{ControllerKind, CxlController, DevLoad, Flit, MemOpcode};
use crate::media::{DramModel, MediaKind, SsdModel};
use crate::sim::{Time, NS};
use crate::util::prng::Pcg32;
use crate::util::stats::Summary;

use super::det_store::{DetStoreEngine, StoreAction};
use super::spec_read::{SpecReadEngine, SrPolicy, MEM_QUEUE_CAP};

/// Endpoint backend behind a port.
#[derive(Debug)]
pub enum EpBackend {
    Dram(DramModel),
    Ssd(SsdModel),
}

impl EpBackend {
    pub fn kind(&self) -> MediaKind {
        match self {
            EpBackend::Dram(_) => MediaKind::Ddr5,
            EpBackend::Ssd(s) => s.kind(),
        }
    }

    pub fn is_ssd(&self) -> bool {
        matches!(self, EpBackend::Ssd(_))
    }
}

/// How a load was ultimately served (for hit-rate reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadPath {
    /// Served from the DS buffer in GPU local memory.
    DsIntercept,
    /// SSD internal DRAM cache hit (possibly SR-prefetched).
    EpCacheHit,
    /// Backend media access.
    Media,
}

/// Completed load description.
#[derive(Debug, Clone, Copy)]
pub struct LoadOutcome {
    pub done: Time,
    pub path: LoadPath,
}

/// Completed store description.
#[derive(Debug, Clone, Copy)]
pub struct StoreOutcome {
    /// When the SMs/LLC may consider the store retired.
    pub ack: Time,
    /// Whether the data still needs a background flush (DS buffered it).
    pub buffered: bool,
}

/// Per-port counters harvested into `RunMetrics` after a run.
#[derive(Debug, Default, Clone)]
pub struct PortStats {
    /// Demand loads serviced (including DS intercepts).
    pub loads: u64,
    /// Stores serviced (buffered, dual-written or blocked).
    pub stores: u64,
    /// End-to-end demand-load latency distribution.
    pub load_latency: Summary,
    /// Store ack latency distribution.
    pub store_latency: Summary,
    /// DevLoad observations in the Severe class.
    pub devload_severe_seen: u64,
    /// Requests that had to wait for a memory-queue slot.
    pub queue_full_waits: u64,
    /// Memory-queue occupancy high-water mark (including the admitted
    /// request), sampled at every slot acquisition.
    pub queue_hwm: u64,
    /// Background tiering transfers serviced ([`RootPort::migrate`]).
    pub migrations: u64,
}

/// One CXL root port with its endpoint.
#[derive(Debug)]
pub struct RootPort {
    /// Port index within the root complex (HDM decode target id).
    pub id: usize,
    /// The CXL controller pair's latency model (both link legs).
    pub ctrl: CxlController,
    /// The endpoint behind this port (DRAM- or SSD-backed).
    pub backend: EpBackend,
    /// Speculative Read engine (MemSpecRd hints into the EP cache).
    pub sr: SpecReadEngine,
    /// Deterministic Store engine (GPU-memory store buffering).
    pub ds: DetStoreEngine,
    /// Memory-queue slots: completion time of the request occupying each.
    slots: Vec<Time>,
    /// Recent outstanding demand addresses (SR window input).
    recent: VecDeque<u64>,
    /// Local-memory mirror latency used for DS acks and intercepts.
    pub local_ack: Time,
    /// Scratch for [`DetStoreEngine::flush_batch_into`]: one buffer
    /// reused across every `FlushTick` instead of a `Vec` per tick.
    flush_scratch: Vec<(u64, u64)>,
    pub stats: PortStats,
    req_id: u64,
}

impl RootPort {
    pub fn new(
        id: usize,
        kind: ControllerKind,
        backend: EpBackend,
        sr_policy: SrPolicy,
        ds_enabled: bool,
        ds_capacity: u64,
    ) -> RootPort {
        RootPort {
            id,
            ctrl: CxlController::new(kind),
            backend,
            sr: SpecReadEngine::new(sr_policy),
            ds: DetStoreEngine::new(ds_enabled, ds_capacity),
            slots: vec![0; MEM_QUEUE_CAP],
            recent: VecDeque::with_capacity(MEM_QUEUE_CAP),
            local_ack: 200 * NS,
            flush_scratch: Vec::new(),
            stats: PortStats::default(),
            req_id: 0,
        }
    }

    fn next_req_id(&mut self) -> u64 {
        self.req_id += 1;
        self.req_id
    }

    /// Number of slots still busy at `at` (ingress occupancy).
    pub fn occupancy(&self, at: Time) -> usize {
        self.slots.iter().filter(|&&t| t > at).count()
    }

    /// Acquire the earliest free memory-queue slot at or after `now`.
    /// Returns (slot index, start time).
    fn acquire_slot(&mut self, now: Time) -> (usize, Time) {
        let (idx, &free) = self
            .slots
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .expect("slots nonempty");
        if free > now {
            self.stats.queue_full_waits += 1;
        }
        let start = free.max(now);
        let occ = self.slots.iter().filter(|&&t| t > start).count() as u64 + 1;
        self.stats.queue_hwm = self.stats.queue_hwm.max(occ);
        (idx, start)
    }

    /// Unloaded 64 B demand-read latency through this port: controller
    /// request/response legs plus quiet-media service. The fabric QoS
    /// controller uses it as the congestion baseline — observed latency
    /// well past this means real queueing, not just occupancy.
    pub fn unloaded_read_ps(&self) -> Time {
        let flit = Flit { op: MemOpcode::MemRd, addr: 0, len: 64, issued_at: 0, req_id: 0 };
        let media = match &self.backend {
            EpBackend::Dram(d) => d.hit_latency(),
            EpBackend::Ssd(s) => s.nominal_read_ps(),
        };
        self.ctrl.request_leg(&flit) + media + self.ctrl.response_leg(&flit)
    }

    /// The endpoint's DevLoad as observed at `at`: ingress-queue
    /// occupancy quartiles plus the internal-task announcement (GC /
    /// wear-leveling) for SSD backends.
    pub fn devload(&self, at: Time) -> DevLoad {
        let task = match &self.backend {
            EpBackend::Dram(_) => false,
            EpBackend::Ssd(s) => s.internal_task_active(at),
        };
        DevLoad::classify(self.occupancy(at), MEM_QUEUE_CAP, task)
    }

    fn remember(&mut self, addr: u64) {
        if self.recent.len() == MEM_QUEUE_CAP {
            self.recent.pop_front();
        }
        self.recent.push_back(addr);
    }

    /// Service a demand load of `len` bytes at EP-relative address `addr`.
    pub fn load(&mut self, now: Time, addr: u64, len: u64) -> LoadOutcome {
        self.stats.loads += 1;

        // DS read interception: buffered lines are served from GPU local
        // memory, never touching the congested EP.
        if self.ds.intercept_read(addr) {
            let done = now + self.local_ack;
            self.stats.load_latency.add((done - now) as f64);
            return LoadOutcome { done, path: LoadPath::DsIntercept };
        }

        // Queue logic first: the MemSpecRd hint is fire-and-forget and
        // does NOT wait for a memory-queue slot — the paper's SR reader
        // speculates for "requests that are waiting in the GPU's memory
        // queue", so hints race ahead of queued demand reads.
        let dl = self.devload(now);
        if dl == DevLoad::Severe {
            self.stats.devload_severe_seen += 1;
        }
        self.sr.observe_devload(dl);
        let rid = self.next_req_id();
        // Split borrows: the SR engine reads the recent-address queue
        // while the backend stays independently mutable (no per-load
        // clone of the queue — this is the hot path).
        let RootPort { sr, recent, backend, ctrl, .. } = self;
        if let (Some(srf), EpBackend::Ssd(ssd)) =
            (sr.on_load(now, addr, recent, rid), backend)
        {
            // The hint crosses the link like a request flit, then the EP
            // prefetches into its internal DRAM.
            let hint_arrive = now + ctrl.request_leg(&srf);
            ssd.prefetch(hint_arrive, srf.addr, srf.len.max(64));
        }

        let (slot, start) = self.acquire_slot(now);

        // Demand read: request leg, media service, response leg.
        let flit = Flit { op: MemOpcode::MemRd, addr, len, issued_at: start, req_id: rid };
        let at_ep = start + self.ctrl.request_leg(&flit);
        let (media_done, path) = match &mut self.backend {
            EpBackend::Dram(d) => (d.access(at_ep, addr, len, false), LoadPath::Media),
            EpBackend::Ssd(s) => {
                s.settle_prefetches(at_ep);
                let (t, hit) = s.read(at_ep, addr, len);
                (t, if hit { LoadPath::EpCacheHit } else { LoadPath::Media })
            }
        };
        let done = media_done + self.ctrl.response_leg(&flit);
        self.slots[slot] = done;
        self.remember(addr);
        self.stats.load_latency.add((done - now) as f64);
        // Prefetch-lead feedback: misses and long waits mean the windows
        // land behind/late; prompt hits mean the lead suffices.
        match path {
            LoadPath::Media => self.sr.feedback_late(),
            LoadPath::EpCacheHit => {
                if media_done.saturating_sub(at_ep) > 4 * 120 * NS {
                    self.sr.feedback_late();
                } else {
                    self.sr.feedback_timely();
                }
            }
            LoadPath::DsIntercept => {}
        }
        LoadOutcome { done, path }
    }

    /// Service a store (LLC writeback or streaming store).
    pub fn store(&mut self, now: Time, addr: u64, len: u64, rng: &mut Pcg32) -> StoreOutcome {
        self.stats.stores += 1;
        let dl_now = self.devload(now);
        let action = if self.backend.is_ssd() {
            self.ds.on_store(now, addr, len, dl_now)
        } else {
            StoreAction::DualWrite
        };

        match action {
            StoreAction::Buffer => {
                // Absorbed into reserved GPU memory: deterministic ack.
                let ack = now + self.local_ack;
                self.stats.store_latency.add((ack - now) as f64);
                StoreOutcome { ack, buffered: true }
            }
            StoreAction::DualWrite if self.backend.is_ssd() && self.ds.enabled => {
                // Fire-and-forget: ack at GPU-memory speed; the EP write
                // rides a queue slot in the background.
                let ack = now + self.local_ack;
                let (slot, start) = self.acquire_slot(now);
                let flit =
                    Flit { op: MemOpcode::MemWr, addr, len, issued_at: start, req_id: 0 };
                let at_ep = start + self.ctrl.request_leg(&flit);
                let done = match &mut self.backend {
                    EpBackend::Ssd(s) => s.write(at_ep, addr, len, rng),
                    EpBackend::Dram(d) => d.access(at_ep, addr, len, true),
                };
                self.slots[slot] = done + self.ctrl.response_leg(&flit);
                self.stats.store_latency.add((ack - now) as f64);
                StoreOutcome { ack, buffered: false }
            }
            StoreAction::DualWrite | StoreAction::Block => {
                let (slot, start) = self.acquire_slot(now);
                let flit =
                    Flit { op: MemOpcode::MemWr, addr, len, issued_at: start, req_id: 0 };
                let at_ep = start + self.ctrl.request_leg(&flit);
                let ack = match &mut self.backend {
                    EpBackend::Dram(d) => {
                        // Posted write: the DRAM EP's controller accepts
                        // the flit into its write queue and returns the
                        // NDR completion immediately; the array write
                        // drains in the background (bank state advances).
                        d.access(at_ep, addr, len, true);
                        at_ep + 10 * NS + self.ctrl.response_leg(&flit)
                    }
                    EpBackend::Ssd(s) => {
                        // SSD acks track the write buffer: fast with room,
                        // stalled when full or during internal tasks —
                        // the tail DS exists to hide.
                        let media_done = s.write(at_ep, addr, len, rng);
                        media_done + self.ctrl.response_leg(&flit)
                    }
                };
                self.slots[slot] = ack;
                self.stats.store_latency.add((ack - now) as f64);
                StoreOutcome { ack, buffered: false }
            }
        }
    }

    /// Service one background tiering transfer of `len` bytes at
    /// EP-relative address `addr` (read when `is_write` is false).
    ///
    /// Migration traffic rides the same machinery as demand traffic — a
    /// memory-queue slot, the controller's request/response legs, and
    /// real media time — so page movement contends with (and delays)
    /// demand requests instead of teleporting. It deliberately bypasses
    /// the SR and DS engines: a DMA-style mover neither speculates nor
    /// needs deterministic acks, and its addresses must not pollute the
    /// SR window detector. Returns the transfer's completion time.
    pub fn migrate(&mut self, now: Time, addr: u64, len: u64, is_write: bool, rng: &mut Pcg32) -> Time {
        self.stats.migrations += 1;
        let (slot, start) = self.acquire_slot(now);
        let op = if is_write { MemOpcode::MemWr } else { MemOpcode::MemRd };
        let flit = Flit { op, addr, len, issued_at: start, req_id: 0 };
        let at_ep = start + self.ctrl.request_leg(&flit);
        let media_done = match &mut self.backend {
            EpBackend::Dram(d) => d.access(at_ep, addr, len, is_write),
            EpBackend::Ssd(s) => {
                if is_write {
                    s.write(at_ep, addr, len, rng)
                } else {
                    s.settle_prefetches(at_ep);
                    s.read(at_ep, addr, len).0
                }
            }
        };
        let done = media_done + self.ctrl.response_leg(&flit);
        self.slots[slot] = done;
        done
    }

    /// Background flush step: if the EP has recovered and the DS stack is
    /// non-empty, forward up to `batch` buffered lines. Returns the time
    /// the batch completes (slots are consumed like normal writes), or
    /// None if nothing was flushed.
    pub fn flush_step(&mut self, now: Time, batch: usize, rng: &mut Pcg32) -> Option<Time> {
        if !self.ds.enabled || self.ds.buffered_entries() == 0 {
            return None;
        }
        if self.devload(now).overloaded() {
            return None; // wait for the EP to recover
        }
        // Move the scratch buffer out of `self` for the loop (the body
        // borrows backend/slots/ds mutably), then put it back so its
        // capacity survives to the next tick.
        let mut lines = std::mem::take(&mut self.flush_scratch);
        self.ds.flush_batch_into(batch, &mut lines);
        let mut last = now;
        for &(line, len) in &lines {
            let (slot, start) = self.acquire_slot(last);
            let flit = Flit { op: MemOpcode::MemWr, addr: line, len, issued_at: start, req_id: 0 };
            let at_ep = start + self.ctrl.request_leg(&flit);
            let done = match &mut self.backend {
                EpBackend::Ssd(s) => s.write(at_ep, line, len, rng),
                EpBackend::Dram(d) => d.access(at_ep, line, len, true),
            };
            self.slots[slot] = done;
            self.ds.flush_done(line);
            last = done;
        }
        self.flush_scratch = lines;
        Some(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::media::{DramTimings, SsdParams};
    use crate::sim::US;

    fn dram_port() -> RootPort {
        RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        )
    }

    fn ssd_port(sr: SrPolicy, ds: bool) -> RootPort {
        RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            sr,
            ds,
            1 << 20,
        )
    }

    #[test]
    fn dram_load_is_protocol_plus_media() {
        let mut p = dram_port();
        let out = p.load(0, 0x1000, 64);
        let ns = out.done as f64 / NS as f64;
        // ~74 ns protocol round trip + ~250 ns DDR subsystem + burst.
        assert!((250.0..450.0).contains(&ns), "DRAM EP load took {ns} ns");
        assert_eq!(out.path, LoadPath::Media);
    }

    #[test]
    fn ssd_cold_load_pays_media_latency() {
        let mut p = ssd_port(SrPolicy::Off, false);
        let out = p.load(0, 0x1000, 64);
        assert!(out.done >= 3 * US);
        assert_eq!(out.path, LoadPath::Media);
    }

    #[test]
    fn sr_prefetch_makes_next_window_hit() {
        let mut p = ssd_port(SrPolicy::Dynamic, false);
        // First load prefetches its 256B window.
        let first = p.load(0, 0x1000, 64);
        // A later load inside the window should hit internal DRAM.
        let second = p.load(first.done + 10 * US, 0x1040, 64);
        assert_eq!(second.path, LoadPath::EpCacheHit);
        assert!(second.done - (first.done + 10 * US) < 2 * US);
    }

    #[test]
    fn ds_store_acks_fast_even_during_gc() {
        let mut rng = Pcg32::new(1, 1);
        let mut p = ssd_port(SrPolicy::Off, true);
        // Force an internal task: make the EP look busy.
        if let EpBackend::Ssd(s) = &mut p.backend {
            // Saturate the write buffer so DevLoad goes severe via task.
            for i in 0..100_000u64 {
                s.write(0, i * 64, 64, &mut rng);
            }
        }
        let out = p.store(1000, 0xabc0, 64, &mut rng);
        assert!(out.ack <= 1000 + p.local_ack + NS, "DS ack must be deterministic");
    }

    #[test]
    fn no_ds_store_waits_for_media_when_buffer_full() {
        let mut rng = Pcg32::new(2, 2);
        let mut p = ssd_port(SrPolicy::Off, false);
        // Fill the SSD write buffer.
        let mut last = 0;
        for i in 0..200_000u64 {
            let out = p.store(0, i * 64, 64, &mut rng);
            last = out.ack;
            if last > 50 * US {
                break;
            }
        }
        assert!(last > 50 * US, "no-DS store should eventually stall: {last}");
    }

    #[test]
    fn buffered_store_intercepts_subsequent_load() {
        let mut rng = Pcg32::new(3, 3);
        let mut p = ssd_port(SrPolicy::Off, true);
        // Announce an internal task: DevLoad goes Severe, stores divert.
        if let EpBackend::Ssd(s) = &mut p.backend {
            s.begin_gc(0);
        }
        let out = p.store(0, 0x5000, 64, &mut rng);
        assert!(out.buffered);
        let load = p.load(out.ack, 0x5000, 64);
        assert_eq!(load.path, LoadPath::DsIntercept);
    }

    #[test]
    fn flush_empties_buffer_when_ep_recovers() {
        let mut rng = Pcg32::new(4, 4);
        let mut p = ssd_port(SrPolicy::Off, true);
        let gc_end = {
            let EpBackend::Ssd(s) = &mut p.backend else { unreachable!() };
            s.begin_gc(0);
            s.gc_until()
        };
        let out = p.store(0, 0x7000, 64, &mut rng);
        assert!(out.buffered);
        // While GC runs, the flush must hold back.
        assert!(p.flush_step(gc_end / 2, 8, &mut rng).is_none());
        // After the EP recovers, flush drains the stack.
        let done = p.flush_step(gc_end + 1, 8, &mut rng);
        assert!(done.is_some());
        assert_eq!(p.ds.buffered_entries(), 0);
    }

    #[test]
    fn migration_occupies_queue_slots_and_media_time() {
        let mut rng = Pcg32::new(5, 5);
        let mut p = ssd_port(SrPolicy::Off, false);
        let done = p.migrate(0, 0x4000, 4096, false, &mut rng);
        assert!(done >= 3 * US, "SSD page read must pay media latency: {done}");
        assert_eq!(p.stats.migrations, 1);
        assert_eq!(p.stats.loads, 0, "migration is not demand traffic");
        // Saturate the queue with migrations: demand sees backpressure.
        for i in 0..MEM_QUEUE_CAP as u64 + 4 {
            p.migrate(0, 0x100000 + i * 4096, 4096, false, &mut rng);
        }
        assert!(p.stats.queue_full_waits >= 1);
    }

    #[test]
    fn queue_slots_backpressure() {
        let mut p = ssd_port(SrPolicy::Off, false);
        // 33 concurrent loads: the 33rd must wait for a slot.
        for i in 0..MEM_QUEUE_CAP as u64 + 1 {
            p.load(0, i * 4096 * 16, 64);
        }
        assert!(p.stats.queue_full_waits >= 1);
    }
}
