//! A CXL root port: queue logic + controller + endpoint.
//!
//! Each port (Fig. 5a) owns a [`CxlController`] pair (root-port side and
//! EP side share the latency model), a DRAM- or SSD-backed endpoint, the
//! SR engine, the DS engine, and the 32-entry memory queue that bounds
//! outstanding demand requests (backpressure to the LLC/MSHRs).

use std::collections::VecDeque;

use crate::cxl::{ControllerKind, CxlController, DevLoad, Flit, MemOpcode};
use crate::expander::{CacheSpec, DeviceCache, Lookup, DEV_DRAM_GBPS, WB_DRAIN_BATCH};
use crate::media::{DramModel, MediaKind, SsdModel};
use crate::obs::{Stage, StageTrace};
use crate::ras::{FaultSpec, RasState};
use crate::sim::{transfer_time, Time, NS};
use crate::util::prng::Pcg32;
use crate::util::stats::Summary;

use super::det_store::{DetStoreEngine, StoreAction};
use super::spec_read::{SpecReadEngine, SrPolicy, MEM_QUEUE_CAP};

/// Endpoint backend behind a port.
#[derive(Debug)]
pub enum EpBackend {
    Dram(DramModel),
    Ssd(SsdModel),
}

impl EpBackend {
    pub fn kind(&self) -> MediaKind {
        match self {
            EpBackend::Dram(_) => MediaKind::Ddr5,
            EpBackend::Ssd(s) => s.kind(),
        }
    }

    pub fn is_ssd(&self) -> bool {
        matches!(self, EpBackend::Ssd(_))
    }
}

/// How a load was ultimately served (for hit-rate reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadPath {
    /// Served from the DS buffer in GPU local memory.
    DsIntercept,
    /// Device-DRAM hit inside the EP: the SSD model's internal cache or
    /// the expander-side device cache (DESIGN.md §14), either possibly
    /// SR-prefetched.
    EpCacheHit,
    /// Backend media access.
    Media,
}

/// Completed load description.
#[derive(Debug, Clone, Copy)]
pub struct LoadOutcome {
    pub done: Time,
    pub path: LoadPath,
}

/// Completed store description.
#[derive(Debug, Clone, Copy)]
pub struct StoreOutcome {
    /// When the SMs/LLC may consider the store retired.
    pub ack: Time,
    /// Whether the data still needs a background flush (DS buffered it).
    pub buffered: bool,
}

/// Per-port counters harvested into `RunMetrics` after a run.
#[derive(Debug, Default, Clone)]
pub struct PortStats {
    /// Demand loads serviced (including DS intercepts).
    pub loads: u64,
    /// Stores serviced (buffered, dual-written or blocked).
    pub stores: u64,
    /// End-to-end demand-load latency distribution.
    pub load_latency: Summary,
    /// Store ack latency distribution.
    pub store_latency: Summary,
    /// DevLoad observations in the Severe class.
    pub devload_severe_seen: u64,
    /// Requests that had to wait for a memory-queue slot.
    pub queue_full_waits: u64,
    /// Memory-queue occupancy high-water mark (including the admitted
    /// request), sampled at every slot acquisition.
    pub queue_hwm: u64,
    /// Background tiering transfers serviced ([`RootPort::migrate`]).
    pub migrations: u64,
}

/// One CXL root port with its endpoint.
#[derive(Debug)]
pub struct RootPort {
    /// Port index within the root complex (HDM decode target id).
    pub id: usize,
    /// The CXL controller pair's latency model (both link legs).
    pub ctrl: CxlController,
    /// The endpoint behind this port (DRAM- or SSD-backed).
    pub backend: EpBackend,
    /// Speculative Read engine (MemSpecRd hints into the EP cache).
    pub sr: SpecReadEngine,
    /// Deterministic Store engine (GPU-memory store buffering).
    pub ds: DetStoreEngine,
    /// Expander-side device DRAM cache (DESIGN.md §14); `None` keeps
    /// every path byte-identical to the uncached port.
    pub cache: Option<DeviceCache>,
    /// RAS fault injection + recovery (DESIGN.md §15); `None` keeps
    /// every path byte-identical to the fault-free port.
    pub ras: Option<RasState>,
    /// Memory-queue slots: completion time of the request occupying each.
    slots: Vec<Time>,
    /// Recent outstanding demand addresses (SR window input).
    recent: VecDeque<u64>,
    /// Local-memory mirror latency used for DS acks and intercepts.
    pub local_ack: Time,
    /// Scratch for [`DetStoreEngine::flush_batch_into`]: one buffer
    /// reused across every `FlushTick` instead of a `Vec` per tick.
    flush_scratch: Vec<(u64, u64)>,
    pub stats: PortStats,
    req_id: u64,
}

impl RootPort {
    pub fn new(
        id: usize,
        kind: ControllerKind,
        backend: EpBackend,
        sr_policy: SrPolicy,
        ds_enabled: bool,
        ds_capacity: u64,
    ) -> RootPort {
        RootPort {
            id,
            ctrl: CxlController::new(kind),
            backend,
            sr: SpecReadEngine::new(sr_policy),
            ds: DetStoreEngine::new(ds_enabled, ds_capacity),
            cache: None,
            ras: None,
            slots: vec![0; MEM_QUEUE_CAP],
            recent: VecDeque::with_capacity(MEM_QUEUE_CAP),
            local_ack: 200 * NS,
            flush_scratch: Vec::new(),
            stats: PortStats::default(),
            req_id: 0,
        }
    }

    /// Attach the expander-side device cache described by `spec` (SSD
    /// backends only — fronting fast DRAM media with more DRAM models
    /// nothing). A disabled or zero-capacity spec attaches no cache at
    /// all, keeping the port byte-identical to the uncached build.
    pub fn with_cache(mut self, spec: CacheSpec) -> RootPort {
        if self.backend.is_ssd() {
            self.cache = DeviceCache::new(spec);
        }
        self
    }

    /// Arm the RAS layer described by `spec` (DESIGN.md §15). An inert
    /// spec — disabled, or every rate zero and no scheduled degradation
    /// — attaches no state at all, keeping the port byte-identical to
    /// the fault-free build (the zero-rate bit-transparency contract).
    pub fn with_ras(mut self, spec: FaultSpec, seed: u64) -> RootPort {
        self.ras = RasState::new(spec, seed, self.id);
        self
    }

    /// Whether this port's endpoint has hard-degraded — the tiering
    /// engine and the pooled switch steer traffic around it.
    pub fn is_degraded(&self) -> bool {
        self.ras.as_ref().map_or(false, |r| r.degraded)
    }

    /// Latch a scheduled hard degradation once due. The order matters:
    /// first rescue every dirty byte out of the device cache — queued
    /// writebacks *and* resident dirty lines retire against the media
    /// now, while the endpoint still answers — then mark the port
    /// degraded so penalties and steering kick in. The conservation
    /// property in `tests/props.rs` proves no dirty byte is lost.
    fn ras_degrade_check(&mut self, now: Time) {
        let RootPort { ras, cache, backend, id, .. } = self;
        let Some(r) = ras else { return };
        if !r.due_degrade(now, *id) {
            return;
        }
        if let (Some(c), EpBackend::Ssd(s)) = (cache.as_mut(), &mut *backend) {
            let line = c.line_bytes();
            for addr in c.drain_all_dirty() {
                s.write_internal(now, addr, line);
                r.stats.dirty_rescued_bytes += line;
            }
        }
        r.mark_degraded();
    }

    /// Request-side RAS effects for one transfer of `flits` link flits:
    /// CRC retry/replay legs, poison containment (the payload is lost
    /// past the retry budget but the requester still holds it — the LLC
    /// line or the DS copy — so re-issuing costs a timeout window plus
    /// one retransmit leg), spontaneous controller timeouts with
    /// exponential backoff, a media latency spike, and the
    /// degraded-endpoint penalty. Zero when RAS is off.
    fn ras_request_extra(&mut self, at: Time, flits: u64, leg: Time) -> Time {
        let Some(r) = &mut self.ras else { return 0 };
        let lr = r.link_transfer(at, flits, leg);
        let mut extra = lr.extra;
        if lr.poisoned {
            extra += r.base_timeout() + leg;
        }
        extra + r.timeout_wait() + r.media_spike() + r.degrade_penalty()
    }

    /// Response-side RAS effects: CRC retry/replay legs, and on poison
    /// the containment re-fetch — the completion data is gone, but the
    /// source still holds it (the EP's internal DRAM for reads), so the
    /// re-issue costs a timeout window, `refetch`, and one more leg.
    fn ras_response_extra(&mut self, at: Time, flits: u64, leg: Time, refetch: Time) -> Time {
        let Some(r) = &mut self.ras else { return 0 };
        let lr = r.link_transfer(at, flits, leg);
        let mut extra = lr.extra;
        if lr.poisoned {
            extra += r.base_timeout() + refetch + leg;
        }
        extra
    }

    /// Cost of re-reading a just-fetched line out of the endpoint for
    /// poisoned-read containment: the data never left the EP's internal
    /// DRAM, so the re-fetch is a device-DRAM hit, not a media access.
    fn ep_reread_cost(&self) -> Time {
        match &self.backend {
            EpBackend::Dram(d) => d.hit_latency(),
            EpBackend::Ssd(s) => s.params.dram_lat,
        }
    }

    /// Drop cached lines in the device-address range `[lo, hi)` — used
    /// by the tiering engine before migrating pages through the port,
    /// mirroring the DS range invalidation. Migration chunks are
    /// line-aligned and at most a page, so the direct set probe is the
    /// right cost shape (covering lines × ways, not a full-slot scan).
    pub fn invalidate_cache_range(&mut self, lo: u64, hi: u64) {
        if let Some(c) = &mut self.cache {
            c.invalidate_span(lo, hi.saturating_sub(lo));
        }
    }

    fn next_req_id(&mut self) -> u64 {
        self.req_id += 1;
        self.req_id
    }

    /// Number of slots still busy at `at` (ingress occupancy).
    pub fn occupancy(&self, at: Time) -> usize {
        self.slots.iter().filter(|&&t| t > at).count()
    }

    /// Acquire the earliest free memory-queue slot at or after `now`.
    /// Returns (slot index, start time).
    fn acquire_slot(&mut self, now: Time) -> (usize, Time) {
        // `slots` is sized MEM_QUEUE_CAP at construction and never
        // shrinks; scan by value so the hot path carries no `expect`
        // unwind edge (the invariant is debug-asserted instead).
        debug_assert!(!self.slots.is_empty());
        let mut idx = 0;
        let mut free = Time::MAX;
        for (i, &t) in self.slots.iter().enumerate() {
            if t < free {
                idx = i;
                free = t;
            }
        }
        if free > now {
            self.stats.queue_full_waits += 1;
        }
        let start = free.max(now);
        let occ = self.slots.iter().filter(|&&t| t > start).count() as u64 + 1;
        self.stats.queue_hwm = self.stats.queue_hwm.max(occ);
        (idx, start)
    }

    /// Unloaded 64 B demand-read latency through this port: controller
    /// request/response legs plus quiet-media service. The fabric QoS
    /// controller uses it as the congestion baseline — observed latency
    /// well past this means real queueing, not just occupancy.
    pub fn unloaded_read_ps(&self) -> Time {
        let flit = Flit { op: MemOpcode::MemRd, addr: 0, len: 64, issued_at: 0, req_id: 0 };
        let media = match &self.backend {
            EpBackend::Dram(d) => d.hit_latency(),
            EpBackend::Ssd(s) => s.nominal_read_ps(),
        };
        self.ctrl.request_leg(&flit) + media + self.ctrl.response_leg(&flit)
    }

    /// The endpoint's DevLoad as observed at `at`: ingress-queue
    /// occupancy quartiles plus the internal-task announcement (GC /
    /// wear-leveling) for SSD backends, plus — when the device cache is
    /// attached — the writeback drain queue's backlog (dirty evictions
    /// the EP still owes its media).
    pub fn devload(&self, at: Time) -> DevLoad {
        let task = match &self.backend {
            EpBackend::Dram(_) => false,
            EpBackend::Ssd(s) => s.internal_task_active(at),
        };
        let (wb, wb_cap) = self
            .cache
            .as_ref()
            .map_or((0, 1), |c| (c.wb_pending(), c.wb_queue_cap()));
        DevLoad::classify_with_drain(self.occupancy(at), MEM_QUEUE_CAP, wb, wb_cap, task)
    }

    fn remember(&mut self, addr: u64) {
        if self.recent.len() == MEM_QUEUE_CAP {
            self.recent.pop_front();
        }
        self.recent.push_back(addr);
    }

    /// Service a demand load of `len` bytes at EP-relative address `addr`.
    pub fn load(&mut self, now: Time, addr: u64, len: u64) -> LoadOutcome {
        self.load_traced(now, addr, len, None)
    }

    /// [`RootPort::load`] with an optional latency-attribution ledger
    /// (DESIGN.md §18). Every stage duration is a difference of the same
    /// timestamps the untraced path already computes — tracing never
    /// perturbs timing — and the stages telescope: their sum is exactly
    /// `done - now`.
    pub fn load_traced(
        &mut self,
        now: Time,
        addr: u64,
        len: u64,
        mut trace: Option<&mut StageTrace>,
    ) -> LoadOutcome {
        self.stats.loads += 1;
        self.ras_degrade_check(now);

        // DS read interception: buffered lines are served from GPU local
        // memory, never touching the congested EP.
        if self.ds.intercept_read(addr) {
            let done = now + self.local_ack;
            self.stats.load_latency.add((done - now) as f64);
            if let Some(t) = trace.as_deref_mut() {
                t.add(Stage::DsLocal, done - now);
            }
            return LoadOutcome { done, path: LoadPath::DsIntercept };
        }

        // Queue logic first: the MemSpecRd hint is fire-and-forget and
        // does NOT wait for a memory-queue slot — the paper's SR reader
        // speculates for "requests that are waiting in the GPU's memory
        // queue", so hints race ahead of queued demand reads.
        let dl = self.devload(now);
        if dl == DevLoad::Severe {
            self.stats.devload_severe_seen += 1;
        }
        self.sr.observe_devload(dl);
        let rid = self.next_req_id();
        // Split borrows: the SR engine reads the recent-address queue
        // while the backend stays independently mutable (no per-load
        // clone of the queue — this is the hot path).
        let RootPort { sr, recent, backend, ctrl, cache, .. } = self;
        if let (Some(srf), EpBackend::Ssd(ssd)) =
            (sr.on_load(now, addr, recent, rid), backend)
        {
            // Device-cache probe: a window already resident in device
            // DRAM needs no hint — the cheap path exists. `sr_issued`
            // still counts the emitted window; `cache_suppressed`
            // records that it never crossed the link.
            if cache.as_ref().map_or(false, |c| c.contains_span(srf.addr, srf.len.max(64))) {
                sr.hint_covered_by_cache();
            } else {
                // The hint crosses the link like a request flit, then the
                // EP prefetches into its internal DRAM — and, when
                // present, the device cache stages the same window
                // (admission-exempt: SR carries its own DevLoad-driven
                // rate control).
                let hint_arrive = now + ctrl.request_leg(&srf);
                let staged = ssd.prefetch(hint_arrive, srf.addr, srf.len.max(64));
                if let Some(c) = cache {
                    c.prefetch_install(srf.addr, srf.len.max(64), staged);
                }
            }
        }

        let (slot, start) = self.acquire_slot(now);

        // Demand read: request leg, device service, response leg. With
        // the device cache attached the EP-side service order is: retire
        // a writeback-drain batch, then serve a resident line from
        // device DRAM, or fetch-and-install the covering cache line
        // (admission permitting) with one backend read, or bypass —
        // which is byte-for-byte the uncached path.
        let flit = Flit { op: MemOpcode::MemRd, addr, len, issued_at: start, req_id: rid };
        let req_leg = self.ctrl.request_leg(&flit);
        // RAS, request side: the read command is a single link flit.
        let at_ep = start + req_leg + self.ras_request_extra(start, 1, req_leg);
        if let Some(t) = trace.as_deref_mut() {
            t.add(Stage::PortQueue, start - now);
            t.add(Stage::ReqLink, req_leg);
            t.add(Stage::RasReq, at_ep - start - req_leg);
        }
        let RootPort { backend, cache, .. } = self;
        let (media_done, path) = match backend {
            EpBackend::Dram(d) => (d.access(at_ep, addr, len, false), LoadPath::Media),
            EpBackend::Ssd(s) => match cache {
                Some(c) => {
                    drain_writebacks(c, s, at_ep);
                    match c.lookup(at_ep, addr, len, false) {
                        Lookup::Hit { ready } => {
                            // Wait out any in-flight fill, then the DRAM
                            // hop + serialization — the same cost surface
                            // as the SSD model's internal hit path.
                            let done = ready.max(at_ep)
                                + c.dram_lat()
                                + transfer_time(len.max(64), DEV_DRAM_GBPS);
                            (done, LoadPath::EpCacheHit)
                        }
                        Lookup::Miss => {
                            s.settle_prefetches(at_ep);
                            if c.should_admit(addr, at_ep) {
                                let (base, span) = c.span(addr, len);
                                let (t, hit) = s.read(at_ep, base, span);
                                c.install(base, span, t, false);
                                (t, if hit { LoadPath::EpCacheHit } else { LoadPath::Media })
                            } else {
                                let (t, hit) = s.read(at_ep, addr, len);
                                (t, if hit { LoadPath::EpCacheHit } else { LoadPath::Media })
                            }
                        }
                    }
                }
                None => {
                    s.settle_prefetches(at_ep);
                    let (t, hit) = s.read(at_ep, addr, len);
                    (t, if hit { LoadPath::EpCacheHit } else { LoadPath::Media })
                }
            },
        };
        let resp_leg = self.ctrl.response_leg(&flit);
        // RAS, response side: the completion carries the data flits; a
        // poisoned completion is contained by re-fetching from the EP's
        // internal DRAM (the line just landed there) after a timeout.
        let refetch = req_leg + self.ep_reread_cost();
        let done = media_done
            + resp_leg
            + self.ras_response_extra(media_done, flit.link_flits(), resp_leg, refetch);
        if let Some(t) = trace.as_deref_mut() {
            let dev = match path {
                LoadPath::EpCacheHit => Stage::CacheHit,
                _ => Stage::Media,
            };
            t.add(dev, media_done - at_ep);
            t.add(Stage::RespLink, resp_leg);
            t.add(Stage::RasResp, done - media_done - resp_leg);
        }
        self.slots[slot] = done;
        self.remember(addr);
        self.stats.load_latency.add((done - now) as f64);
        // Prefetch-lead feedback: misses and long waits mean the windows
        // land behind/late; prompt hits mean the lead suffices.
        match path {
            LoadPath::Media => self.sr.feedback_late(),
            LoadPath::EpCacheHit => {
                if media_done.saturating_sub(at_ep) > 4 * 120 * NS {
                    self.sr.feedback_late();
                } else {
                    self.sr.feedback_timely();
                }
            }
            LoadPath::DsIntercept => {}
        }
        LoadOutcome { done, path }
    }

    /// Service a store (LLC writeback or streaming store).
    pub fn store(&mut self, now: Time, addr: u64, len: u64, rng: &mut Pcg32) -> StoreOutcome {
        self.store_traced(now, addr, len, rng, None)
    }

    /// [`RootPort::store`] with an optional latency-attribution ledger
    /// (DESIGN.md §18). Stage sums telescope to exactly `ack - now`; DS
    /// and dual-write acks are one `DsLocal` stage (the background media
    /// write is not part of the acked latency), and a blocked store's
    /// device time — cache-absorbed or media — is charged to `Media`.
    pub fn store_traced(
        &mut self,
        now: Time,
        addr: u64,
        len: u64,
        rng: &mut Pcg32,
        mut trace: Option<&mut StageTrace>,
    ) -> StoreOutcome {
        self.stats.stores += 1;
        self.ras_degrade_check(now);
        let dl_now = self.devload(now);
        let action = if self.backend.is_ssd() {
            self.ds.on_store(now, addr, len, dl_now)
        } else {
            StoreAction::DualWrite
        };

        match action {
            StoreAction::Buffer => {
                // Absorbed into reserved GPU memory: deterministic ack.
                let ack = now + self.local_ack;
                self.stats.store_latency.add((ack - now) as f64);
                if let Some(t) = trace.as_deref_mut() {
                    t.add(Stage::DsLocal, ack - now);
                }
                StoreOutcome { ack, buffered: true }
            }
            StoreAction::DualWrite if self.backend.is_ssd() && self.ds.enabled => {
                // Fire-and-forget: ack at GPU-memory speed; the EP write
                // rides a queue slot in the background.
                let ack = now + self.local_ack;
                let (slot, start) = self.acquire_slot(now);
                let flit =
                    Flit { op: MemOpcode::MemWr, addr, len, issued_at: start, req_id: 0 };
                let req_leg = self.ctrl.request_leg(&flit);
                // RAS: the write's data rides the request leg. The ack
                // already happened at GPU-memory speed (the DS copy is
                // the recovery source), so only the background slot
                // occupancy stretches.
                let at_ep =
                    start + req_leg + self.ras_request_extra(start, flit.link_flits(), req_leg);
                let RootPort { backend, cache, .. } = self;
                let done = match backend {
                    EpBackend::Ssd(s) => ssd_write_through_cache(cache, s, at_ep, addr, len, rng),
                    EpBackend::Dram(d) => d.access(at_ep, addr, len, true),
                };
                self.slots[slot] = done + self.ctrl.response_leg(&flit);
                self.stats.store_latency.add((ack - now) as f64);
                if let Some(t) = trace.as_deref_mut() {
                    t.add(Stage::DsLocal, ack - now);
                }
                StoreOutcome { ack, buffered: false }
            }
            StoreAction::DualWrite | StoreAction::Block => {
                let (slot, start) = self.acquire_slot(now);
                let flit =
                    Flit { op: MemOpcode::MemWr, addr, len, issued_at: start, req_id: 0 };
                let req_leg = self.ctrl.request_leg(&flit);
                // RAS: the write's data rides the request leg; the
                // requester holds the line until the ack, so a poison
                // re-issues from there.
                let at_ep =
                    start + req_leg + self.ras_request_extra(start, flit.link_flits(), req_leg);
                let resp_leg = self.ctrl.response_leg(&flit);
                let RootPort { backend, cache, ctrl, .. } = self;
                let ack = match backend {
                    EpBackend::Dram(d) => {
                        // Posted write: the DRAM EP's controller accepts
                        // the flit into its write queue and returns the
                        // NDR completion immediately; the array write
                        // drains in the background (bank state advances).
                        d.access(at_ep, addr, len, true);
                        at_ep + 10 * NS + ctrl.response_leg(&flit)
                    }
                    EpBackend::Ssd(s) => {
                        // SSD acks track the write buffer: fast with room,
                        // stalled when full or during internal tasks —
                        // the tail DS exists to hide. A device-cache hit
                        // absorbs the store in device DRAM instead.
                        let media_done =
                            ssd_write_through_cache(cache, s, at_ep, addr, len, rng);
                        media_done + ctrl.response_leg(&flit)
                    }
                };
                // RAS, response side: the NDR completion is one flit
                // with nothing to re-fetch — a poisoned ack just costs
                // a timeout and a clean retransmit of the completion.
                let ack0 = ack;
                let ack = ack + self.ras_response_extra(ack, 1, resp_leg, 0);
                if let Some(t) = trace.as_deref_mut() {
                    t.add(Stage::PortQueue, start - now);
                    t.add(Stage::ReqLink, req_leg);
                    t.add(Stage::RasReq, at_ep - start - req_leg);
                    t.add(Stage::Media, ack0 - resp_leg - at_ep);
                    t.add(Stage::RespLink, resp_leg);
                    t.add(Stage::RasResp, ack - ack0);
                }
                self.slots[slot] = ack;
                self.stats.store_latency.add((ack - now) as f64);
                StoreOutcome { ack, buffered: false }
            }
        }
    }

    /// Service one background tiering transfer of `len` bytes at
    /// EP-relative address `addr` (read when `is_write` is false).
    ///
    /// Migration traffic rides the same machinery as demand traffic — a
    /// memory-queue slot, the controller's request/response legs, and
    /// real media time — so page movement contends with (and delays)
    /// demand requests instead of teleporting. It deliberately bypasses
    /// the SR and DS engines *and* the device cache: a DMA-style mover
    /// neither speculates nor needs deterministic acks, its addresses
    /// must not pollute the SR window detector, and the tiering engine
    /// invalidates migrated ranges out of the cache instead
    /// ([`RootPort::invalidate_cache_range`]). Returns the transfer's
    /// completion time.
    pub fn migrate(&mut self, now: Time, addr: u64, len: u64, is_write: bool, rng: &mut Pcg32) -> Time {
        self.stats.migrations += 1;
        self.ras_degrade_check(now);
        let (slot, start) = self.acquire_slot(now);
        let op = if is_write { MemOpcode::MemWr } else { MemOpcode::MemRd };
        let flit = Flit { op, addr, len, issued_at: start, req_id: 0 };
        let req_leg = self.ctrl.request_leg(&flit);
        // RAS: page-move data rides the request leg on a write and the
        // response leg on a read; the opposite leg is a one-flit
        // command/completion.
        let req_flits = if is_write { flit.link_flits() } else { 1 };
        let at_ep = start + req_leg + self.ras_request_extra(start, req_flits, req_leg);
        let media_done = match &mut self.backend {
            EpBackend::Dram(d) => d.access(at_ep, addr, len, is_write),
            EpBackend::Ssd(s) => {
                if is_write {
                    s.write(at_ep, addr, len, rng)
                } else {
                    s.settle_prefetches(at_ep);
                    s.read(at_ep, addr, len).0
                }
            }
        };
        let resp_leg = self.ctrl.response_leg(&flit);
        let (resp_flits, refetch) = if is_write {
            (1, 0)
        } else {
            (flit.link_flits(), req_leg + self.ep_reread_cost())
        };
        let done = media_done
            + resp_leg
            + self.ras_response_extra(media_done, resp_flits, resp_leg, refetch);
        self.slots[slot] = done;
        done
    }

    /// Background flush step: if the EP has recovered and the DS stack is
    /// non-empty, forward up to `batch` buffered lines. Returns the time
    /// the batch completes (slots are consumed like normal writes), or
    /// None if nothing was flushed.
    pub fn flush_step(&mut self, now: Time, batch: usize, rng: &mut Pcg32) -> Option<Time> {
        if !self.ds.enabled || self.ds.buffered_entries() == 0 {
            return None;
        }
        if self.devload(now).overloaded() {
            return None; // wait for the EP to recover
        }
        // Move the scratch buffer out of `self` for the loop (the body
        // borrows backend/slots/ds mutably), then put it back so its
        // capacity survives to the next tick.
        let mut lines = std::mem::take(&mut self.flush_scratch);
        self.ds.flush_batch_into(batch, &mut lines);
        let mut last = now;
        for &(line, len) in &lines {
            let (slot, start) = self.acquire_slot(last);
            let flit = Flit { op: MemOpcode::MemWr, addr: line, len, issued_at: start, req_id: 0 };
            let at_ep = start + self.ctrl.request_leg(&flit);
            let RootPort { backend, cache, .. } = &mut *self;
            let done = match backend {
                EpBackend::Ssd(s) => ssd_write_through_cache(cache, s, at_ep, line, len, rng),
                EpBackend::Dram(d) => d.access(at_ep, line, len, true),
            };
            self.slots[slot] = done;
            self.ds.flush_done(line);
            last = done;
        }
        self.flush_scratch = lines;
        Some(last)
    }
}

/// Retire up to [`WB_DRAIN_BATCH`] queued dirty-eviction writebacks
/// against the media. Opportunistic: it runs at each EP-side access, so
/// drain progress rides the same timeline as the traffic that caused
/// the evictions, and each drained line is charged as a real media
/// write (write-buffer occupancy, GC accounting) via
/// [`SsdModel::write_internal`].
fn drain_writebacks(cache: &mut DeviceCache, ssd: &mut SsdModel, now: Time) {
    for _ in 0..WB_DRAIN_BATCH {
        match cache.pop_writeback() {
            Some(line) => {
                ssd.write_internal(now, line, cache.line_bytes());
            }
            None => break,
        }
    }
}

/// SSD store path through the device cache: writeback-on-hit (the store
/// is absorbed in device DRAM and reaches the flash only on eviction),
/// no-allocate on miss (streaming stores write through exactly as the
/// uncached path does — no false residency from partial-line installs).
/// A write-through miss also reconciles any resident covering lines
/// ([`DeviceCache::on_write_through`]): fully-overwritten ones are
/// superseded by the flash, partially-covered ones keep their freshest
/// bytes and stay dirty. `None` cache is byte-for-byte the uncached
/// path.
fn ssd_write_through_cache(
    cache: &mut Option<DeviceCache>,
    s: &mut SsdModel,
    at_ep: Time,
    addr: u64,
    len: u64,
    rng: &mut Pcg32,
) -> Time {
    match cache {
        Some(c) => {
            drain_writebacks(c, s, at_ep);
            match c.lookup(at_ep, addr, len, true) {
                Lookup::Hit { ready } => ready.max(at_ep) + c.dram_lat(),
                Lookup::Miss => {
                    c.on_write_through(addr, len);
                    s.write(at_ep, addr, len, rng)
                }
            }
        }
        None => s.write(at_ep, addr, len, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::media::{DramTimings, SsdParams};
    use crate::sim::US;

    fn dram_port() -> RootPort {
        RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        )
    }

    fn ssd_port(sr: SrPolicy, ds: bool) -> RootPort {
        RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            sr,
            ds,
            1 << 20,
        )
    }

    #[test]
    fn dram_load_is_protocol_plus_media() {
        let mut p = dram_port();
        let out = p.load(0, 0x1000, 64);
        let ns = out.done as f64 / NS as f64;
        // ~74 ns protocol round trip + ~250 ns DDR subsystem + burst.
        assert!((250.0..450.0).contains(&ns), "DRAM EP load took {ns} ns");
        assert_eq!(out.path, LoadPath::Media);
    }

    #[test]
    fn ssd_cold_load_pays_media_latency() {
        let mut p = ssd_port(SrPolicy::Off, false);
        let out = p.load(0, 0x1000, 64);
        assert!(out.done >= 3 * US);
        assert_eq!(out.path, LoadPath::Media);
    }

    #[test]
    fn sr_prefetch_makes_next_window_hit() {
        let mut p = ssd_port(SrPolicy::Dynamic, false);
        // First load prefetches its 256B window.
        let first = p.load(0, 0x1000, 64);
        // A later load inside the window should hit internal DRAM.
        let second = p.load(first.done + 10 * US, 0x1040, 64);
        assert_eq!(second.path, LoadPath::EpCacheHit);
        assert!(second.done - (first.done + 10 * US) < 2 * US);
    }

    #[test]
    fn ds_store_acks_fast_even_during_gc() {
        let mut rng = Pcg32::new(1, 1);
        let mut p = ssd_port(SrPolicy::Off, true);
        // Force an internal task: make the EP look busy.
        if let EpBackend::Ssd(s) = &mut p.backend {
            // Saturate the write buffer so DevLoad goes severe via task.
            for i in 0..100_000u64 {
                s.write(0, i * 64, 64, &mut rng);
            }
        }
        let out = p.store(1000, 0xabc0, 64, &mut rng);
        assert!(out.ack <= 1000 + p.local_ack + NS, "DS ack must be deterministic");
    }

    #[test]
    fn no_ds_store_waits_for_media_when_buffer_full() {
        let mut rng = Pcg32::new(2, 2);
        let mut p = ssd_port(SrPolicy::Off, false);
        // Fill the SSD write buffer.
        let mut last = 0;
        for i in 0..200_000u64 {
            let out = p.store(0, i * 64, 64, &mut rng);
            last = out.ack;
            if last > 50 * US {
                break;
            }
        }
        assert!(last > 50 * US, "no-DS store should eventually stall: {last}");
    }

    #[test]
    fn buffered_store_intercepts_subsequent_load() {
        let mut rng = Pcg32::new(3, 3);
        let mut p = ssd_port(SrPolicy::Off, true);
        // Announce an internal task: DevLoad goes Severe, stores divert.
        if let EpBackend::Ssd(s) = &mut p.backend {
            s.begin_gc(0);
        }
        let out = p.store(0, 0x5000, 64, &mut rng);
        assert!(out.buffered);
        let load = p.load(out.ack, 0x5000, 64);
        assert_eq!(load.path, LoadPath::DsIntercept);
    }

    #[test]
    fn flush_empties_buffer_when_ep_recovers() {
        let mut rng = Pcg32::new(4, 4);
        let mut p = ssd_port(SrPolicy::Off, true);
        let gc_end = {
            let EpBackend::Ssd(s) = &mut p.backend else { unreachable!() };
            s.begin_gc(0);
            s.gc_until()
        };
        let out = p.store(0, 0x7000, 64, &mut rng);
        assert!(out.buffered);
        // While GC runs, the flush must hold back.
        assert!(p.flush_step(gc_end / 2, 8, &mut rng).is_none());
        // After the EP recovers, flush drains the stack.
        let done = p.flush_step(gc_end + 1, 8, &mut rng);
        assert!(done.is_some());
        assert_eq!(p.ds.buffered_entries(), 0);
    }

    #[test]
    fn migration_occupies_queue_slots_and_media_time() {
        let mut rng = Pcg32::new(5, 5);
        let mut p = ssd_port(SrPolicy::Off, false);
        let done = p.migrate(0, 0x4000, 4096, false, &mut rng);
        assert!(done >= 3 * US, "SSD page read must pay media latency: {done}");
        assert_eq!(p.stats.migrations, 1);
        assert_eq!(p.stats.loads, 0, "migration is not demand traffic");
        // Saturate the queue with migrations: demand sees backpressure.
        for i in 0..MEM_QUEUE_CAP as u64 + 4 {
            p.migrate(0, 0x100000 + i * 4096, 4096, false, &mut rng);
        }
        assert!(p.stats.queue_full_waits >= 1);
    }

    #[test]
    fn queue_slots_backpressure() {
        let mut p = ssd_port(SrPolicy::Off, false);
        // 33 concurrent loads: the 33rd must wait for a slot.
        for i in 0..MEM_QUEUE_CAP as u64 + 1 {
            p.load(0, i * 4096 * 16, 64);
        }
        assert!(p.stats.queue_full_waits >= 1);
    }

    fn cached_ssd_port(spec: CacheSpec) -> RootPort {
        RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            SrPolicy::Off,
            false,
            0,
        )
        .with_cache(spec)
    }

    fn admit_all_spec() -> CacheSpec {
        CacheSpec { enabled: true, ..CacheSpec::default() }.admit_all()
    }

    #[test]
    fn with_cache_attaches_only_nonzero_specs_on_ssd() {
        let p = cached_ssd_port(CacheSpec::default());
        assert!(p.cache.is_none(), "disabled spec attaches nothing");
        let z = CacheSpec { enabled: true, capacity_bytes: 0, ..CacheSpec::default() };
        assert!(cached_ssd_port(z).cache.is_none(), "zero capacity attaches nothing");
        assert!(cached_ssd_port(admit_all_spec()).cache.is_some());
        let dram = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        )
        .with_cache(admit_all_spec());
        assert!(dram.cache.is_none(), "DRAM EPs take no device cache");
    }

    #[test]
    fn device_cache_miss_fetch_then_spatial_hit() {
        let mut p = cached_ssd_port(admit_all_spec());
        let first = p.load(0, 0x1000, 64);
        assert!(first.done >= 3 * US, "admitted miss pays the media read");
        // The whole 256 B device-cache line came in with the fetch: a
        // later load of the *adjacent* 64 B hits device DRAM.
        let second = p.load(first.done, 0x10c0, 64);
        assert_eq!(second.path, LoadPath::EpCacheHit);
        assert!(second.done - first.done < 1 * US, "hit took {}", second.done - first.done);
        let c = p.cache.as_ref().unwrap();
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.hits, 1);
    }

    #[test]
    fn adaptive_admission_bypasses_a_pure_scan() {
        let spec = CacheSpec { enabled: true, ..CacheSpec::default() };
        let mut p = cached_ssd_port(spec);
        let mut now = 0;
        for i in 0..256u64 {
            now = p.load(now, i * 4096 * 8, 64).done;
        }
        let c = p.cache.as_ref().unwrap();
        assert!(c.stats.bypasses > 100, "scan must mostly bypass: {}", c.stats.bypasses);
        assert!(c.lines() < 64, "scan must not fill the cache: {} lines", c.lines());
    }

    #[test]
    fn store_hit_absorbs_in_device_dram_and_eviction_writes_back() {
        let mut rng = Pcg32::new(7, 7);
        // Tiny direct-mapped cache so conflict evictions are easy.
        let spec = CacheSpec {
            enabled: true,
            capacity_bytes: 4 << 10,
            ways: 1,
            ..CacheSpec::default()
        }
        .admit_all();
        let mut p = cached_ssd_port(spec);
        let warm = p.load(0, 0x0, 64).done; // install line 0
        let out = p.store(warm, 0x0, 64, &mut rng);
        assert!(out.ack - warm < 1 * US, "store hit must ack at DRAM speed: {}", out.ack - warm);
        assert_eq!(p.cache.as_ref().unwrap().dirty_lines(), 1);
        // Conflict-evict the dirty line (16 sets of 256 B lines).
        let t = p.load(out.ack, 16 * 256, 64).done;
        let c = p.cache.as_ref().unwrap();
        assert_eq!(c.stats.writebacks, 1, "dirty eviction must queue a writeback");
        // The next access drains the queue into the media.
        p.load(t, 32 * 256, 64);
        assert_eq!(p.cache.as_ref().unwrap().wb_pending(), 0, "drain retired the writeback");
        let EpBackend::Ssd(s) = &p.backend else { unreachable!() };
        assert!(s.stats.writes >= 1, "writeback must be charged as a media write");
    }

    #[test]
    fn sr_window_stages_into_the_device_cache_and_probes_suppress() {
        let mut p = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            SrPolicy::Dynamic,
            false,
            0,
        )
        .with_cache(admit_all_spec());
        let first = p.load(0, 0x4000, 64);
        let c = p.cache.as_ref().unwrap();
        assert!(c.stats.prefetch_installs > 0, "the SR window must stage into the cache");
        // A later load inside the staged window hits device DRAM.
        let second = p.load(first.done + 10 * US, 0x4100, 64);
        assert_eq!(second.path, LoadPath::EpCacheHit);
    }

    #[test]
    fn zero_capacity_cache_port_is_byte_identical() {
        let mut plain = ssd_port(SrPolicy::Dynamic, true);
        let mut zero = RootPort::new(
            0,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            SrPolicy::Dynamic,
            true,
            1 << 20,
        )
        .with_cache(CacheSpec { enabled: true, capacity_bytes: 0, ..CacheSpec::default() });
        let mut rng_a = Pcg32::new(11, 11);
        let mut rng_b = Pcg32::new(11, 11);
        let mut now = 0;
        for i in 0..200u64 {
            let a = plain.load(now, (i * 67) % (1 << 20) * 64, 64);
            let b = zero.load(now, (i * 67) % (1 << 20) * 64, 64);
            assert_eq!(a.done, b.done, "load {i} diverged");
            assert_eq!(a.path, b.path, "load {i} path diverged");
            let sa = plain.store(now, (i * 31) % (1 << 20) * 64, 64, &mut rng_a);
            let sb = zero.store(now, (i * 31) % (1 << 20) * 64, 64, &mut rng_b);
            assert_eq!(sa.ack, sb.ack, "store {i} diverged");
            now = now.max(a.done) + 100;
        }
        assert_eq!(plain.stats.queue_hwm, zero.stats.queue_hwm);
    }

    #[test]
    fn inert_ras_spec_attaches_no_state() {
        let armed_but_zero = FaultSpec { enabled: true, ..FaultSpec::default() };
        let p = ssd_port(SrPolicy::Off, false).with_ras(armed_but_zero, 42);
        assert!(p.ras.is_none(), "zero-rate spec must build nothing");
        let live = FaultSpec { enabled: true, crc_error_rate: 1e-6, ..FaultSpec::default() };
        assert!(ssd_port(SrPolicy::Off, false).with_ras(live, 42).ras.is_some());
    }

    #[test]
    fn crc_errors_charge_retry_legs_on_loads() {
        let spec = FaultSpec { enabled: true, crc_error_rate: 0.3, ..FaultSpec::default() };
        let mut faulty = ssd_port(SrPolicy::Off, false).with_ras(spec, 42);
        let mut clean = ssd_port(SrPolicy::Off, false);
        let (mut tf, mut tc) = (0u64, 0u64);
        let mut now = 0;
        for i in 0..300u64 {
            let a = faulty.load(now, i * 4096, 64);
            let b = clean.load(now, i * 4096, 64);
            tf += a.done - now;
            tc += b.done - now;
            now = a.done.max(b.done) + NS;
        }
        let r = faulty.ras.as_ref().expect("armed");
        assert!(r.stats.retries > 0, "30% flit corruption must retry");
        assert!(tf > tc, "retry legs must cost wall time: {tf} vs {tc}");
        // Exactly-once link accounting holds after every transfer.
        assert_eq!(r.replay.in_flight(), 0);
        let rs = r.replay.stats;
        assert_eq!(rs.sent, rs.delivered + rs.poisoned);
    }

    #[test]
    fn scheduled_degradation_rescues_dirty_lines_first() {
        let mut rng = Pcg32::new(9, 9);
        let spec = FaultSpec {
            enabled: true,
            degrade_at: 10 * US,
            degrade_port: 0,
            degrade_penalty: 5 * US,
            ..FaultSpec::default()
        };
        let mut p = cached_ssd_port(admit_all_spec()).with_ras(spec, 42);
        let warm = p.load(0, 0x0, 64).done; // install line 0
        let st = p.store(warm, 0x0, 64, &mut rng); // dirty it in device DRAM
        assert_eq!(p.cache.as_ref().expect("cache").dirty_lines(), 1);
        assert!(!p.is_degraded(), "not due yet");
        // First access past the deadline: drain the dirty line, then latch.
        p.load(st.ack.max(10 * US), 0x8000, 64);
        assert!(p.is_degraded());
        let r = p.ras.as_ref().expect("armed");
        assert_eq!(r.stats.failovers, 1);
        assert_eq!(r.stats.dirty_rescued_bytes, 256, "one 256B line rescued");
        assert_eq!(p.cache.as_ref().expect("cache").dirty_lines(), 0);
        let EpBackend::Ssd(s) = &p.backend else { unreachable!() };
        assert!(s.stats.writes >= 2, "the rescue must be charged as a media write");
    }

    #[test]
    fn degraded_port_pays_the_penalty_on_every_access() {
        let spec = FaultSpec {
            enabled: true,
            degrade_at: 1,
            degrade_port: 0,
            degrade_penalty: 50 * US,
            ..FaultSpec::default()
        };
        let mut p = ssd_port(SrPolicy::Off, false).with_ras(spec, 42);
        let out = p.load(10, 0x1000, 64);
        assert!(p.is_degraded());
        assert!(out.done - 10 >= 50 * US, "degraded access must pay the penalty");
    }
}
