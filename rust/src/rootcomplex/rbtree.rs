//! Red-black tree keyed by physical address.
//!
//! The DS engine keeps "a record of each stack entry's precise location
//! ... within the system bus's internal SRAM, which is implemented as a
//! red-black tree for efficient management" (§Fine control for internal
//! tasks). Implemented from scratch (arena-based, no unsafe): insert,
//! lookup, remove, in-order iteration, and an invariant checker used by
//! the property tests.

/// Node color.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Color {
    Red,
    Black,
}

const NIL: usize = usize::MAX;

#[derive(Debug, Clone)]
struct Node<V> {
    key: u64,
    val: V,
    color: Color,
    left: usize,
    right: usize,
    parent: usize,
}

/// Arena-based red-black tree map from `u64` keys to `V`.
#[derive(Debug, Clone)]
pub struct RbTree<V> {
    nodes: Vec<Node<V>>,
    free: Vec<usize>,
    root: usize,
    len: usize,
}

impl<V> Default for RbTree<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> RbTree<V> {
    pub fn new() -> Self {
        RbTree { nodes: Vec::new(), free: Vec::new(), root: NIL, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn alloc(&mut self, key: u64, val: V) -> usize {
        if let Some(i) = self.free.pop() {
            self.nodes[i] = Node { key, val, color: Color::Red, left: NIL, right: NIL, parent: NIL };
            i
        } else {
            self.nodes.push(Node { key, val, color: Color::Red, left: NIL, right: NIL, parent: NIL });
            self.nodes.len() - 1
        }
    }

    fn color(&self, n: usize) -> Color {
        if n == NIL {
            Color::Black
        } else {
            self.nodes[n].color
        }
    }

    /// Find the arena index for `key`.
    fn find(&self, key: u64) -> usize {
        let mut cur = self.root;
        while cur != NIL {
            let node = &self.nodes[cur];
            cur = match key.cmp(&node.key) {
                std::cmp::Ordering::Less => node.left,
                std::cmp::Ordering::Greater => node.right,
                std::cmp::Ordering::Equal => return cur,
            };
        }
        NIL
    }

    pub fn contains(&self, key: u64) -> bool {
        self.find(key) != NIL
    }

    pub fn get(&self, key: u64) -> Option<&V> {
        let i = self.find(key);
        if i == NIL {
            None
        } else {
            Some(&self.nodes[i].val)
        }
    }

    pub fn get_mut(&mut self, key: u64) -> Option<&mut V> {
        let i = self.find(key);
        if i == NIL {
            None
        } else {
            Some(&mut self.nodes[i].val)
        }
    }

    /// Insert (or replace). Returns the previous value for the key.
    pub fn insert(&mut self, key: u64, val: V) -> Option<V> {
        // BST descent.
        let mut parent = NIL;
        let mut cur = self.root;
        while cur != NIL {
            parent = cur;
            let node = &self.nodes[cur];
            match key.cmp(&node.key) {
                std::cmp::Ordering::Less => cur = node.left,
                std::cmp::Ordering::Greater => cur = node.right,
                std::cmp::Ordering::Equal => {
                    return Some(std::mem::replace(&mut self.nodes[cur].val, val));
                }
            }
        }
        let n = self.alloc(key, val);
        self.nodes[n].parent = parent;
        if parent == NIL {
            self.root = n;
        } else if key < self.nodes[parent].key {
            self.nodes[parent].left = n;
        } else {
            self.nodes[parent].right = n;
        }
        self.len += 1;
        self.fix_insert(n);
        None
    }

    fn rotate_left(&mut self, x: usize) {
        let y = self.nodes[x].right;
        debug_assert_ne!(y, NIL);
        let y_left = self.nodes[y].left;
        self.nodes[x].right = y_left;
        if y_left != NIL {
            self.nodes[y_left].parent = x;
        }
        let xp = self.nodes[x].parent;
        self.nodes[y].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp].left == x {
            self.nodes[xp].left = y;
        } else {
            self.nodes[xp].right = y;
        }
        self.nodes[y].left = x;
        self.nodes[x].parent = y;
    }

    fn rotate_right(&mut self, x: usize) {
        let y = self.nodes[x].left;
        debug_assert_ne!(y, NIL);
        let y_right = self.nodes[y].right;
        self.nodes[x].left = y_right;
        if y_right != NIL {
            self.nodes[y_right].parent = x;
        }
        let xp = self.nodes[x].parent;
        self.nodes[y].parent = xp;
        if xp == NIL {
            self.root = y;
        } else if self.nodes[xp].left == x {
            self.nodes[xp].left = y;
        } else {
            self.nodes[xp].right = y;
        }
        self.nodes[y].right = x;
        self.nodes[x].parent = y;
    }

    fn fix_insert(&mut self, mut z: usize) {
        while self.color(self.nodes[z].parent) == Color::Red {
            let p = self.nodes[z].parent;
            let g = self.nodes[p].parent;
            if g == NIL {
                break;
            }
            if p == self.nodes[g].left {
                let u = self.nodes[g].right;
                if self.color(u) == Color::Red {
                    self.nodes[p].color = Color::Black;
                    self.nodes[u].color = Color::Black;
                    self.nodes[g].color = Color::Red;
                    z = g;
                } else {
                    if z == self.nodes[p].right {
                        z = p;
                        self.rotate_left(z);
                    }
                    let p = self.nodes[z].parent;
                    let g = self.nodes[p].parent;
                    self.nodes[p].color = Color::Black;
                    self.nodes[g].color = Color::Red;
                    self.rotate_right(g);
                }
            } else {
                let u = self.nodes[g].left;
                if self.color(u) == Color::Red {
                    self.nodes[p].color = Color::Black;
                    self.nodes[u].color = Color::Black;
                    self.nodes[g].color = Color::Red;
                    z = g;
                } else {
                    if z == self.nodes[p].left {
                        z = p;
                        self.rotate_right(z);
                    }
                    let p = self.nodes[z].parent;
                    let g = self.nodes[p].parent;
                    self.nodes[p].color = Color::Black;
                    self.nodes[g].color = Color::Red;
                    self.rotate_left(g);
                }
            }
        }
        let r = self.root;
        self.nodes[r].color = Color::Black;
    }

    fn minimum(&self, mut n: usize) -> usize {
        while self.nodes[n].left != NIL {
            n = self.nodes[n].left;
        }
        n
    }

    fn transplant(&mut self, u: usize, v: usize) {
        let up = self.nodes[u].parent;
        if up == NIL {
            self.root = v;
        } else if self.nodes[up].left == u {
            self.nodes[up].left = v;
        } else {
            self.nodes[up].right = v;
        }
        if v != NIL {
            self.nodes[v].parent = up;
        }
    }

    /// Remove `key`, returning its value.
    pub fn remove(&mut self, key: u64) -> Option<V>
    where
        V: Default,
    {
        let z = self.find(key);
        if z == NIL {
            return None;
        }
        let fix_parent; // parent of the "moved-up" position when x is NIL
        let mut y = z;
        let mut y_color = self.nodes[y].color;
        let x;
        if self.nodes[z].left == NIL {
            x = self.nodes[z].right;
            fix_parent = self.nodes[z].parent;
            self.transplant(z, x);
        } else if self.nodes[z].right == NIL {
            x = self.nodes[z].left;
            fix_parent = self.nodes[z].parent;
            self.transplant(z, x);
        } else {
            y = self.minimum(self.nodes[z].right);
            y_color = self.nodes[y].color;
            x = self.nodes[y].right;
            if self.nodes[y].parent == z {
                fix_parent = y;
                if x != NIL {
                    self.nodes[x].parent = y;
                }
            } else {
                fix_parent = self.nodes[y].parent;
                self.transplant(y, x);
                let zr = self.nodes[z].right;
                self.nodes[y].right = zr;
                self.nodes[zr].parent = y;
            }
            self.transplant(z, y);
            let zl = self.nodes[z].left;
            self.nodes[y].left = zl;
            self.nodes[zl].parent = y;
            self.nodes[y].color = self.nodes[z].color;
        }
        if y_color == Color::Black {
            self.fix_remove(x, fix_parent);
        }
        self.len -= 1;
        self.free.push(z);
        let val = std::mem::take(&mut self.nodes[z].val);
        // Poison the freed node so stale references are caught in tests.
        self.nodes[z].parent = NIL;
        self.nodes[z].left = NIL;
        self.nodes[z].right = NIL;
        Some(val)
    }

    fn fix_remove(&mut self, mut x: usize, mut parent: usize) {
        while x != self.root && self.color(x) == Color::Black {
            if parent == NIL {
                break;
            }
            if x == self.nodes[parent].left {
                let mut w = self.nodes[parent].right;
                if self.color(w) == Color::Red {
                    self.nodes[w].color = Color::Black;
                    self.nodes[parent].color = Color::Red;
                    self.rotate_left(parent);
                    w = self.nodes[parent].right;
                }
                if self.color(self.nodes[w].left) == Color::Black
                    && self.color(self.nodes[w].right) == Color::Black
                {
                    self.nodes[w].color = Color::Red;
                    x = parent;
                    parent = self.nodes[x].parent;
                } else {
                    if self.color(self.nodes[w].right) == Color::Black {
                        let wl = self.nodes[w].left;
                        if wl != NIL {
                            self.nodes[wl].color = Color::Black;
                        }
                        self.nodes[w].color = Color::Red;
                        self.rotate_right(w);
                        w = self.nodes[parent].right;
                    }
                    self.nodes[w].color = self.nodes[parent].color;
                    self.nodes[parent].color = Color::Black;
                    let wr = self.nodes[w].right;
                    if wr != NIL {
                        self.nodes[wr].color = Color::Black;
                    }
                    self.rotate_left(parent);
                    x = self.root;
                    break;
                }
            } else {
                let mut w = self.nodes[parent].left;
                if self.color(w) == Color::Red {
                    self.nodes[w].color = Color::Black;
                    self.nodes[parent].color = Color::Red;
                    self.rotate_right(parent);
                    w = self.nodes[parent].left;
                }
                if self.color(self.nodes[w].right) == Color::Black
                    && self.color(self.nodes[w].left) == Color::Black
                {
                    self.nodes[w].color = Color::Red;
                    x = parent;
                    parent = self.nodes[x].parent;
                } else {
                    if self.color(self.nodes[w].left) == Color::Black {
                        let wr = self.nodes[w].right;
                        if wr != NIL {
                            self.nodes[wr].color = Color::Black;
                        }
                        self.nodes[w].color = Color::Red;
                        self.rotate_left(w);
                        w = self.nodes[parent].left;
                    }
                    self.nodes[w].color = self.nodes[parent].color;
                    self.nodes[parent].color = Color::Black;
                    let wl = self.nodes[w].left;
                    if wl != NIL {
                        self.nodes[wl].color = Color::Black;
                    }
                    self.rotate_right(parent);
                    x = self.root;
                    break;
                }
            }
        }
        if x != NIL {
            self.nodes[x].color = Color::Black;
        }
    }

    /// In-order key iteration (ascending).
    pub fn keys(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.len);
        let mut stack = Vec::new();
        let mut cur = self.root;
        while cur != NIL || !stack.is_empty() {
            while cur != NIL {
                stack.push(cur);
                cur = self.nodes[cur].left;
            }
            let n = stack.pop().unwrap();
            out.push(self.nodes[n].key);
            cur = self.nodes[n].right;
        }
        out
    }

    /// Smallest key >= `key` (for flush scans).
    pub fn ceiling(&self, key: u64) -> Option<u64> {
        let mut best = None;
        let mut cur = self.root;
        while cur != NIL {
            let node = &self.nodes[cur];
            if node.key >= key {
                best = Some(node.key);
                cur = node.left;
            } else {
                cur = node.right;
            }
        }
        best
    }

    /// First key in-order (minimum).
    pub fn first(&self) -> Option<u64> {
        if self.root == NIL {
            None
        } else {
            Some(self.nodes[self.minimum(self.root)].key)
        }
    }

    /// Validate red-black invariants. Returns black-height or an error.
    pub fn check_invariants(&self) -> Result<usize, String> {
        if self.root != NIL && self.nodes[self.root].color == Color::Red {
            return Err("root is red".into());
        }
        self.check_node(self.root, u64::MIN, u64::MAX)
    }

    fn check_node(&self, n: usize, lo: u64, hi: u64) -> Result<usize, String> {
        if n == NIL {
            return Ok(1);
        }
        let node = &self.nodes[n];
        if !(lo..=hi).contains(&node.key) {
            return Err(format!("BST order violated at key {}", node.key));
        }
        if node.color == Color::Red {
            if self.color(node.left) == Color::Red || self.color(node.right) == Color::Red {
                return Err(format!("red-red violation at key {}", node.key));
            }
        }
        let lh = self.check_node(node.left, lo, node.key.saturating_sub(1))?;
        let rh = self.check_node(node.right, node.key.saturating_add(1), hi)?;
        if lh != rh {
            return Err(format!("black-height mismatch at key {}: {lh} vs {rh}", node.key));
        }
        Ok(lh + if node.color == Color::Black { 1 } else { 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut t: RbTree<u32> = RbTree::new();
        assert!(t.insert(10, 1).is_none());
        assert!(t.insert(5, 2).is_none());
        assert!(t.insert(15, 3).is_none());
        assert_eq!(t.get(5), Some(&2));
        assert_eq!(t.insert(5, 9), Some(2));
        assert_eq!(t.remove(5), Some(9));
        assert_eq!(t.get(5), None);
        assert_eq!(t.len(), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn in_order_keys_sorted() {
        let mut t: RbTree<()> = RbTree::new();
        for k in [50u64, 20, 80, 10, 30, 70, 90, 25, 35] {
            t.insert(k, ());
        }
        let keys = t.keys();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
        t.check_invariants().unwrap();
    }

    #[test]
    fn ceiling_and_first() {
        let mut t: RbTree<()> = RbTree::new();
        for k in [10u64, 20, 30] {
            t.insert(k, ());
        }
        assert_eq!(t.ceiling(15), Some(20));
        assert_eq!(t.ceiling(20), Some(20));
        assert_eq!(t.ceiling(31), None);
        assert_eq!(t.first(), Some(10));
    }

    #[test]
    fn random_workout_keeps_invariants() {
        let mut t: RbTree<u64> = RbTree::new();
        let mut reference = std::collections::BTreeMap::new();
        let mut rng = Pcg32::new(99, 0);
        for step in 0..5000 {
            let key = rng.below(500);
            if rng.chance(0.6) {
                t.insert(key, step);
                reference.insert(key, step);
            } else {
                assert_eq!(t.remove(key), reference.remove(&key), "step {step} key {key}");
            }
            if step % 64 == 0 {
                t.check_invariants().unwrap();
                assert_eq!(t.len(), reference.len());
            }
        }
        let keys: Vec<u64> = reference.keys().copied().collect();
        assert_eq!(t.keys(), keys);
    }

    #[test]
    fn empty_tree_behaviour() {
        let mut t: RbTree<u8> = RbTree::new();
        assert!(t.is_empty());
        assert_eq!(t.remove(7), None);
        assert_eq!(t.first(), None);
        assert_eq!(t.ceiling(0), None);
        t.check_invariants().unwrap();
    }
}
