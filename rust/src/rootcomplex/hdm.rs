//! HDM decoder: maps host physical addresses (HPA) to root ports.
//!
//! During initialization the simplified core enumerates CXL EPs, reads
//! their HDM capability registers, and programs the host bridge's HDM
//! decoder with each root port's base/size (Fig. 5a). At run time every
//! expander request consults this decoder to pick its port.
//!
//! Windows come in two flavours, mirroring the CXL HDM decoder's IW/IG
//! fields:
//!
//! * **Direct** ([`HdmEntry::direct`]) — one port owns the whole window;
//!   the decoded device address is simply `hpa - base`. This is the
//!   seed's behaviour and what [`super::RootComplex::enumerate`] programs.
//! * **Interleaved** ([`HdmEntry::interleaved`]) — 2/4/8 same-media ports
//!   stripe the window at a power-of-two granularity (IG). Consecutive
//!   granules rotate across the target list (IW), so a dense request
//!   stream engages every port's queue and media in parallel — this is
//!   how multi-port DRAM configurations turn port fan-out into bandwidth.
//!
//! Interleave math (the CXL HPA→DPA convention, with the window base
//! subtracted first): for window offset `o`, the way is
//! `(o >> IG) % IW` and the device address drops the way-selector bits:
//! `dpa = ((o >> (IG + log2 IW)) << IG) | (o & (2^IG - 1))`.

/// Upper bound on interleave ways per window (CXL supports up to 8-way
/// power-of-two interleaving at the host bridge, which is all this model
/// needs; a fixed-size target array keeps [`HdmEntry`] `Copy`).
pub const MAX_INTERLEAVE_WAYS: usize = 8;

/// One HDM window: a `[base, base+size)` HPA range owned by one port
/// (direct) or striped across 2/4/8 ports (interleaved).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HdmEntry {
    pub base: u64,
    /// Total window bytes across all ways.
    pub size: u64,
    /// Target root ports, one per way; only the first [`HdmEntry::ways`]
    /// entries are meaningful.
    pub targets: [usize; MAX_INTERLEAVE_WAYS],
    /// Interleave ways (IW): 1 (direct), 2, 4 or 8.
    pub ways: usize,
    /// Interleave granularity (IG) as log2 bytes; ignored for direct
    /// windows.
    pub gran_bits: u32,
    /// Device-address offset added to every decoded DPA. Lets a port own
    /// several windows without their device-address ranges aliasing
    /// (e.g. the direct remainder window behind an interleaved bulk
    /// window starts its DPAs where the bulk's per-way span ends).
    pub dpa_base: u64,
}

impl HdmEntry {
    /// A direct (non-interleaved) window owned entirely by `port`.
    pub fn direct(port: usize, base: u64, size: u64) -> HdmEntry {
        let mut targets = [0usize; MAX_INTERLEAVE_WAYS];
        targets[0] = port;
        HdmEntry { base, size, targets, ways: 1, gran_bits: 0, dpa_base: 0 }
    }

    /// A window striped across `ports` (2, 4 or 8 of them) at
    /// `1 << gran_bits` bytes per granule.
    pub fn interleaved(ports: &[usize], base: u64, size: u64, gran_bits: u32) -> HdmEntry {
        assert!(
            matches!(ports.len(), 2 | 4 | 8),
            "interleave ways must be 2/4/8, got {}",
            ports.len()
        );
        let mut targets = [0usize; MAX_INTERLEAVE_WAYS];
        targets[..ports.len()].copy_from_slice(ports);
        HdmEntry { base, size, targets, ways: ports.len(), gran_bits, dpa_base: 0 }
    }

    /// Offset every decoded DPA by `dpa_base` (see the field docs).
    pub fn with_dpa_base(mut self, dpa_base: u64) -> HdmEntry {
        self.dpa_base = dpa_base;
        self
    }

    /// Exclusive end of the window. Saturating: [`HdmDecoder::program`]
    /// rejects windows whose true end would wrap past the address space,
    /// so a saturated value can only be observed on hand-built entries.
    pub fn end(&self) -> u64 {
        self.base.saturating_add(self.size)
    }

    pub fn contains(&self, hpa: u64) -> bool {
        (self.base..self.end()).contains(&hpa)
    }

    /// The single owner of a direct window (first target).
    pub fn port(&self) -> usize {
        self.targets[0]
    }

    /// Bytes decoded to each way.
    pub fn per_way(&self) -> u64 {
        self.size / self.ways as u64
    }

    /// One full rotation of the interleave pattern, in bytes.
    fn stripe(&self) -> u64 {
        (self.ways as u64) << self.gran_bits
    }

    /// Decode an in-window HPA to (port, device address).
    pub fn decode_at(&self, hpa: u64) -> (usize, u64) {
        debug_assert!(self.contains(hpa));
        let off = hpa - self.base;
        if self.ways == 1 {
            return (self.targets[0], self.dpa_base + off);
        }
        let way = ((off >> self.gran_bits) as usize) & (self.ways - 1);
        let gran_mask = (1u64 << self.gran_bits) - 1;
        let way_bits = self.ways.trailing_zeros();
        let dpa = ((off >> (self.gran_bits + way_bits)) << self.gran_bits) | (off & gran_mask);
        (self.targets[way], self.dpa_base + dpa)
    }

    /// Inverse of [`HdmEntry::decode_at`]: the HPA that decodes to
    /// `(targets[way], dpa)`. Used by firmware sanity checks and the
    /// round-trip property test.
    pub fn hpa_of(&self, way: usize, dpa: u64) -> u64 {
        let dpa = dpa - self.dpa_base;
        if self.ways == 1 {
            return self.base + dpa;
        }
        debug_assert!(way < self.ways);
        let gran_mask = (1u64 << self.gran_bits) - 1;
        let way_bits = self.ways.trailing_zeros();
        self.base
            + (((dpa >> self.gran_bits) << (self.gran_bits + way_bits))
                | ((way as u64) << self.gran_bits)
                | (dpa & gran_mask))
    }
}

/// The host bridge's HDM decoder: a sorted, non-overlapping window list.
#[derive(Debug, Clone, Default)]
pub struct HdmDecoder {
    entries: Vec<HdmEntry>,
}

impl HdmDecoder {
    pub fn new() -> HdmDecoder {
        HdmDecoder { entries: Vec::new() }
    }

    /// Program a window. Firmware runs once at init, so malformed windows
    /// are a programming error and rejected: zero size, an end that wraps
    /// the 64-bit address space, a non-power-of-two way count, a size
    /// that doesn't stripe evenly, duplicate targets, or any overlap with
    /// an already-programmed window.
    pub fn program(&mut self, entry: HdmEntry) -> Result<(), String> {
        if entry.size == 0 {
            return Err("zero-size HDM window".into());
        }
        // `base + size` must not wrap: a window reaching past u64::MAX
        // would make `end()` alias low addresses and corrupt routing.
        let end = entry
            .base
            .checked_add(entry.size)
            .ok_or_else(|| {
                format!(
                    "HDM window [{:#x}, +{:#x}) wraps the address space",
                    entry.base, entry.size
                )
            })?;
        if entry.dpa_base.checked_add(entry.size).is_none() {
            return Err(format!(
                "device-address range [{:#x}, +{:#x}) wraps",
                entry.dpa_base, entry.size
            ));
        }
        if !matches!(entry.ways, 1 | 2 | 4 | 8) {
            return Err(format!("interleave ways must be 1/2/4/8, got {}", entry.ways));
        }
        if entry.ways > 1 {
            if !(6..=16).contains(&entry.gran_bits) {
                return Err(format!(
                    "interleave granularity 2^{} out of the 64B..64KiB range",
                    entry.gran_bits
                ));
            }
            if entry.size % entry.stripe() != 0 {
                return Err(format!(
                    "window size {:#x} not a multiple of the {}x{:#x} stripe",
                    entry.size,
                    entry.ways,
                    1u64 << entry.gran_bits
                ));
            }
            for i in 0..entry.ways {
                for j in (i + 1)..entry.ways {
                    if entry.targets[i] == entry.targets[j] {
                        return Err(format!(
                            "duplicate interleave target port {}",
                            entry.targets[i]
                        ));
                    }
                }
            }
        }
        for e in &self.entries {
            if entry.base < e.end() && e.base < end {
                return Err(format!(
                    "HDM window [{:#x},{:#x}) overlaps window [{:#x},{:#x})",
                    entry.base,
                    end,
                    e.base,
                    e.end()
                ));
            }
        }
        self.entries.push(entry);
        self.entries.sort_by_key(|e| e.base);
        Ok(())
    }

    /// Decode an HPA to (port, device address within that port's HDM).
    pub fn decode(&self, hpa: u64) -> Option<(usize, u64)> {
        // Binary search over sorted bases.
        let idx = self.entries.partition_point(|e| e.base <= hpa);
        if idx == 0 {
            return None;
        }
        let e = &self.entries[idx - 1];
        if e.contains(hpa) {
            Some(e.decode_at(hpa))
        } else {
            None
        }
    }

    /// The programmed windows, sorted by base.
    pub fn entries(&self) -> &[HdmEntry] {
        &self.entries
    }

    /// Total decoded bytes.
    pub fn total_size(&self) -> u64 {
        self.entries.iter().map(|e| e.size).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_and_decode() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry::direct(0, 0x0, 0x1000)).unwrap();
        d.program(HdmEntry::direct(1, 0x1000, 0x2000)).unwrap();
        assert_eq!(d.decode(0x0), Some((0, 0)));
        assert_eq!(d.decode(0xfff), Some((0, 0xfff)));
        assert_eq!(d.decode(0x1000), Some((1, 0)));
        assert_eq!(d.decode(0x2fff), Some((1, 0x1fff)));
        assert_eq!(d.decode(0x3000), None);
    }

    #[test]
    fn rejects_overlap() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry::direct(0, 0x1000, 0x1000)).unwrap();
        assert!(d.program(HdmEntry::direct(1, 0x1800, 0x1000)).is_err());
        assert!(d.program(HdmEntry::direct(1, 0x0, 0x1001)).is_err());
        assert!(d.program(HdmEntry::direct(1, 0x2000, 0)).is_err());
    }

    #[test]
    fn rejects_wrapping_window() {
        // Regression: `base + size` used to wrap silently, making `end()`
        // alias low addresses. `program` must reject the window instead.
        let mut d = HdmDecoder::new();
        assert!(d.program(HdmEntry::direct(0, u64::MAX - 0xfff, 0x2000)).is_err());
        assert!(d.program(HdmEntry::direct(0, u64::MAX, 1)).is_err());
        // A window ending exactly at the top of the space is fine.
        d.program(HdmEntry::direct(0, u64::MAX - 0x1000, 0x1000)).unwrap();
        assert_eq!(d.decode(u64::MAX - 1), Some((0, 0xffe)));
    }

    #[test]
    fn gaps_decode_to_none() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry::direct(0, 0x0, 0x100)).unwrap();
        d.program(HdmEntry::direct(1, 0x1000, 0x100)).unwrap();
        assert_eq!(d.decode(0x500), None);
    }

    #[test]
    fn total_size_sums_windows() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry::direct(0, 0, 10 << 20)).unwrap();
        d.program(HdmEntry::direct(1, 10 << 20, 30 << 20)).unwrap();
        assert_eq!(d.total_size(), 40 << 20);
    }

    #[test]
    fn two_way_interleave_alternates_granules() {
        let mut d = HdmDecoder::new();
        // Ports 3 and 5, 2-way, 4 KiB granules, 64 KiB window.
        d.program(HdmEntry::interleaved(&[3, 5], 0, 64 << 10, 12)).unwrap();
        assert_eq!(d.decode(0x0000), Some((3, 0x0000)));
        assert_eq!(d.decode(0x1000), Some((5, 0x0000)));
        assert_eq!(d.decode(0x2000), Some((3, 0x1000)));
        assert_eq!(d.decode(0x3000), Some((5, 0x1000)));
        // Intra-granule offsets survive the way-bit removal.
        assert_eq!(d.decode(0x3040), Some((5, 0x1040)));
    }

    #[test]
    fn four_way_interleave_covers_each_port_equally() {
        let mut d = HdmDecoder::new();
        let e = HdmEntry::interleaved(&[0, 1, 2, 3], 0x10000, 64 << 10, 8);
        d.program(e).unwrap();
        let mut per_port = [0u64; 4];
        for g in 0..(64 << 10) / 256 {
            let (p, _) = d.decode(0x10000 + g * 256).unwrap();
            per_port[p] += 1;
        }
        assert_eq!(per_port, [64, 64, 64, 64]);
        assert_eq!(e.per_way(), 16 << 10);
    }

    #[test]
    fn interleave_round_trips_through_hpa_of() {
        let e = HdmEntry::interleaved(&[2, 7], 0x4000, 32 << 10, 10);
        for way in 0..2 {
            for dpa in [0u64, 0x3ff, 0x400, 0x1234, (16 << 10) - 1] {
                let hpa = e.hpa_of(way, dpa);
                assert!(e.contains(hpa), "{hpa:#x} outside the window");
                assert_eq!(e.decode_at(hpa), (e.targets[way], dpa));
            }
        }
    }

    #[test]
    fn dpa_base_offsets_the_decoded_device_address() {
        let mut d = HdmDecoder::new();
        // One port, two windows: the second continues the first's DPA
        // space instead of aliasing it back to zero.
        d.program(HdmEntry::direct(4, 0x0, 0x1000)).unwrap();
        d.program(HdmEntry::direct(4, 0x1000, 0x800).with_dpa_base(0x1000)).unwrap();
        assert_eq!(d.decode(0xfff), Some((4, 0xfff)));
        assert_eq!(d.decode(0x1000), Some((4, 0x1000)));
        assert_eq!(d.decode(0x17ff), Some((4, 0x17ff)));
        let e = HdmEntry::direct(4, 0x1000, 0x800).with_dpa_base(0x1000);
        assert_eq!(e.hpa_of(0, 0x1200), 0x1200);
    }

    #[test]
    fn rejects_malformed_interleave() {
        let mut d = HdmDecoder::new();
        // Unaligned size (not a stripe multiple).
        assert!(d
            .program(HdmEntry::interleaved(&[0, 1], 0, (8 << 10) + 256, 12))
            .is_err());
        // Duplicate targets.
        assert!(d.program(HdmEntry::interleaved(&[1, 1], 0, 8 << 10, 12)).is_err());
        // Granularity out of range.
        assert!(d.program(HdmEntry::interleaved(&[0, 1], 0, 8 << 10, 2)).is_err());
        // 3-way rejected by program() on a hand-built entry.
        let mut bad = HdmEntry::interleaved(&[0, 1], 0, 96 << 10, 12);
        bad.ways = 3;
        assert!(d.program(bad).is_err());
    }
}
