//! HDM decoder: maps host physical addresses (HPA) to root ports.
//!
//! During initialization the simplified core enumerates CXL EPs, reads
//! their HDM capability registers, and programs the host bridge's HDM
//! decoder with each root port's base/size (Fig. 5a). At run time every
//! expander request consults this decoder to pick its port.

/// One root port's HDM window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HdmEntry {
    pub port: usize,
    pub base: u64,
    pub size: u64,
}

impl HdmEntry {
    pub fn end(&self) -> u64 {
        self.base + self.size
    }

    pub fn contains(&self, hpa: u64) -> bool {
        (self.base..self.end()).contains(&hpa)
    }
}

/// The host bridge's HDM decoder: a sorted, non-overlapping window list.
#[derive(Debug, Clone, Default)]
pub struct HdmDecoder {
    entries: Vec<HdmEntry>,
}

impl HdmDecoder {
    pub fn new() -> HdmDecoder {
        HdmDecoder { entries: Vec::new() }
    }

    /// Program a window. Firmware runs once at init, so overlaps are a
    /// programming error and rejected.
    pub fn program(&mut self, entry: HdmEntry) -> Result<(), String> {
        if entry.size == 0 {
            return Err("zero-size HDM window".into());
        }
        for e in &self.entries {
            if entry.base < e.end() && e.base < entry.end() {
                return Err(format!(
                    "HDM window [{:#x},{:#x}) overlaps port {} window [{:#x},{:#x})",
                    entry.base,
                    entry.end(),
                    e.port,
                    e.base,
                    e.end()
                ));
            }
        }
        self.entries.push(entry);
        self.entries.sort_by_key(|e| e.base);
        Ok(())
    }

    /// Decode an HPA to (port, offset-within-window).
    pub fn decode(&self, hpa: u64) -> Option<(usize, u64)> {
        // Binary search over sorted bases.
        let idx = self.entries.partition_point(|e| e.base <= hpa);
        if idx == 0 {
            return None;
        }
        let e = &self.entries[idx - 1];
        if e.contains(hpa) {
            Some((e.port, hpa - e.base))
        } else {
            None
        }
    }

    pub fn entries(&self) -> &[HdmEntry] {
        &self.entries
    }

    /// Total decoded bytes.
    pub fn total_size(&self) -> u64 {
        self.entries.iter().map(|e| e.size).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_and_decode() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry { port: 0, base: 0x0, size: 0x1000 }).unwrap();
        d.program(HdmEntry { port: 1, base: 0x1000, size: 0x2000 }).unwrap();
        assert_eq!(d.decode(0x0), Some((0, 0)));
        assert_eq!(d.decode(0xfff), Some((0, 0xfff)));
        assert_eq!(d.decode(0x1000), Some((1, 0)));
        assert_eq!(d.decode(0x2fff), Some((1, 0x1fff)));
        assert_eq!(d.decode(0x3000), None);
    }

    #[test]
    fn rejects_overlap() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry { port: 0, base: 0x1000, size: 0x1000 }).unwrap();
        assert!(d.program(HdmEntry { port: 1, base: 0x1800, size: 0x1000 }).is_err());
        assert!(d.program(HdmEntry { port: 1, base: 0x0, size: 0x1001 }).is_err());
        assert!(d.program(HdmEntry { port: 1, base: 0x2000, size: 0 }).is_err());
    }

    #[test]
    fn gaps_decode_to_none() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry { port: 0, base: 0x0, size: 0x100 }).unwrap();
        d.program(HdmEntry { port: 1, base: 0x1000, size: 0x100 }).unwrap();
        assert_eq!(d.decode(0x500), None);
    }

    #[test]
    fn total_size_sums_windows() {
        let mut d = HdmDecoder::new();
        d.program(HdmEntry { port: 0, base: 0, size: 10 << 20 }).unwrap();
        d.program(HdmEntry { port: 1, base: 10 << 20, size: 30 << 20 }).unwrap();
        assert_eq!(d.total_size(), 40 << 20);
    }
}
