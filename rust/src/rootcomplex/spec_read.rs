//! Speculative Read (SR) engine — the queue logic beneath each root port
//! (Figs. 6 and 7).
//!
//! On every incoming load the SR reader may emit a `MemSpecRd` so the EP
//! can stage data in its internal DRAM before the demand read lands. The
//! three policy levels reproduce Fig. 9d's ablation:
//!
//! * [`SrPolicy::Naive`] (CXL-NAIVE): blindly issue a 64 B MemSpecRd for
//!   every memory request.
//! * [`SrPolicy::Dynamic`] (CXL-DYN): use the repurposed low address bits
//!   to issue larger requests, sizing granularity from the endpoint's
//!   DevLoad telemetry (light -> grow to 1024 B, optimal -> hold,
//!   moderate -> shrink, severe -> halt).
//! * [`SrPolicy::Window`] (CXL-SR): additionally compute an address
//!   window from the memory queue (past) and SR queue (future) so the
//!   prefetch may extend *backwards* for descending streams ("Around"
//!   patterns), rounded to 256 B.

use std::collections::VecDeque;

use crate::cxl::{DevLoad, Flit, SPECRD_OFFSET_UNIT};
use crate::sim::Time;

/// SR aggressiveness (Fig. 9d configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrPolicy {
    /// SR disabled (plain CXL).
    Off,
    /// CXL-NAIVE.
    Naive,
    /// CXL-DYN.
    Dynamic,
    /// CXL-SR (full: DYN + address-window control).
    Window,
}

/// Queue capacities from the paper: "two separate queues: the SR queue
/// and the memory queue, each with a capacity of 32 entries".
pub const SR_QUEUE_CAP: usize = 32;
pub const MEM_QUEUE_CAP: usize = 32;
/// Ring buffer of issued SR windows used for dedup.
pub const RING_CAP: usize = 64;

/// Counters for the Fig. 9d analysis.
#[derive(Debug, Clone, Default)]
pub struct SrStats {
    pub loads_seen: u64,
    pub sr_issued: u64,
    pub sr_bytes: u64,
    pub dedup_forwarded: u64,
    /// Hints suppressed because the port's device-cache probe found the
    /// candidate window already resident in device DRAM (DESIGN.md §14).
    pub cache_suppressed: u64,
    pub halted: u64,
    pub streak_grows: u64,
    pub shrinks: u64,
    pub grows: u64,
}

/// The per-port SR engine.
#[derive(Debug)]
pub struct SpecReadEngine {
    pub policy: SrPolicy,
    /// Current SpecRd granularity in bytes (256..=1024), DevLoad-driven.
    granularity: u64,
    /// Issue 1 of every `period` loads (DevLoad-driven frequency control;
    /// 1 = every load, 8 = severe-overload trickle).
    period: u64,
    /// Issued-window ring buffer: (addr, len).
    ring: VecDeque<(u64, u64)>,
    /// Pending loads whose SR has not been issued yet (SR queue).
    sr_queue: VecDeque<u64>,
    /// Consecutive dedup-covered loads (on-stream evidence).
    dedup_streak: u32,
    /// Adaptive prefetch lead distance in bytes: how far beyond the
    /// demand front windows are placed. Grows when demands keep missing
    /// or waiting on in-flight prefetches (windows landing late), decays
    /// slowly when demands hit promptly.
    lead: u64,
    pub stats: SrStats,
}

impl SpecReadEngine {
    pub fn new(policy: SrPolicy) -> SpecReadEngine {
        SpecReadEngine {
            policy,
            granularity: 4 * SPECRD_OFFSET_UNIT,
            period: 1,
            ring: VecDeque::with_capacity(RING_CAP),
            sr_queue: VecDeque::with_capacity(SR_QUEUE_CAP),
            dedup_streak: 0,
            lead: 1024,
            stats: SrStats::default(),
        }
    }

    pub fn granularity(&self) -> u64 {
        self.granularity
    }

    /// Current issue period (1 = every load).
    pub fn period(&self) -> u64 {
        self.period
    }

    /// Current prefetch lead distance in bytes.
    pub fn lead(&self) -> u64 {
        self.lead
    }

    /// Feedback from the demand path: a load paid backend-media latency
    /// (window was behind the front) or waited on an in-flight prefetch
    /// (window was issued too late). Deepen the lead.
    pub fn feedback_late(&mut self) {
        self.lead = (self.lead + 256).min(32 << 10);
    }

    /// Feedback: a load hit promptly — the windows are early enough;
    /// decay the lead slowly toward its floor.
    pub fn feedback_timely(&mut self) {
        self.lead = self.lead.saturating_sub(32).max(512);
    }

    /// Covered-window evidence (ring dedup or device-cache residency):
    /// sustained coverage means the windows are tracking the stream —
    /// widen them even if the EP's DevLoad never reports Light (a
    /// saturated-but-recovering EP would otherwise pin the granularity
    /// at its floor).
    fn note_on_stream_evidence(&mut self) {
        self.dedup_streak += 1;
        if self.dedup_streak >= 16 {
            self.dedup_streak = 0;
            if self.granularity < 1024 {
                self.granularity *= 2;
                self.stats.streak_grows += 1;
            }
        }
    }

    /// The port probed the expander's device cache for the window this
    /// engine just emitted and found it fully resident: the hint was
    /// dropped before crossing the link. Like ring dedup, residency is
    /// on-stream evidence, so it feeds the same streak-widening loop —
    /// after first undoing the emission path's off-stream decrement
    /// (the window turned out to be covered after all; without the
    /// undo, suppression evidence would only ever cancel to net zero
    /// and cache-resident streams could never widen their windows).
    pub fn hint_covered_by_cache(&mut self) {
        self.stats.cache_suppressed += 1;
        self.dedup_streak += 1;
        self.note_on_stream_evidence();
    }

    /// Record a DevLoad observation from a completion (the profiler path)
    /// and adapt granularity *and frequency* (§Load control for
    /// speculative reads: "the DevLoad metric ... is shared with the SR
    /// reader to dynamically adjust the frequency of SR requests").
    pub fn observe_devload(&mut self, dl: DevLoad) {
        match dl {
            DevLoad::Light => {
                self.period = 1;
                if self.granularity < 1024 {
                    self.granularity = (self.granularity * 2).min(1024);
                    self.stats.grows += 1;
                }
            }
            DevLoad::Optimal => {
                // Operate at full bandwidth: hold granularity/frequency.
                self.period = 1;
            }
            DevLoad::Moderate => {
                self.period = 1;
                if self.granularity > SPECRD_OFFSET_UNIT {
                    self.granularity = (self.granularity / 2).max(SPECRD_OFFSET_UNIT);
                    self.stats.shrinks += 1;
                }
            }
            DevLoad::Severe => {
                // Reduced frequency (every other load may speculate), at
                // unchanged granularity. A full halt would be a stable
                // bad equilibrium — a miss-bound stream keeps the queue
                // full forever, so SR would never restart; the window
                // dedup already suppresses redundant speculation, so the
                // residual rate costs the EP almost nothing.
                self.period = 2;
                self.stats.halted += 1;
            }
        }
    }

    /// Is every 256 B unit of `[start, start+len)` already covered by an
    /// issued SR window? (The ring-buffer check — applied to the window
    /// the reader is *about* to issue, since windows sit ahead of the
    /// demand address.)
    fn window_covered(&self, start: u64, len: u64) -> bool {
        let unit = SPECRD_OFFSET_UNIT;
        let mut covered = 0u64;
        let mut total = 0u64;
        let mut u = start / unit * unit;
        while u < start + len {
            total += 1;
            if self.ring.iter().any(|&(a, l)| a <= u && u + unit <= a + l) {
                covered += 1;
            }
            u += unit;
        }
        // Mostly-covered windows are suppressed: re-fetching one fringe
        // unit is not worth a backend op (jittering walk patterns would
        // otherwise spray near-duplicate windows).
        covered * 2 > total
    }

    fn remember(&mut self, addr: u64, len: u64) {
        if self.ring.len() == RING_CAP {
            self.ring.pop_front();
        }
        self.ring.push_back((addr, len));
    }

    /// Process an incoming load at `now`. `mem_queue` holds the addresses
    /// of demand reads currently outstanding at the port (the memory
    /// queue). Returns a `MemSpecRd` flit to issue, if any.
    pub fn on_load(
        &mut self,
        now: Time,
        addr: u64,
        mem_queue: &VecDeque<u64>,
        req_id: u64,
    ) -> Option<Flit> {
        self.stats.loads_seen += 1;
        if self.policy == SrPolicy::Off {
            return None;
        }
        // Frequency control: under load only every `period`-th load
        // generates speculation; the rest queue as anticipated work.
        if self.stats.loads_seen % self.period != 0 {
            if self.sr_queue.len() == SR_QUEUE_CAP {
                self.sr_queue.pop_front();
            }
            self.sr_queue.push_back(addr);
            return None;
        }

        // Build the candidate window per policy, then apply the ring
        // check against *that window* (not the trigger address — the
        // window sits ahead of the demand front by design).
        let flit = match self.policy {
            SrPolicy::Off => unreachable!(),
            SrPolicy::Naive => {
                // 64 B blind speculation at the demand address.
                let f = Flit::spec_rd(addr, SPECRD_OFFSET_UNIT, now, req_id);
                // Model the 64 B intent: naive still occupies one offset
                // unit on the wire but covers only the demand line.
                Flit { len: 64, ..f }
            }
            SrPolicy::Dynamic => Flit::spec_rd(addr, self.granularity, now, req_id),
            SrPolicy::Window => {
                let (start, len) = self.address_window(addr, mem_queue);
                Flit::spec_rd(start, len, now, req_id)
            }
        };
        if self.window_covered(flit.addr, flit.len.max(64)) {
            self.stats.dedup_forwarded += 1;
            self.note_on_stream_evidence();
            return None;
        }
        self.dedup_streak = self.dedup_streak.saturating_sub(1);
        self.remember(flit.addr, flit.len.max(64));
        self.stats.sr_issued += 1;
        self.stats.sr_bytes += flit.len.max(64);
        // Track as anticipated-future work for subsequent window calcs.
        if self.sr_queue.len() == SR_QUEUE_CAP {
            self.sr_queue.pop_front();
        }
        self.sr_queue.push_back(addr);
        Some(flit)
    }

    /// Fig. 7's address-window computation, as skip-ahead control.
    ///
    /// The memory queue (chronological past requests) and SR queue
    /// (anticipated work) are analyzed for a direction *trend*: a
    /// coalesced multi-warp stream forms a moving band of addresses, so
    /// instantaneous above/below counts are uninformative — what matters
    /// is whether the band's centre is rising or falling. With a clear
    /// trend the window is placed beyond the band edge plus an adaptive
    /// lead (speculation must land before the demand front arrives);
    /// without one ("Around" patterns — binary-tree descents,
    /// pivot-relative accesses) the window is centred on the trigger so
    /// either direction is served.
    fn address_window(&self, addr: u64, mem_queue: &VecDeque<u64>) -> (u64, u64) {
        let g = self.granularity;
        let unit = SPECRD_OFFSET_UNIT;
        let n = mem_queue.len();
        if n >= 8 {
            let half = n / 2;
            let older: u64 = mem_queue.iter().take(half).sum::<u64>() / half as u64;
            let newer: u64 =
                mem_queue.iter().skip(half).sum::<u64>() / (n - half) as u64;
            // The trend must dominate the band's own spread: interleaved
            // walks over per-warp regions (Around) span megabytes with
            // zero net direction, while a coalesced stream's band is
            // narrow and its centre moves a band-width per queue-life.
            let spread = mem_queue.iter().copied().max().unwrap_or(addr)
                - mem_queue.iter().copied().min().unwrap_or(addr);
            let drift = newer.abs_diff(older);
            let directional = drift > 64 && drift * 4 > spread;
            if directional && newer > older {
                // Ascending band: prefetch beyond its upper edge.
                let edge = mem_queue.iter().copied().max().unwrap_or(addr).max(addr);
                let start = (edge + 64 + self.lead) / unit * unit;
                return (start, g);
            }
            if directional && older > newer {
                // Descending band: prefetch below its lower edge.
                let edge = mem_queue.iter().copied().min().unwrap_or(addr).min(addr);
                let end = edge.saturating_sub(self.lead) / unit * unit;
                return (end.saturating_sub(g), g);
            }
        }
        // No clear direction (Fig. 7's both-ways case — the next access
        // may come before or after): bias the window forward but keep a
        // quarter of it behind the trigger, so descending steps of a
        // wandering pattern still land in covered ground.
        let start = addr.saturating_sub(g / 4) / unit * unit;
        (start, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mq(addrs: &[u64]) -> VecDeque<u64> {
        addrs.iter().copied().collect()
    }

    #[test]
    fn off_policy_never_speculates() {
        let mut e = SpecReadEngine::new(SrPolicy::Off);
        assert!(e.on_load(0, 0x1000, &mq(&[]), 1).is_none());
        assert_eq!(e.stats.sr_issued, 0);
    }

    #[test]
    fn naive_issues_64b_at_demand_addr() {
        let mut e = SpecReadEngine::new(SrPolicy::Naive);
        let f = e.on_load(0, 0x1040, &mq(&[]), 1).unwrap();
        assert_eq!(f.len, 64);
        assert_eq!(f.addr, 0x1000, "aligned to the 256B offset unit");
    }

    #[test]
    fn dynamic_grows_on_light_and_shrinks_on_moderate() {
        let mut e = SpecReadEngine::new(SrPolicy::Dynamic);
        assert_eq!(e.granularity(), 1024, "wide default for cold-start coverage");
        e.observe_devload(DevLoad::Light);
        assert_eq!(e.granularity(), 1024, "capped at 1 KiB");
        e.observe_devload(DevLoad::Moderate);
        assert_eq!(e.granularity(), 512);
        e.observe_devload(DevLoad::Moderate);
        assert_eq!(e.granularity(), 256);
        e.observe_devload(DevLoad::Moderate);
        assert_eq!(e.granularity(), 256, "floor at one offset unit");
        e.observe_devload(DevLoad::Optimal);
        assert_eq!(e.granularity(), 256, "optimal holds");
        e.observe_devload(DevLoad::Severe);
        assert_eq!(e.granularity(), 256, "severe trickles, holds size");
    }

    #[test]
    fn severe_reduces_sr_frequency() {
        let mut e = SpecReadEngine::new(SrPolicy::Dynamic);
        e.observe_devload(DevLoad::Severe);
        assert_eq!(e.period(), 2);
        // Over 32 far-apart loads, about half generate speculation.
        let mut issued = 0;
        for i in 0..32u64 {
            if e.on_load(0, 0x100000 + i * 0x10000, &mq(&[]), i).is_some() {
                issued += 1;
            }
        }
        assert!((10..=22).contains(&issued), "severe issued {issued}/32");
        e.observe_devload(DevLoad::Light);
        assert_eq!(e.period(), 1);
        assert!(e.on_load(0, 0x9000000, &mq(&[]), 99).is_some());
    }

    #[test]
    fn ring_buffer_dedups_covered_windows() {
        let mut e = SpecReadEngine::new(SrPolicy::Dynamic);
        let f = e.on_load(0, 0x2000, &mq(&[]), 1).unwrap();
        assert!(f.len >= 512);
        // A nearby load whose candidate window is fully covered by the
        // issued one generates no new SR.
        assert!(e.on_load(1, 0x2040, &mq(&[]), 2).is_none());
        assert_eq!(e.stats.dedup_forwarded, 1);
    }

    #[test]
    fn window_extends_backwards_for_descending_streams() {
        let mut e = SpecReadEngine::new(SrPolicy::Window);
        e.observe_devload(DevLoad::Light); // 1024
        // Chronologically falling band: stream moving down.
        let queue =
            mq(&[0x9700, 0x9600, 0x9500, 0x9400, 0x9300, 0x9200, 0x9100, 0x9000]);
        let f = e.on_load(0, 0x8000, &queue, 1).unwrap();
        assert!(f.addr < 0x8000, "window should sit below the trigger: {:#x}", f.addr);
    }

    #[test]
    fn window_skips_ahead_for_ascending_streams() {
        let mut e = SpecReadEngine::new(SrPolicy::Window);
        e.observe_devload(DevLoad::Light); // 1024
        // Chronologically rising band (>= 8 samples for trend detection).
        let queue =
            mq(&[0x7000, 0x7100, 0x7200, 0x7300, 0x7400, 0x7500, 0x7600, 0x7700]);
        let f = e.on_load(0, 0x8000, &queue, 1).unwrap();
        // The window must land ahead of the trigger — speculation runs
        // ahead of the demand front (band edge + adaptive lead).
        assert!(f.addr >= 0x8000, "window should skip ahead: {:#x}", f.addr);
        assert!(f.addr <= 0x8000 + (40 << 10), "but not unboundedly far");
    }

    #[test]
    fn window_is_256b_aligned_and_bounded() {
        let mut e = SpecReadEngine::new(SrPolicy::Window);
        for dl in [DevLoad::Light, DevLoad::Light, DevLoad::Light] {
            e.observe_devload(dl);
        }
        let queue = mq(&[0x100, 0x40000, 0x80000]);
        let f = e.on_load(0, 0x40040, &queue, 1).unwrap();
        assert_eq!(f.addr % 256, 0);
        assert!(f.len >= 256 && f.len <= 1024, "len {}", f.len);
    }

    #[test]
    fn cache_suppression_counts_and_feeds_the_streak() {
        let mut e = SpecReadEngine::new(SrPolicy::Dynamic);
        e.observe_devload(DevLoad::Moderate);
        e.observe_devload(DevLoad::Moderate);
        assert_eq!(e.granularity(), 256);
        // Integrated sequence: each window is *emitted* by on_load
        // (which decrements the streak as off-stream pessimism) and
        // then suppressed by the port's cache probe. Suppression must
        // net-advance the streak, not just cancel the decrement.
        for i in 0..16u64 {
            let f = e.on_load(i, 0x100000 * (i + 1), &mq(&[]), i).expect("window emitted");
            assert!(f.len >= 256);
            e.hint_covered_by_cache();
        }
        assert_eq!(e.stats.cache_suppressed, 16);
        assert!(e.granularity() > 256, "sustained residency must widen windows");
    }

    #[test]
    fn stats_accumulate() {
        let mut e = SpecReadEngine::new(SrPolicy::Dynamic);
        e.on_load(0, 0x0, &mq(&[]), 1);
        e.on_load(1, 0x10000, &mq(&[]), 2);
        assert_eq!(e.stats.loads_seen, 2);
        assert_eq!(e.stats.sr_issued, 2);
        assert!(e.stats.sr_bytes >= 512);
    }
}
