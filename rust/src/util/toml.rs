//! Minimal TOML-subset parser for experiment configs (offline stand-in
//! for the `toml` crate).
//!
//! Supported: `[table]` and `[table.sub]` headers, `key = value` pairs
//! with string / integer / float / boolean / homogeneous-array values,
//! `#` comments, and bare or quoted keys. Unsupported TOML (dates,
//! inline tables, arrays-of-tables, multi-line strings) is rejected with
//! a line-numbered error — configs in this repo stay inside the subset.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: dotted-path keys (`table.sub.key`) to values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    pub entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }
    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }
    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Keys under a table prefix, e.g. `keys_under("media")`.
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let p = format!("{prefix}.");
        self.entries.keys().filter(|k| k.starts_with(&p)).map(|k| k.as_str()).collect()
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document, String> {
    let mut doc = Document::default();
    let mut table = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?;
            if inner.starts_with('[') {
                return Err(format!("line {}: arrays-of-tables unsupported", lineno + 1));
            }
            table = inner.trim().to_string();
            if table.is_empty() {
                return Err(format!("line {}: empty table name", lineno + 1));
            }
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let path = if table.is_empty() { key } else { format!("{table}.{key}") };
        doc.entries.insert(path, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.find('"').ok_or("unterminated string")?;
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing garbage after string".into());
        }
        return Ok(Value::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    let cleaned = s.replace('_', "");
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(i) = u64::from_str_radix(cleaned.trim_start_matches("0x"), 16) {
        if cleaned.starts_with("0x") {
            return Ok(Value::Int(i as i64));
        }
    }
    Err(format!("cannot parse value `{s}`"))
}

/// Split on commas not inside nested brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = parse(
            r#"
# experiment
name = "fig9a"
seed = 42
scale = 1.5
verbose = true

[gpu]
cores = 8
threads = 8

[media.znand]
read_ns = 3000
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "fig9a");
        assert_eq!(doc.int_or("seed", 0), 42);
        assert!((doc.float_or("scale", 0.0) - 1.5).abs() < 1e-12);
        assert!(doc.bool_or("verbose", false));
        assert_eq!(doc.int_or("gpu.cores", 0), 8);
        assert_eq!(doc.int_or("media.znand.read_ns", 0), 3000);
    }

    #[test]
    fn parses_arrays() {
        let doc = parse("xs = [1, 2, 3]\nnames = [\"a\", \"b\"]").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_int(), Some(3));
        let names = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
    }

    #[test]
    fn comments_and_underscores() {
        let doc = parse("big = 1_000_000 # one million").unwrap();
        assert_eq!(doc.int_or("big", 0), 1_000_000);
    }

    #[test]
    fn comment_char_inside_string_kept() {
        let doc = parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rejects_array_of_tables() {
        assert!(parse("[[t]]").is_err());
    }

    #[test]
    fn float_forms() {
        let doc = parse("a = 2.5\nb = 1e3\nc = 3").unwrap();
        assert_eq!(doc.float_or("a", 0.0), 2.5);
        assert_eq!(doc.float_or("b", 0.0), 1000.0);
        assert_eq!(doc.float_or("c", 0.0), 3.0); // int coerces
    }

    #[test]
    fn keys_under_prefix() {
        let doc = parse("[m.a]\nx = 1\n[m.b]\ny = 2\n[other]\nz = 3").unwrap();
        let keys = doc.keys_under("m");
        assert_eq!(keys, vec!["m.a.x", "m.b.y"]);
    }
}
