//! Minimal JSON parser (offline stand-in for `serde_json`), used to read
//! `artifacts/manifest.json` and to emit experiment result files.
//!
//! Full JSON value model; numbers are kept as f64 (the manifest only
//! contains small integers and strings).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", quote(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", quote(k))?;
                }
                write!(f, "}}")
            }
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u8> for Json {
    fn from(n: u8) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Chainable object builder — the one escaping-correct way to assemble
/// report documents (bench reports, `--trace-out`, `--telemetry-out`),
/// replacing the ad-hoc `format!` JSON emitters that broke on `"` or
/// `\` in a config name.
#[derive(Debug, Default)]
pub struct JsonObj(BTreeMap<String, Json>);

impl JsonObj {
    pub fn new() -> JsonObj {
        JsonObj(BTreeMap::new())
    }

    /// Insert a key (last write wins, keys render sorted).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> JsonObj {
        self.0.insert(key.to_string(), value.into());
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

impl From<JsonObj> for Json {
    fn from(o: JsonObj) -> Json {
        o.build()
    }
}

/// Write a document to `path` with a trailing newline; errors carry the
/// path. The single exit point for every JSON artifact the binaries emit.
pub fn write_file(path: &str, doc: &Json) -> Result<(), String> {
    std::fs::write(path, format!("{doc}\n")).map_err(|e| format!("{path}: {e}"))
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("unknown escape at byte {}", self.pos)),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number `{s}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"workloads": [{"name": "vadd", "hlo": "vadd.hlo.txt",
            "inputs": [{"shape": [262144], "dtype": "float32"}],
            "outputs": [{"shape": [262144], "dtype": "float32"}]}]}"#;
        let j = parse(doc).unwrap();
        let w = j.get("workloads").unwrap().idx(0).unwrap();
        assert_eq!(w.get("name").unwrap().as_str(), Some("vadd"));
        let shape = w.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(0).unwrap().as_u64(), Some(262144));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"a": [1, 2.5, true, null, "s\"x"], "b": {"c": -3}}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""A\n""#).unwrap();
        assert_eq!(j.as_str(), Some("A\n"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn builder_escapes_hostile_keys_and_values() {
        let doc: Json = JsonObj::new()
            .set("name", "cfg\"with\\quotes")
            .set("ops", 12u64)
            .set("ratio", 1.5)
            .set("ok", true)
            .set("rows", vec![Json::from(1u64), Json::from("x")])
            .into();
        let text = doc.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back.get("name").unwrap().as_str(), Some("cfg\"with\\quotes"));
        assert_eq!(back.get("ops").unwrap().as_u64(), Some(12));
        assert_eq!(back.get("rows").unwrap().idx(1).unwrap().as_str(), Some("x"));
    }

    #[test]
    fn builder_last_write_wins() {
        let doc = JsonObj::new().set("k", 1u64).set("k", 2u64).build();
        assert_eq!(doc.get("k").unwrap().as_u64(), Some(2));
    }
}
