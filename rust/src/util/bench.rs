//! Micro-benchmark harness (offline stand-in for criterion).
//!
//! Benches in `rust/benches/` are `harness = false` binaries that call
//! [`Bench::run`] for hot-loop timing and use [`Table`] to print the
//! paper-figure reproductions. Timing uses `std::time::Instant` with
//! warmup, multiple measured batches, and median-of-batches reporting.

use std::time::Instant;

/// One benchmark's timing configuration + results.
pub struct Bench {
    pub name: String,
    warmup_iters: u64,
    batches: usize,
    batch_iters: u64,
}

/// Result of a bench run (per-iteration times, ns).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters: u64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), warmup_iters: 3, batches: 7, batch_iters: 5 }
    }

    /// Configure iteration counts (for fast vs slow bodies).
    pub fn iters(mut self, warmup: u64, batches: usize, batch_iters: u64) -> Self {
        self.warmup_iters = warmup;
        self.batches = batches.max(1);
        self.batch_iters = batch_iters.max(1);
        self
    }

    /// Time `f`, which must do one unit of work per call. Returns stats
    /// and prints a criterion-like line.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut per_iter: Vec<f64> = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..self.batch_iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / self.batch_iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = per_iter[per_iter.len() / 2];
        let mean = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
        let res = BenchResult {
            name: self.name.clone(),
            median_ns: median,
            mean_ns: mean,
            min_ns: per_iter[0],
            max_ns: *per_iter.last().unwrap(),
            iters: self.batches as u64 * self.batch_iters,
        };
        println!(
            "bench {:<40} median {:>12}  (min {}, max {}, n={})",
            res.name,
            super::fmt_ns(res.median_ns),
            super::fmt_ns(res.min_ns),
            super::fmt_ns(res.max_ns),
            res.iters
        );
        res
    }
}

/// Fixed-width table printer for paper-figure reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells);
    }

    /// Render to stdout.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n== {} ==", self.title);
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", "-".repeat(total));
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!("{}", "-".repeat(total));
    }
}

/// Helper: `3.14x`-style ratio formatting used across the figure benches.
pub fn ratio(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = Bench::new("spin").iters(1, 3, 10).run(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn table_prints_all_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        t.print(); // smoke: no panic, widths adapt
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(2.345), "2.35x");
        assert_eq!(ratio(52.7), "52.7x");
        assert_eq!(ratio(250.0), "250x");
    }
}
