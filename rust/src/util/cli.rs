//! Tiny CLI argument parser (offline stand-in for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for usage text.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected number, got `{v}`")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse `argv[1..]`. `value_opts` lists option names that consume a value;
/// anything else starting with `--` is a flag.
pub fn parse(argv: &[String], value_opts: &[&str]) -> Result<Args, String> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if value_opts.contains(&stripped) {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| format!("--{stripped} requires a value"))?;
                out.options.insert(stripped.to_string(), v.clone());
            } else {
                out.flags.push(stripped.to_string());
            }
        } else if out.subcommand.is_none() && out.positional.is_empty() {
            out.subcommand = Some(a.clone());
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

/// Render a usage block.
pub fn usage(prog: &str, about: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut s = format!("{prog} — {about}\n\nUSAGE:\n  {prog} <COMMAND> [OPTIONS]\n");
    if !subcommands.is_empty() {
        s.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            s.push_str(&format!("  {name:<18} {help}\n"));
        }
    }
    if !opts.is_empty() {
        s.push_str("\nOPTIONS:\n");
        for o in opts {
            let name = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            s.push_str(&format!("  {name:<18} {}\n", o.help));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let args =
            parse(&sv(&["run", "--workload", "vadd", "--verbose", "--seed=7", "extra"]),
                  &["workload", "seed"]).unwrap();
        assert_eq!(args.subcommand.as_deref(), Some("run"));
        assert_eq!(args.get("workload"), Some("vadd"));
        assert_eq!(args.get("seed"), Some("7"));
        assert!(args.has_flag("verbose"));
        assert_eq!(args.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&sv(&["run", "--workload"]), &["workload"]).is_err());
    }

    #[test]
    fn numeric_accessors() {
        let args = parse(&sv(&["x", "--n=1_000", "--f=2.5"]), &[]).unwrap();
        assert_eq!(args.get_u64("n", 0).unwrap(), 1000);
        assert_eq!(args.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(args.get_u64("absent", 9).unwrap(), 9);
        assert!(parse(&sv(&["x", "--n=zzz"]), &[]).unwrap().get_u64("n", 0).is_err());
    }

    #[test]
    fn usage_contains_everything() {
        let u = usage("cxl-gpu", "about", &[("run", "run an experiment")],
                      &[OptSpec { name: "seed", help: "rng seed", takes_value: true }]);
        assert!(u.contains("cxl-gpu"));
        assert!(u.contains("run"));
        assert!(u.contains("--seed"));
    }
}
