//! Minimal property-based testing runner (offline stand-in for proptest).
//!
//! A property is a closure over a [`Gen`] (seeded case-data source). The
//! runner executes `cases` random cases; on failure it re-runs with greedy
//! size shrinking of every recorded integer draw and reports the smallest
//! failing case's draw log plus the seed needed to replay it.

use super::prng::Pcg32;

/// Case-data source handed to properties. Records every draw so the
/// runner can shrink failing cases.
pub struct Gen {
    rng: Pcg32,
    /// (label, value) log of draws for failure reports.
    pub log: Vec<(String, i128)>,
    /// Shrink overrides: when set, draw i returns the override.
    overrides: Vec<Option<i128>>,
    draw_idx: usize,
}

impl Gen {
    fn new(seed: u64, case: u64, overrides: Vec<Option<i128>>) -> Self {
        Gen { rng: Pcg32::new(seed, case), log: Vec::new(), overrides, draw_idx: 0 }
    }

    fn record(&mut self, label: &str, v: i128) -> i128 {
        let idx = self.draw_idx;
        self.draw_idx += 1;
        let v = match self.overrides.get(idx).copied().flatten() {
            Some(o) => o,
            None => v,
        };
        self.log.push((label.to_string(), v));
        v
    }

    /// Uniform `u64` in `[lo, hi]`, logged under `label`.
    pub fn u64(&mut self, label: &str, lo: u64, hi: u64) -> u64 {
        let raw = self.rng.range(lo, hi.saturating_add(1).max(lo + 1)) as i128;
        let v = self.record(label, raw);
        (v.clamp(lo as i128, hi as i128)) as u64
    }

    /// Uniform `usize` in `[lo, hi]`.
    pub fn usize(&mut self, label: &str, lo: usize, hi: usize) -> usize {
        self.u64(label, lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` (not shrunk; logged as permille).
    pub fn unit_f64(&mut self, label: &str) -> f64 {
        let v = self.rng.f64();
        self.record(label, (v * 1000.0) as i128);
        v
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, label: &str, p_true: f64) -> bool {
        let v = self.rng.chance(p_true);
        self.record(label, v as i128);
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, label: &str, xs: &'a [T]) -> &'a T {
        let i = self.usize(label, 0, xs.len() - 1);
        &xs[i]
    }

    /// A vector of `u64` draws.
    pub fn vec_u64(&mut self, label: &str, len_lo: usize, len_hi: usize, lo: u64, hi: u64) -> Vec<u64> {
        let len = self.usize(&format!("{label}.len"), len_lo, len_hi);
        (0..len).map(|i| self.u64(&format!("{label}[{i}]"), lo, hi)).collect()
    }
}

/// Outcome of a property run.
pub enum PropResult {
    Pass,
    Fail { case: u64, log: Vec<(String, i128)>, msg: String },
}

/// Run `prop` for `cases` cases with the given seed. Panics (with a replay
/// report) on the first failure after shrinking.
///
/// The property returns `Err(msg)` or panics to signal failure.
pub fn check<F>(name: &str, seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut run = |ovr: Vec<Option<i128>>| -> (Result<(), String>, Vec<(String, i128)>) {
            let mut g = Gen::new(seed, case, ovr);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            let res = match r {
                Ok(inner) => inner,
                Err(p) => Err(panic_msg(p)),
            };
            (res, g.log)
        };
        let (res, log) = run(Vec::new());
        if let Err(first_msg) = res {
            // Greedy shrink: for each draw, try 0 / lo-style reductions.
            let mut best_log = log;
            let mut best_msg = first_msg;
            let mut overrides: Vec<Option<i128>> = vec![None; best_log.len()];
            let mut improved = true;
            while improved {
                improved = false;
                for i in 0..overrides.len() {
                    let orig = best_log.get(i).map(|kv| kv.1).unwrap_or(0);
                    for cand in [0, orig / 2, orig - 1] {
                        if cand == orig || cand < 0 {
                            continue;
                        }
                        let mut trial = overrides.clone();
                        trial[i] = Some(cand);
                        let (r, l) = run(trial.clone());
                        if let Err(m) = r {
                            overrides = trial;
                            best_log = l;
                            best_msg = m;
                            improved = true;
                            break;
                        }
                    }
                }
            }
            let draws: Vec<String> =
                best_log.iter().map(|(k, v)| format!("{k}={v}")).collect();
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  {}\n  draws: [{}]",
                best_msg,
                draws.join(", ")
            );
        }
    }
}

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 1, 50, |g| {
            let a = g.u64("a", 0, 1000);
            let b = g.u64("b", 0, 1000);
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 1, 10, |g| {
            let a = g.u64("a", 0, 100);
            if a <= 100 { Err("nope".into()) } else { Ok(()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'panics' failed")]
    fn panicking_property_is_caught() {
        check("panics", 1, 5, |g| {
            let v = g.u64("v", 10, 20);
            assert!(v < 5, "v too big");
            Ok(())
        });
    }

    #[test]
    fn draws_are_deterministic_per_seed_case() {
        let mut first: Vec<u64> = Vec::new();
        check("det", 7, 1, |g| {
            first.push(g.u64("x", 0, u32::MAX as u64));
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("det", 7, 1, |g| {
            second.push(g.u64("x", 0, u32::MAX as u64));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
