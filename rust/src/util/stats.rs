//! Streaming summary statistics and histogram utilities used by the
//! simulator's metric collection and the bench harness.

/// Online mean/min/max/count accumulator (Welford variance).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn sum(&self) -> f64 {
        self.sum
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another summary (means combined exactly; m2 via Chan et al.).
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * n1 * n2 / (n1 + n2);
        self.mean = (n1 * self.mean + n2 * other.mean) / (n1 + n2);
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Reservoir of raw samples for percentile queries; above `cap` samples it
/// keeps a uniform reservoir (deterministic, index-hashed).
#[derive(Debug, Clone)]
pub struct Percentiles {
    cap: usize,
    seen: u64,
    samples: Vec<f64>,
}

impl Default for Percentiles {
    /// 4096-sample reservoir: enough that the p99 of a full-scale run's
    /// expander loads is pinned by real tail samples.
    fn default() -> Self {
        Percentiles::new(4096)
    }
}

impl Percentiles {
    pub fn new(cap: usize) -> Self {
        Percentiles { cap: cap.max(16), seen: 0, samples: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            // Deterministic reservoir: SplitMix over the index.
            let mut z = self.seen.wrapping_mul(0x9E3779B97F4A7C15);
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            let slot = z % self.seen;
            if (slot as usize) < self.cap {
                self.samples[slot as usize] = x;
            }
        }
    }

    /// Nearest-rank on the (sorted) reservoir. `p` outside [0, 100] is
    /// clamped (negative `p` would otherwise round through a negative
    /// float-to-usize cast; `p > 100` would index past the end), so a
    /// single-sample reservoir answers that sample for every `p` and
    /// `percentile(100.0)` is always the maximum.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn count(&self) -> u64 {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn summary_merge_matches_sequential() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut whole = Summary::new();
        for i in 0..100 {
            let x = (i * i % 37) as f64;
            if i % 2 == 0 { a.add(x) } else { b.add(x) }
            whole.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_exact_under_cap() {
        let mut p = Percentiles::new(1000);
        for i in 0..=100 {
            p.add(i as f64);
        }
        assert_eq!(p.percentile(0.0), 0.0);
        assert_eq!(p.percentile(50.0), 50.0);
        assert_eq!(p.percentile(100.0), 100.0);
    }

    #[test]
    fn percentiles_empty_reservoir_answers_zero_for_any_p() {
        let p = Percentiles::new(16);
        for q in [-10.0, 0.0, 50.0, 100.0, 250.0] {
            assert_eq!(p.percentile(q), 0.0);
        }
    }

    #[test]
    fn percentiles_single_sample_clamps_every_query() {
        let mut p = Percentiles::new(16);
        p.add(42.0);
        // One sample answers itself at every rank, including the former
        // out-of-range casts (p=100 rounded to rank 1 of a len-1 vec
        // before the clamp fix; negative p cast through f64→usize).
        for q in [-5.0, 0.0, 50.0, 99.9, 100.0, 1000.0] {
            assert_eq!(p.percentile(q), 42.0);
        }
    }

    #[test]
    fn percentiles_two_samples_split_at_the_median() {
        let mut p = Percentiles::new(16);
        p.add(10.0);
        p.add(20.0);
        assert_eq!(p.percentile(0.0), 10.0);
        assert_eq!(p.percentile(100.0), 20.0);
        assert_eq!(p.percentile(-1.0), 10.0);
        assert_eq!(p.percentile(101.0), 20.0);
        // Nearest-rank: 50% of (len-1) rounds to rank 1.
        assert_eq!(p.percentile(50.0), 20.0);
        assert_eq!(p.percentile(49.0), 10.0);
    }

    #[test]
    fn percentiles_reservoir_stays_bounded() {
        let mut p = Percentiles::new(64);
        for i in 0..100_000 {
            p.add((i % 1000) as f64);
        }
        assert_eq!(p.count(), 100_000);
        let med = p.percentile(50.0);
        assert!((200.0..800.0).contains(&med), "median {med}");
    }
}
