//! Deterministic fast hashing for simulator-internal maps.
//!
//! `std`'s default SipHash shows up prominently in the simulator profile:
//! every LLC access probes the MSHR map, every UVM/GDS touch probes the
//! page table, every SSD cache lookup probes the frame map. Those keys are
//! line/page/frame numbers — not attacker-controlled input — so DoS
//! resistance buys nothing, and SipHash's per-process random seed is
//! actively wrong for a simulator that promises bit-reproducible runs.
//! This is the rustc-internal multiplicative ("Fx") hash: one rotate, one
//! xor, one multiply per word, identical on every run.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Word-at-a-time multiplicative hasher.
#[derive(Debug, Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

/// Knuth-style golden-ratio multiplier (as used by rustc's FxHash).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        // Fold the (well-mixed) high half into the low half: hashbrown
        // indexes buckets by the LOW hash bits, and a bare multiplicative
        // hash of 64 B-aligned keys (LLC line addresses) leaves the low 6
        // bits constant — every key would probe one cluster.
        self.hash ^ (self.hash >> 32)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }
    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }
    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// Seed-free builder: every map hashes identically on every run.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` with the deterministic fast hasher (`FxHashMap::default()`).
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` with the deterministic fast hasher.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_one<T: Hash>(v: &T) -> u64 {
        let mut h = FxBuildHasher::default().build_hasher();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn map_works_like_std() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i * 64, i);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(&(i * 64)), Some(&i));
        }
        assert_eq!(m.remove(&(999 * 64)), Some(999));
        assert!(m.get(&(999 * 64)).is_none());
    }

    #[test]
    fn hashing_is_deterministic_and_spreads() {
        assert_eq!(hash_one(&0xDEAD_BEEFu64), hash_one(&0xDEAD_BEEFu64));
        // Sequential line addresses must not collapse to one bucket.
        let mut low_bits = FxHashSet::default();
        for i in 0..64u64 {
            low_bits.insert(hash_one(&(i * 64)) >> 57);
        }
        assert!(low_bits.len() > 16, "only {} distinct top-7-bit values", low_bits.len());
    }

    #[test]
    fn byte_slices_hash_consistently() {
        assert_eq!(hash_one(&"abcdefghij"), hash_one(&"abcdefghij"));
        assert_ne!(hash_one(&"abcdefghij"), hash_one(&"abcdefghik"));
    }
}
