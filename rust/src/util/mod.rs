//! Self-contained utility substrates.
//!
//! This environment is fully offline — only the `xla` crate's vendored
//! closure exists — so the conveniences a production crate would pull from
//! crates.io are implemented here from scratch: a deterministic PRNG
//! ([`prng`]), summary statistics ([`stats`]), a TOML-subset config parser
//! ([`toml`]), a tiny CLI argument parser ([`cli`]), a micro-benchmark
//! harness ([`bench`]), a property-test runner ([`prop`]) and a
//! deterministic fast hasher for hot simulator maps ([`hash`]).

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod toml;

/// Format a nanosecond quantity with an adaptive unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Format a byte quantity with an adaptive unit (B/KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const K: u64 = 1024;
    if b < K {
        format!("{b}B")
    } else if b < K * K {
        format!("{:.1}KiB", b as f64 / K as f64)
    } else if b < K * K * K {
        format!("{:.1}MiB", b as f64 / (K * K) as f64)
    } else {
        format!("{:.2}GiB", b as f64 / (K * K * K) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0ns");
        assert_eq!(fmt_ns(1_500.0), "1.50µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_200_000_000.0), "3.200s");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00GiB");
    }
}
