//! Deterministic PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! Every stochastic element of the simulator (media tail-latency draws,
//! workload trace irregularity, GC scheduling jitter) draws from a [`Pcg32`]
//! seeded from the experiment config, so runs are bit-reproducible.
//! Implemented from scratch (offline environment; no `rand` crate).

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection sampling (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially-distributed draw with the given mean (tail-latency
    /// modelling for media internal tasks).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::new(7, 0);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::new(123, 5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::new(9, 3);
        let n = 200_000;
        let mean_target = 250.0;
        let sum: f64 = (0..n).map(|_| r.exponential(mean_target)).sum();
        let mean = sum / n as f64;
        assert!((mean - mean_target).abs() / mean_target < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
