//! Deterministic PCG-XSH-RR 64/32 pseudo-random number generator.
//!
//! Every stochastic element of the simulator (media tail-latency draws,
//! workload trace irregularity, GC scheduling jitter) draws from a [`Pcg32`]
//! seeded from the experiment config, so runs are bit-reproducible.
//! Implemented from scratch (offline environment; no `rand` crate).

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's unbiased multiply-shift.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection sampling (unbiased).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially-distributed draw with the given mean (tail-latency
    /// modelling for media internal tasks).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent labelled sub-stream *without* advancing this
    /// generator. The child is a function of the parent's current state
    /// and the label only, so (a) forking is invisible to every
    /// subsequent draw from the parent — existing workload/SR/tiering
    /// sequences cannot be perturbed by a subsystem that forks its own
    /// stream — and (b) the same (parent state, label) pair always yields
    /// the same child. Distinct labels select distinct PCG streams (the
    /// label lands in the increment), so siblings are as independent as
    /// `Pcg32::new` streams.
    pub fn fork(&self, label: u64) -> Pcg32 {
        Pcg32::new(self.state ^ label.wrapping_mul(PCG_MULT), (self.inc >> 1) ^ label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::new(7, 0);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg32::new(123, 5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::new(9, 3);
        let n = 200_000;
        let mean_target = 250.0;
        let sum: f64 = (0..n).map(|_| r.exponential(mean_target)).sum();
        let mean = sum / n as f64;
        assert!((mean - mean_target).abs() / mean_target < 0.02, "mean {mean}");
    }

    #[test]
    fn fork_is_deterministic_and_does_not_perturb_the_parent() {
        let parent = Pcg32::new(0xC11A, 0xD15C);
        // Same parent state + same label → the same child stream.
        let mut c1 = parent.fork(3);
        let mut c2 = parent.fork(3);
        for _ in 0..200 {
            assert_eq!(c1.next_u32(), c2.next_u32());
        }
        // Forking is invisible to the parent: a forked and an unforked
        // copy draw identical sequences afterwards.
        let mut forked = Pcg32::new(0xC11A, 0xD15C);
        let _ = forked.fork(7);
        let _ = forked.fork(11);
        let mut plain = Pcg32::new(0xC11A, 0xD15C);
        for _ in 0..200 {
            assert_eq!(forked.next_u32(), plain.next_u32());
        }
    }

    #[test]
    fn fork_labels_select_distinct_streams() {
        let parent = Pcg32::new(42, 9);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3, "labels 0/1 produced {same} collisions in 100 draws");
        // Children also differ from the parent's own stream.
        let mut p = parent.clone();
        let mut c = parent.fork(5);
        let same = (0..100).filter(|_| p.next_u32() == c.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn fork_depends_on_parent_state() {
        let mut p1 = Pcg32::new(1, 1);
        let p2 = p1.clone();
        p1.next_u32(); // advance: forks must now differ
        let mut a = p1.fork(4);
        let mut b = p2.fork(4);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
