//! Cross-system event interleaving: step N independent event-driven
//! systems as if their calendars were one queue.
//!
//! Each tenant `System` of a pooled-fabric run owns its own
//! [`EventQueue`](super::EventQueue), but they mutate *shared* state
//! (the switch and its pooled endpoints), so the order in which their
//! events execute matters. [`interleave()`] merges the queues by always
//! stepping the system whose next event is earliest — ties break on the
//! lowest index — which is exactly the (time, tenant) order one global
//! calendar would produce. Deterministic by construction: no wall
//! clock, no thread scheduling, a total order over every event.
//!
//! The merge is a min-heap keyed on `(time, index)` rather than an
//! O(N) scan per step: each system has exactly one entry while it has
//! pending work, popped and re-pushed as it advances, so a step costs
//! `O(log N)` at rack-scale tenant counts. The tuple key makes the
//! serial tie rule (lowest index first on equal times) part of the heap
//! order itself.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Time;

/// An event-driven system that can be single-stepped by a coordinator.
///
/// Coordinators assume *isolation*: stepping one system never changes
/// another system's `next_time()`. Tenant `System`s satisfy this — their
/// calendars are private, and shared-fabric calls complete synchronously
/// within the caller's step.
pub trait Steppable {
    /// Time of the next pending event, or `None` when this system has
    /// nothing more to do (finished, or queue drained).
    fn next_time(&self) -> Option<Time>;
    /// Pop and process one event. Returns `false` if there was nothing
    /// to pop.
    fn step(&mut self) -> bool;

    /// Step until the next pending event is at or past `horizon`
    /// (exclusive: an event exactly at the horizon does *not* run) or
    /// the system finishes. Returns the number of steps executed. The
    /// conservative-lookahead engine (`sim::pdes`) advances each shard
    /// with this bounded drain.
    fn step_until(&mut self, horizon: Time) -> u64 {
        let mut steps = 0;
        while let Some(t) = self.next_time() {
            if t >= horizon || !self.step() {
                break;
            }
            steps += 1;
        }
        steps
    }
}

/// Drain `systems` to completion in global (time, index) order; returns
/// the number of steps executed.
pub fn interleave<T: Steppable>(systems: &mut [T]) -> u64 {
    let mut heap: BinaryHeap<Reverse<(Time, usize)>> = systems
        .iter()
        .enumerate()
        .filter_map(|(i, s)| s.next_time().map(|t| Reverse((t, i))))
        .collect();
    let mut steps = 0;
    while let Some(Reverse((t, i))) = heap.pop() {
        // An entry is refreshed every time its system steps, and only
        // its own steps can move its clock (the isolation contract), so
        // the heap key is never stale.
        debug_assert_eq!(systems[i].next_time(), Some(t), "heap key went stale");
        if systems[i].step() {
            steps += 1;
        }
        if let Some(next) = systems[i].next_time() {
            debug_assert!(next >= t, "system {i} scheduled backwards: {next} < {t}");
            heap.push(Reverse((next, i)));
        }
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy steppable: a preloaded list of event times, recording
    /// (time, id) into a shared log on each step.
    struct Toy<'a> {
        id: usize,
        times: Vec<Time>,
        cursor: usize,
        log: &'a std::cell::RefCell<Vec<(Time, usize)>>,
    }

    impl Steppable for Toy<'_> {
        fn next_time(&self) -> Option<Time> {
            self.times.get(self.cursor).copied()
        }
        fn step(&mut self) -> bool {
            let Some(&t) = self.times.get(self.cursor) else { return false };
            self.cursor += 1;
            self.log.borrow_mut().push((t, self.id));
            true
        }
    }

    #[test]
    fn merges_in_global_time_order_with_index_ties() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut toys = vec![
            Toy { id: 0, times: vec![5, 10, 10, 30], cursor: 0, log: &log },
            Toy { id: 1, times: vec![1, 10, 20], cursor: 0, log: &log },
        ];
        let steps = interleave(&mut toys);
        assert_eq!(steps, 7);
        assert_eq!(
            log.into_inner(),
            vec![(1, 1), (5, 0), (10, 0), (10, 0), (10, 1), (20, 1), (30, 0)],
            "ties must resolve to the lowest index, repeatedly"
        );
    }

    #[test]
    fn empty_and_single_system() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut none: Vec<Toy> = Vec::new();
        assert_eq!(interleave(&mut none), 0);
        let mut one = vec![Toy { id: 7, times: vec![2, 4], cursor: 0, log: &log }];
        assert_eq!(interleave(&mut one), 2);
        assert_eq!(log.into_inner(), vec![(2, 7), (4, 7)]);
    }

    /// Many-way tie storm: five systems all carrying runs of equal
    /// timestamps must drain in strict index order *within every
    /// timestamp*, including a system whose whole schedule ties and one
    /// that joins a tie mid-run. Guards the heap rewrite against any
    /// `BinaryHeap` tie-handling subtlety the 2-system toy would miss.
    #[test]
    fn equal_timestamp_ties_across_many_systems_resolve_by_index() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut toys = vec![
            Toy { id: 0, times: vec![10, 10, 20], cursor: 0, log: &log },
            Toy { id: 1, times: vec![10, 20, 20], cursor: 0, log: &log },
            Toy { id: 2, times: vec![10, 10, 10], cursor: 0, log: &log },
            Toy { id: 3, times: vec![5, 10, 20], cursor: 0, log: &log },
            Toy { id: 4, times: vec![20, 20, 20], cursor: 0, log: &log },
        ];
        let steps = interleave(&mut toys);
        assert_eq!(steps, 15);
        assert_eq!(
            log.into_inner(),
            vec![
                (5, 3),
                // t=10: index order, and a system that stays at 10 keeps
                // winning its slot before higher indices run theirs.
                (10, 0),
                (10, 0),
                (10, 1),
                (10, 2),
                (10, 2),
                (10, 2),
                (10, 3),
                // t=20: index order again, repeated entries contiguous.
                (20, 0),
                (20, 1),
                (20, 1),
                (20, 3),
                (20, 4),
                (20, 4),
                (20, 4),
            ],
            "equal timestamps must drain lowest-index-first, repeatedly"
        );
    }

    #[test]
    fn step_until_respects_an_exclusive_horizon() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut toy = Toy { id: 0, times: vec![1, 5, 10, 10, 12], cursor: 0, log: &log };
        // Events strictly before 10 run; the ones at 10 wait.
        assert_eq!(toy.step_until(10), 2);
        assert_eq!(toy.next_time(), Some(10));
        // Horizon past the end drains the rest.
        assert_eq!(toy.step_until(Time::MAX), 3);
        assert_eq!(toy.next_time(), None);
        assert_eq!(log.into_inner(), vec![(1, 0), (5, 0), (10, 0), (10, 0), (12, 0)]);
    }
}
