//! Cross-system event interleaving: step N independent event-driven
//! systems as if their calendars were one queue.
//!
//! Each tenant `System` of a pooled-fabric run owns its own
//! [`EventQueue`](super::EventQueue), but they mutate *shared* state
//! (the switch and its pooled endpoints), so the order in which their
//! events execute matters. [`interleave()`] merges the queues by always
//! stepping the system whose next event is earliest — ties break on the
//! lowest index — which is exactly the (time, tenant) order one global
//! calendar would produce. Deterministic by construction: no wall
//! clock, no thread scheduling, a total order over every event.

use super::Time;

/// An event-driven system that can be single-stepped by a coordinator.
pub trait Steppable {
    /// Time of the next pending event, or `None` when this system has
    /// nothing more to do (finished, or queue drained).
    fn next_time(&self) -> Option<Time>;
    /// Pop and process one event. Returns `false` if there was nothing
    /// to pop.
    fn step(&mut self) -> bool;
}

/// Drain `systems` to completion in global (time, index) order; returns
/// the number of steps executed.
pub fn interleave<T: Steppable>(systems: &mut [T]) -> u64 {
    let mut steps = 0;
    loop {
        let mut best: Option<(Time, usize)> = None;
        for (i, s) in systems.iter().enumerate() {
            if let Some(t) = s.next_time() {
                // Strict `<` keeps the earliest index on ties.
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, i));
                }
            }
        }
        let Some((_, i)) = best else { return steps };
        if systems[i].step() {
            steps += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy steppable: a preloaded list of event times, recording
    /// (time, id) into a shared log on each step.
    struct Toy<'a> {
        id: usize,
        times: Vec<Time>,
        cursor: usize,
        log: &'a std::cell::RefCell<Vec<(Time, usize)>>,
    }

    impl Steppable for Toy<'_> {
        fn next_time(&self) -> Option<Time> {
            self.times.get(self.cursor).copied()
        }
        fn step(&mut self) -> bool {
            let Some(&t) = self.times.get(self.cursor) else { return false };
            self.cursor += 1;
            self.log.borrow_mut().push((t, self.id));
            true
        }
    }

    #[test]
    fn merges_in_global_time_order_with_index_ties() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut toys = vec![
            Toy { id: 0, times: vec![5, 10, 10, 30], cursor: 0, log: &log },
            Toy { id: 1, times: vec![1, 10, 20], cursor: 0, log: &log },
        ];
        let steps = interleave(&mut toys);
        assert_eq!(steps, 7);
        assert_eq!(
            log.into_inner(),
            vec![(1, 1), (5, 0), (10, 0), (10, 0), (10, 1), (20, 1), (30, 0)],
            "ties must resolve to the lowest index, repeatedly"
        );
    }

    #[test]
    fn empty_and_single_system() {
        let log = std::cell::RefCell::new(Vec::new());
        let mut none: Vec<Toy> = Vec::new();
        assert_eq!(interleave(&mut none), 0);
        let mut one = vec![Toy { id: 7, times: vec![2, 4], cursor: 0, log: &log }];
        assert_eq!(interleave(&mut one), 2);
        assert_eq!(log.into_inner(), vec![(2, 7), (4, 7)]);
    }
}
