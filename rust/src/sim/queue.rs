//! The event queue: a binary min-heap keyed on (time, sequence).
//!
//! Sequence numbers break ties deterministically in insertion order, which
//! keeps simulations bit-reproducible regardless of heap internals.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Time;

#[derive(Debug)]
struct Entry<E> {
    at: Time,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Deterministic discrete-event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: Time,
    pushed: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0, pushed: 0, popped: 0 }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Scheduling in the past
    /// (before `now`) is a logic error and panics in debug builds; in
    /// release it clamps to `now` to keep time monotone.
    pub fn push_at(&mut self, at: Time, event: E) {
        debug_assert!(at >= self.now, "event scheduled in the past: {} < {}", at, self.now);
        let at = at.max(self.now);
        self.heap.push(Reverse(Entry { at, seq: self.seq, event }));
        self.seq += 1;
        self.pushed += 1;
    }

    /// Schedule `event` `delay` after now.
    #[inline]
    pub fn push_in(&mut self, delay: Time, event: E) {
        self.push_at(self.now + delay, event);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        self.popped += 1;
        Some((e.at, e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Total events processed (for the sim-throughput perf metric).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    pub fn pushed(&self) -> u64 {
        self.pushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn now_advances_with_pops() {
        let mut q = EventQueue::new();
        q.push_at(7, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 7);
        q.push_in(3, ());
        assert_eq!(q.peek_time(), Some(10));
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.push_at(100, ());
        q.pop();
        q.push_at(50, ());
    }

    #[test]
    fn counters() {
        let mut q = EventQueue::new();
        q.push_at(1, ());
        q.push_at(2, ());
        q.pop();
        assert_eq!(q.pushed(), 2);
        assert_eq!(q.popped(), 1);
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }
}
