//! The event queue: a two-level bucketed (calendar-style) queue keyed on
//! (time, sequence).
//!
//! Discrete-event simulators spend a large share of their cycles in the
//! pending-event set, and a binary heap pays `O(log n)` pointer-chasing
//! per operation. The overwhelming majority of this simulator's events
//! land within a few microseconds of `now` (LLC hits, compute bursts,
//! DRAM fills, link beats), so the queue is split in two:
//!
//! * a **near-horizon ring** of `NUM_BUCKETS` time buckets, each
//!   `2^BUCKET_SHIFT` ps wide, drained in slot order with `O(1)`
//!   amortized push/pop. Only the single *active* bucket is kept sorted
//!   (sorted once when the drain cursor reaches it; same-slot pushes do a
//!   binary insert);
//! * an **overflow min-heap** for far-future events (DS `FlushTick`
//!   reschedules, SSD GC completions, multi-ms UVM fault service), which
//!   migrate into the ring as the horizon advances past them.
//!
//! Sequence numbers break same-time ties deterministically in insertion
//! order — the exact ordering contract of the old `BinaryHeap` engine —
//! so simulations stay bit-reproducible regardless of queue internals
//! (asserted by `tests/props.rs::prop_bucketed_queue_matches_reference_heap`).
//! Scheduling in the past is still a debug-build panic.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Time;

/// log2 of a near-horizon bucket's width in picoseconds (8.192 ns): wide
/// enough that dense same-warp wakeups share a bucket, narrow enough that
/// a bucket rarely holds more than a few dozen events.
const BUCKET_SHIFT: u32 = 13;
/// Near-horizon bucket count (power of two). With `BUCKET_SHIFT = 13`
/// the horizon spans ~67 µs — past Z-NAND read latency, so only rare
/// multi-ms events (GC, UVM windows, flush ticks) hit the overflow heap.
const NUM_BUCKETS: usize = 1 << 13;
const OCC_WORDS: usize = NUM_BUCKETS / 64;

#[derive(Debug)]
struct Entry<E> {
    at: Time,
    seq: u64,
    event: E,
}

impl<E> Entry<E> {
    #[inline]
    fn key(&self) -> (Time, u64) {
        (self.at, self.seq)
    }
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Deterministic discrete-event queue (two-level calendar).
///
/// Invariants (checked in debug builds where cheap):
/// * every ring event's slot (`at >> BUCKET_SHIFT`) lies in
///   `[cur_slot, cur_slot + NUM_BUCKETS)`; two live slots never alias one
///   ring index because the window is exactly one rotation long;
/// * every overflow event's slot is `>= cur_slot + NUM_BUCKETS`
///   (re-established by `migrate` whenever `cur_slot` advances);
/// * `cur_slot == slot(now)` between `pop` calls, so `push_at(now, ..)`
///   always lands in the live window.
#[derive(Debug)]
pub struct EventQueue<E> {
    ring: Vec<Vec<Entry<E>>>,
    /// One bit per ring bucket: set iff non-empty (fast drain skipping).
    occ: [u64; OCC_WORDS],
    /// Events currently held in the ring.
    ring_len: usize,
    /// Absolute (unwrapped) slot of `now`; the drain cursor.
    cur_slot: u64,
    /// Whether the active bucket is sorted descending by (time, seq).
    active_sorted: bool,
    overflow: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: Time,
    pushed: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            ring: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            occ: [0; OCC_WORDS],
            ring_len: 0,
            cur_slot: 0,
            active_sorted: true,
            overflow: BinaryHeap::new(),
            seq: 0,
            now: 0,
            pushed: 0,
            popped: 0,
        }
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    #[inline]
    fn slot_of(at: Time) -> u64 {
        at >> BUCKET_SHIFT
    }

    #[inline]
    fn ring_idx(slot: u64) -> usize {
        slot as usize & (NUM_BUCKETS - 1)
    }

    /// Schedule `event` at absolute time `at`. Scheduling in the past
    /// (before `now`) is a logic error and panics in debug builds; in
    /// release it clamps to `now` to keep time monotone.
    pub fn push_at(&mut self, at: Time, event: E) {
        debug_assert!(at >= self.now, "event scheduled in the past: {} < {}", at, self.now);
        let at = at.max(self.now);
        let entry = Entry { at, seq: self.seq, event };
        self.seq += 1;
        self.pushed += 1;
        self.insert(entry);
    }

    /// Schedule `event` `delay` after now.
    #[inline]
    pub fn push_in(&mut self, delay: Time, event: E) {
        self.push_at(self.now + delay, event);
    }

    /// Claim the sequence number the *next* `push_at` would have used,
    /// without scheduling anything. Pair with [`push_at_seq`] to defer a
    /// push while preserving the exact tie-break position it would have
    /// had if made immediately — the mechanism the sharded pool
    /// (`fabric::shard`) uses to replay deferred fabric completions
    /// bit-identically to the serial run.
    ///
    /// [`push_at_seq`]: EventQueue::push_at_seq
    #[inline]
    pub fn reserve_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    /// Schedule `event` at `at` under a sequence number previously
    /// claimed with [`reserve_seq`]. The caller must use each reserved
    /// seq at most once — (time, seq) keys must stay unique for the
    /// active-bucket binary insert.
    ///
    /// [`reserve_seq`]: EventQueue::reserve_seq
    pub fn push_at_seq(&mut self, at: Time, seq: u64, event: E) {
        debug_assert!(at >= self.now, "event scheduled in the past: {} < {}", at, self.now);
        debug_assert!(seq < self.seq, "seq {} was never reserved", seq);
        let at = at.max(self.now);
        self.pushed += 1;
        self.insert(Entry { at, seq, event });
    }

    /// Place an entry in the ring or the overflow heap.
    fn insert(&mut self, entry: Entry<E>) {
        let slot = Self::slot_of(entry.at);
        debug_assert!(slot >= self.cur_slot, "entry behind the drain cursor");
        if slot >= self.cur_slot + NUM_BUCKETS as u64 {
            self.overflow.push(Reverse(entry));
            return;
        }
        let idx = Self::ring_idx(slot);
        let bucket = &mut self.ring[idx];
        if slot == self.cur_slot && self.active_sorted {
            // Active bucket stays sorted descending; keys are unique so
            // partition_point lands between strict neighbours.
            let key = entry.key();
            let pos = bucket.partition_point(|e| e.key() > key);
            bucket.insert(pos, entry);
        } else {
            bucket.push(entry);
        }
        if bucket.len() == 1 {
            self.occ[idx >> 6] |= 1u64 << (idx & 63);
        }
        self.ring_len += 1;
    }

    /// Pull overflow events whose slot has entered the horizon into the
    /// ring. Called whenever `cur_slot` advances; each overflow event
    /// migrates at most once because the horizon is monotone.
    fn migrate(&mut self) {
        let horizon = self.cur_slot + NUM_BUCKETS as u64;
        loop {
            match self.overflow.peek() {
                Some(Reverse(e)) if Self::slot_of(e.at) < horizon => {}
                _ => break,
            }
            let Reverse(e) = self.overflow.pop().unwrap();
            self.insert(e);
        }
    }

    /// Next occupied ring slot strictly after `cur_slot`. Caller must
    /// ensure the ring is non-empty and the current bucket is drained.
    fn next_occupied_slot(&self) -> u64 {
        debug_assert!(self.ring_len > 0);
        let cur_idx = Self::ring_idx(self.cur_slot);
        debug_assert!(self.ring[cur_idx].is_empty());
        let start = (cur_idx + 1) & (NUM_BUCKETS - 1);
        let mut word_i = start >> 6;
        let mut word = self.occ[word_i] & (!0u64 << (start & 63));
        let mut scanned = 0;
        loop {
            if word != 0 {
                let idx = (word_i << 6) | word.trailing_zeros() as usize;
                let dist = (idx.wrapping_sub(cur_idx) & (NUM_BUCKETS - 1)) as u64;
                debug_assert!(dist > 0);
                return self.cur_slot + dist;
            }
            word_i = (word_i + 1) & (OCC_WORDS - 1);
            word = self.occ[word_i];
            scanned += 1;
            assert!(scanned <= OCC_WORDS, "ring_len > 0 but occupancy bitmap empty");
        }
    }

    /// Pop the next event in (time, sequence) order, advancing `now`.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        loop {
            let idx = Self::ring_idx(self.cur_slot);
            if !self.ring[idx].is_empty() {
                if !self.active_sorted {
                    self.ring[idx].sort_unstable_by(|a, b| b.key().cmp(&a.key()));
                    self.active_sorted = true;
                }
                let e = self.ring[idx].pop().unwrap();
                if self.ring[idx].is_empty() {
                    self.occ[idx >> 6] &= !(1u64 << (idx & 63));
                }
                self.ring_len -= 1;
                self.now = e.at;
                self.popped += 1;
                return Some((e.at, e.event));
            }
            // Current bucket drained: advance the cursor to the next
            // event source (ring first — the overflow invariant puts all
            // heap events at least one full rotation out).
            if self.ring_len > 0 {
                self.cur_slot = self.next_occupied_slot();
            } else if let Some(Reverse(e)) = self.overflow.peek() {
                self.cur_slot = Self::slot_of(e.at);
            } else {
                return None;
            }
            self.active_sorted = false;
            self.migrate();
        }
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<Time> {
        if self.ring_len > 0 {
            // Earlier slots hold strictly earlier times, so the first
            // occupied bucket from the cursor contains the global minimum
            // (overflow events are at least a rotation later).
            let cur_idx = Self::ring_idx(self.cur_slot);
            if !self.ring[cur_idx].is_empty() {
                let b = &self.ring[cur_idx];
                if self.active_sorted {
                    return b.last().map(|e| e.at);
                }
                return b.iter().map(|e| e.at).min();
            }
            let b = &self.ring[Self::ring_idx(self.next_occupied_slot())];
            b.iter().map(|e| e.at).min()
        } else {
            self.overflow.peek().map(|Reverse(e)| e.at)
        }
    }

    pub fn is_empty(&self) -> bool {
        self.ring_len == 0 && self.overflow.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ring_len + self.overflow.len()
    }

    /// Total events processed (for the sim-throughput perf metric).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    pub fn pushed(&self) -> u64 {
        self.pushed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push_at(5, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn now_advances_with_pops() {
        let mut q = EventQueue::new();
        q.push_at(7, ());
        assert_eq!(q.now(), 0);
        q.pop();
        assert_eq!(q.now(), 7);
        q.push_in(3, ());
        assert_eq!(q.peek_time(), Some(10));
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.push_at(100, ());
        q.pop();
        q.push_at(50, ());
    }

    #[test]
    fn counters() {
        let mut q = EventQueue::new();
        q.push_at(1, ());
        q.push_at(2, ());
        q.pop();
        assert_eq!(q.pushed(), 2);
        assert_eq!(q.popped(), 1);
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }

    #[test]
    fn reserved_seq_keeps_deferred_push_in_original_tie_position() {
        // a reserves its slot, b pushes after it, both at the same time:
        // a must still pop first, exactly as if it had pushed eagerly.
        let mut q = EventQueue::new();
        let seq_a = q.reserve_seq();
        q.push_at(5, "b");
        q.push_at_seq(5, seq_a, "a");
        assert_eq!(q.pop(), Some((5, "a")));
        assert_eq!(q.pop(), Some((5, "b")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pushed(), 2);
        assert_eq!(q.popped(), 2);
    }

    #[test]
    fn reserved_seq_interleaves_with_plain_pushes() {
        let mut q = EventQueue::new();
        q.push_at(10, 0u32); // seq 0
        let s1 = q.reserve_seq(); // seq 1
        q.push_at(10, 2u32); // seq 2
        let s3 = q.reserve_seq(); // seq 3
        q.push_at_seq(10, s3, 3u32);
        q.push_at_seq(10, s1, 1u32);
        for want in 0..4u32 {
            assert_eq!(q.pop(), Some((10, want)));
        }
    }

    /// One bucket width in ps (for horizon-crossing tests).
    const W: Time = 1 << BUCKET_SHIFT;

    #[test]
    fn far_future_events_round_trip_through_overflow() {
        let mut q = EventQueue::new();
        let far = W * NUM_BUCKETS as Time * 3 + 17; // well past the horizon
        q.push_at(far, "far");
        q.push_at(5, "near");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((5, "near")));
        assert_eq!(q.pop(), Some((far, "far")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn overflow_migration_preserves_order_against_later_ring_pushes() {
        let mut q = EventQueue::new();
        let horizon = W * NUM_BUCKETS as Time;
        q.push_at(horizon + 10, 1u32); // overflow at push time
        q.push_at(horizon - 10, 2u32); // tail of the ring
        assert_eq!(q.pop(), Some((horizon - 10, 2)));
        // Now inside the horizon: a fresh near event must not overtake
        // the migrated one if it is later in time.
        q.push_in(30, 3u32);
        assert_eq!(q.pop(), Some((horizon + 10, 1)));
        assert_eq!(q.pop(), Some((horizon - 10 + 30, 3)));
    }

    #[test]
    fn interleaved_push_pop_is_globally_sorted() {
        let mut q = EventQueue::new();
        let mut last = (0, 0);
        let mut seq_seen = 0u64;
        for round in 0..50u64 {
            // A spread of same-bucket, near, and far pushes each round.
            let base = q.now();
            q.push_at(base, round * 10);
            q.push_at(base + W / 2, round * 10 + 1);
            q.push_at(base + W * 7 + 3, round * 10 + 2);
            q.push_at(base + W * NUM_BUCKETS as Time + round, round * 10 + 3);
            for _ in 0..3 {
                let (t, _) = q.pop().expect("queue has events");
                let key = (t, seq_seen);
                assert!(t >= last.0, "time regressed: {t} < {}", last.0);
                last = key;
                seq_seen += 1;
            }
        }
        let mut prev = last.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= prev);
            prev = t;
        }
        assert_eq!(q.pushed(), q.popped());
    }
}
