//! Conservative-lookahead parallel discrete-event simulation (PDES).
//!
//! [`run_conservative`] advances N event-driven systems on worker
//! threads while guaranteeing the *exact* event order — and therefore
//! bit-identical results — of the serial [`interleave()`] merge. The
//! classic conservative argument (Chandy–Misra–Bryant, specialized to a
//! hub-and-spoke topology): systems only interact through one shared
//! hub (the CXL switch), and every interaction's effect lands at least
//! `lookahead` after its cause, so a system may safely run ahead of its
//! own earliest un-executed interaction by up to that window without
//! ever processing an event that the response could have preceded.
//!
//! The run alternates two phases:
//!
//! * **Parallel epoch** — every system independently records (defers)
//!   its hub interactions and advances until its next event would cross
//!   `earliest recorded interaction + lookahead`, or it finishes.
//!   Systems share nothing here, so thread scheduling cannot influence
//!   the outcome.
//! * **Serial reconciliation** — one coordinator replays the recorded
//!   interactions against the hub in global `(time, system index,
//!   record order)` order, stopping at the conservative cut: an
//!   interaction at `(t, i)` replays only while every other live system
//!   `j` satisfies `(t, i) < (next_time_j, j)` — past that point system
//!   `j` could still generate an earlier-ordered interaction once
//!   resumed. The cut is re-evaluated live because replaying a load
//!   re-arms its system's calendar (the fill lands), pulling
//!   `next_time` down.
//!
//! Progress: after an epoch every unfinished system is blocked on its
//! own earliest recorded interaction at `t_head`, with
//! `next_time >= t_head + lookahead > t_head`; the globally minimal
//! recorded interaction therefore always passes the cut, so every round
//! retires at least one interaction or finishes a system.
//!
//! Determinism: phase boundaries and the replay order are functions of
//! simulation state only — worker count, shard count, and OS scheduling
//! affect wall-clock, never results. `fabric::shard` pins this with a
//! bit-equality harness against the serial run.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use super::{Steppable, Time};

/// A [`Steppable`] system that can defer its shared-hub interactions
/// for barrier-phase replay. `coordinator::System` implements this for
/// pooled-fabric tenants (`fabric::shard`).
pub trait Lookahead: Steppable + Send {
    /// Advance until the next event would reach `earliest pending
    /// interaction + lookahead`, or the system finishes. Must not touch
    /// any shared state.
    fn advance(&mut self, lookahead: Time) -> u64;
    /// Event time of the earliest pending recorded interaction.
    fn pending_head(&self) -> Option<Time>;
    /// Execute the earliest pending interaction against the hub.
    fn replay_head(&mut self) -> bool;
    /// Finished with nothing left to replay.
    fn drained(&self) -> bool;
}

/// Drain `systems` to completion, bit-identically to
/// `interleave(systems)`, using up to `threads` workers over `shards`
/// contiguous system groups. Returns the systems plus the total steps
/// executed (equal to the serial merge's step count).
///
/// `lookahead` must be a lower bound on the cause→effect delay of every
/// hub interaction (for the CXL pool: one switch hop each way). A
/// larger-than-true value is unsound; a smaller one only costs rounds.
pub fn run_conservative<T: Lookahead>(
    systems: Vec<T>,
    shards: usize,
    threads: usize,
    lookahead: Time,
) -> (Vec<T>, u64) {
    let n = systems.len();
    if n == 0 {
        return (systems, 0);
    }
    let shards = shards.clamp(1, n);
    let workers = threads.clamp(1, shards);
    // Shard s owns the contiguous range [s*per, (s+1)*per); worker w
    // round-robins over shards w, w+workers, ... — a fixed partition,
    // though results never depend on it (epochs share nothing).
    let per = n.div_ceil(shards);
    let cells: Vec<Mutex<T>> = systems.into_iter().map(Mutex::new).collect();
    let steps = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(workers + 1);

    std::thread::scope(|scope| {
        for w in 0..workers {
            let (cells, steps, stop, barrier) = (&cells, &steps, &stop, &barrier);
            scope.spawn(move || loop {
                barrier.wait();
                if stop.load(Ordering::Acquire) {
                    return;
                }
                let mut local = 0;
                let mut s = w;
                while s * per < n {
                    let hi = ((s + 1) * per).min(n);
                    for cell in &cells[s * per..hi] {
                        local += cell.lock().expect("pdes tenant mutex poisoned").advance(lookahead);
                    }
                    s += workers;
                }
                steps.fetch_add(local, Ordering::Relaxed);
                barrier.wait();
            });
        }

        loop {
            barrier.wait(); // release workers into a parallel epoch
            barrier.wait(); // epoch done: every system blocked or finished
            let mut guards: Vec<_> = cells
                .iter()
                .map(|c| c.lock().expect("pdes tenant mutex poisoned"))
                .collect();
            loop {
                // Globally earliest recorded interaction (ties to the
                // lowest index — the serial merge's tie rule).
                let mut cand: Option<(Time, usize)> = None;
                for (i, g) in guards.iter().enumerate() {
                    if let Some(t) = g.pending_head() {
                        if cand.map_or(true, |(bt, _)| t < bt) {
                            cand = Some((t, i));
                        }
                    }
                }
                let Some((t, i)) = cand else { break };
                // The conservative cut (see module docs).
                let safe = guards
                    .iter()
                    .enumerate()
                    .all(|(j, g)| j == i || g.next_time().map_or(true, |nj| (t, i) < (nj, j)));
                if !safe {
                    break;
                }
                guards[i].replay_head();
            }
            let done = guards.iter().all(|g| g.drained());
            drop(guards);
            if done {
                stop.store(true, Ordering::Release);
                barrier.wait(); // workers observe `stop` and exit
                break;
            }
        }
    });

    let out = cells
        .into_iter()
        .map(|c| c.into_inner().expect("pdes tenant mutex poisoned"))
        .collect();
    (out, steps.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::interleave;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Toy hub-coupled system: a schedule of (time, is_interaction)
    /// events; interactions append (time, id, local order) to a shared
    /// log (the "hub") and, like a real fabric load, schedule a local
    /// follow-up event at `time + LAT`. `LAT >= LOOKAHEAD` keeps the toy
    /// honest about the causality bound.
    const LAT: Time = 10;
    const LOOKAHEAD: Time = 10;

    #[derive(Debug)]
    struct Toy<'a> {
        id: usize,
        /// (time, hub-interaction?) events, merged with scheduled
        /// follow-ups; kept sorted ascending by (time, insertion).
        queue: std::collections::VecDeque<(Time, bool)>,
        hub: &'a Mutex<Vec<(Time, usize)>>,
        /// Deferred interaction times (deferral mode on = record).
        defer: bool,
        pending: std::collections::VecDeque<Time>,
        steps_hint: &'a AtomicU64,
    }

    impl Toy<'_> {
        fn interact(&mut self, t: Time) {
            self.hub.lock().unwrap().push((t, self.id));
            // Follow-up lands a full latency later; insert keeping the
            // queue time-sorted (stable for equal times).
            let at = t + LAT;
            let pos = self.queue.partition_point(|&(qt, _)| qt <= at);
            self.queue.insert(pos, (at, false));
        }
    }

    impl Steppable for Toy<'_> {
        fn next_time(&self) -> Option<Time> {
            self.queue.front().map(|&(t, _)| t)
        }
        fn step(&mut self) -> bool {
            let Some((t, hub)) = self.queue.pop_front() else { return false };
            self.steps_hint.fetch_add(1, Ordering::Relaxed);
            if hub {
                if self.defer {
                    self.pending.push_back(t);
                } else {
                    self.interact(t);
                }
            }
            true
        }
    }

    impl Lookahead for Toy<'_> {
        fn advance(&mut self, lookahead: Time) -> u64 {
            let mut steps = 0;
            while let Some(t) = self.next_time() {
                if let Some(&head) = self.pending.front() {
                    if t >= head + lookahead {
                        break;
                    }
                }
                if !self.step() {
                    break;
                }
                steps += 1;
            }
            steps
        }
        fn pending_head(&self) -> Option<Time> {
            self.pending.front().copied()
        }
        fn replay_head(&mut self) -> bool {
            let Some(t) = self.pending.pop_front() else { return false };
            self.interact(t);
            true
        }
        fn drained(&self) -> bool {
            self.queue.is_empty() && self.pending.is_empty()
        }
    }

    fn build<'a>(
        hub: &'a Mutex<Vec<(Time, usize)>>,
        steps: &'a AtomicU64,
        defer: bool,
    ) -> Vec<Toy<'a>> {
        // Deliberately rough mix: equal times across systems, bursts,
        // hub interactions back-to-back within the lookahead window.
        let schedules: [&[(Time, bool)]; 5] = [
            &[(0, true), (3, false), (25, true), (25, true), (90, false)],
            &[(0, false), (5, true), (25, true), (60, true)],
            &[(2, true), (2, true), (40, false), (80, true)],
            &[(7, false), (8, false), (9, false)],
            &[(5, true), (26, true), (47, true), (68, true), (89, true)],
        ];
        schedules
            .iter()
            .enumerate()
            .map(|(id, sched)| Toy {
                id,
                queue: sched.iter().copied().collect(),
                hub,
                defer,
                pending: std::collections::VecDeque::new(),
                steps_hint: steps,
            })
            .collect()
    }

    #[test]
    fn conservative_run_matches_serial_interleave_exactly() {
        let serial_hub = Mutex::new(Vec::new());
        let serial_steps = AtomicU64::new(0);
        let mut serial = build(&serial_hub, &serial_steps, false);
        let steps = interleave(&mut serial);

        for shards in [1, 2, 3, 5] {
            for threads in [1, 2, 4] {
                let hub = Mutex::new(Vec::new());
                let hint = AtomicU64::new(0);
                let systems = build(&hub, &hint, true);
                let (out, psteps) = run_conservative(systems, shards, threads, LOOKAHEAD);
                assert!(out.iter().all(|t| t.drained()));
                assert_eq!(psteps, steps, "step count (shards {shards}, threads {threads})");
                assert_eq!(
                    *hub.lock().unwrap(),
                    *serial_hub.lock().unwrap(),
                    "hub order diverged at shards {shards}, threads {threads}"
                );
            }
        }
    }

    #[test]
    fn empty_input_is_a_noop() {
        let (out, steps) = run_conservative(Vec::<Toy>::new(), 4, 4, LOOKAHEAD);
        assert!(out.is_empty());
        assert_eq!(steps, 0);
    }
}
