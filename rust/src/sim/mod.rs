//! Discrete-event simulation core.
//!
//! The whole memory-system model runs on one [`EventQueue`]: components
//! schedule typed events at absolute picosecond timestamps and the system
//! drains them in (time, sequence) order, so simulations are fully
//! deterministic for a given seed. Mirrors the paper's methodology — their
//! evaluation also ran on a software simulator reproducing the RTL's
//! behaviour (Evaluation §Methodology).

pub mod interleave;
pub mod pdes;
pub mod queue;

pub use interleave::{interleave, Steppable};
pub use pdes::{run_conservative, Lookahead};
pub use queue::EventQueue;
/// Historical name for the bucketed time series, which now lives with
/// the flight recorder as [`crate::telemetry::Series`] (§19) — one
/// time-series representation for Fig. 9e and telemetry alike.
pub use crate::telemetry::Series as Timeline;

/// Simulation time in **picoseconds**. CXL layer costs are single-digit
/// nanoseconds and PCIe serialization is sub-nanosecond per lane-beat, so
/// nanosecond resolution would accumulate rounding error.
pub type Time = u64;

/// One nanosecond in [`Time`] units.
pub const NS: Time = 1_000;
/// One microsecond.
pub const US: Time = 1_000_000;
/// One millisecond.
pub const MS: Time = 1_000_000_000;

/// Convert picoseconds to fractional nanoseconds (for reporting only).
pub fn ps_to_ns(t: Time) -> f64 {
    t as f64 / NS as f64
}

/// Convert a (bytes, GB/s) pair to a serialization delay.
///
/// `gbps` is interpreted as 10^9 bytes per second (vendor convention used
/// by the paper's PCIe 5.0 x8 ≈ 32 GB/s figure).
pub fn transfer_time(bytes: u64, gbps: f64) -> Time {
    debug_assert!(gbps > 0.0);
    // ps = bytes / (GB/s) * 1e12 / 1e9 = bytes * 1000 / gbps
    (bytes as f64 * 1000.0 / gbps).round() as Time
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_64b_at_32gbps_is_2ns() {
        assert_eq!(transfer_time(64, 32.0), 2 * NS);
    }

    #[test]
    fn transfer_time_4k_page() {
        // 4096 B at 32 GB/s = 128 ns.
        assert_eq!(transfer_time(4096, 32.0), 128 * NS);
    }

    #[test]
    fn ps_to_ns_roundtrip() {
        assert_eq!(ps_to_ns(1_500), 1.5);
    }
}
