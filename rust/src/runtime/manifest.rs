//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (shapes, dtypes, file names, content hashes).

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// One tensor's shape + dtype.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<u64>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> u64 {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|v| v.as_u64().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-lowered workload.
#[derive(Debug, Clone)]
pub struct WorkloadArtifact {
    pub name: String,
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub workloads: Vec<WorkloadArtifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let arr = j
            .get("workloads")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing `workloads`"))?;
        let mut workloads = Vec::with_capacity(arr.len());
        for w in arr {
            let name = w
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("workload missing name"))?
                .to_string();
            let hlo = w
                .get("hlo")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing hlo path"))?
                .to_string();
            let inputs = w
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?;
            let outputs = w
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?;
            let sha256 = w
                .get("sha256")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string();
            workloads.push(WorkloadArtifact { name, hlo, inputs, outputs, sha256 });
        }
        Ok(Manifest { workloads })
    }

    pub fn get(&self, name: &str) -> Option<&WorkloadArtifact> {
        self.workloads.iter().find(|w| w.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.workloads.iter().map(|w| w.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "workloads": [
        {"name": "vadd", "hlo": "vadd.hlo.txt",
         "inputs": [{"shape": [262144], "dtype": "float32"},
                    {"shape": [262144], "dtype": "float32"}],
         "outputs": [{"shape": [262144], "dtype": "float32"}],
         "sha256": "abc"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.workloads.len(), 1);
        let w = m.get("vadd").unwrap();
        assert_eq!(w.inputs.len(), 2);
        assert_eq!(w.inputs[0].elements(), 262144);
        assert_eq!(w.outputs[0].dtype, "float32");
        assert_eq!(w.sha256, "abc");
    }

    #[test]
    fn names_listed() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.names(), vec!["vadd"]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"workloads": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
