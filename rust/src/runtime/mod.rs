//! PJRT runtime: load the AOT-compiled workload artifacts and execute the
//! *real* workload compute from Rust.
//!
//! Python runs only at build time (`make artifacts`); this module loads
//! the HLO **text** artifacts (see python/compile/aot.py for why text,
//! not serialized protos), compiles them on the PJRT CPU client, and
//! executes them with deterministic inputs. The e2e example uses this to
//! prove the three layers compose: L1 Pallas kernels inside L2 JAX graphs
//! executed under the L3 coordinator.

pub mod manifest;

pub use manifest::{Manifest, TensorSpec, WorkloadArtifact};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::prng::Pcg32;

/// Result of executing one workload artifact.
#[derive(Debug, Clone, Copy)]
pub struct ExecOutcome {
    /// Number of output tensors.
    pub outputs: usize,
    /// Mean of all finite f32 output values (stable under same seed).
    pub checksum: f64,
    /// Total output elements.
    pub elements: usize,
}

/// The PJRT runtime: one CPU client + the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: String,
}

impl Runtime {
    /// Load the manifest from `dir` and start a PJRT CPU client.
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, manifest, dir: dir.to_string() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one workload's HLO text.
    fn compile(&self, art: &WorkloadArtifact) -> Result<xla::PjRtLoadedExecutable> {
        let path = format!("{}/{}", self.dir, art.hlo);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).with_context(|| format!("compiling {}", art.name))
    }

    /// Build a deterministic input literal for a tensor spec, applying the
    /// same per-workload validity fixups the python tests use (diagonal
    /// dominance for gauss, 0/1 adjacency + one-hot for bfs/gnn, positive
    /// fields for cfd).
    fn build_input(
        workload: &str,
        idx: usize,
        ninputs: usize,
        spec: &TensorSpec,
        rng: &mut Pcg32,
    ) -> Result<xla::Literal> {
        if spec.dtype != "float32" {
            bail!("unsupported input dtype {} for {workload}", spec.dtype);
        }
        let n: usize = spec.shape.iter().product::<u64>() as usize;
        let mut data: Vec<f32> = (0..n).map(|_| (rng.f64() as f32) * 2.0 - 1.0).collect();

        match (workload, idx) {
            ("gauss", 0) => {
                // Diagonal dominance over the (m, m+1) augmented matrix.
                let m = spec.shape[0] as usize;
                let cols = spec.shape[1] as usize;
                for i in 0..m {
                    data[i * cols + i] += m as f32;
                }
            }
            ("bfs", 0) | ("gnn", 0) => {
                // Sparse 0/1 adjacency.
                for v in data.iter_mut() {
                    *v = if *v > 0.8 { 1.0 } else { 0.0 };
                }
            }
            ("bfs", i) | ("gnn", i) if i == ninputs - 1 => {
                // One-hot source vector.
                for v in data.iter_mut() {
                    *v = 0.0;
                }
                data[0] = 1.0;
            }
            ("cfd", 0) => {
                for v in data.iter_mut() {
                    *v = v.abs() + 1.0; // positive density
                }
            }
            ("cfd", 2) => {
                for v in data.iter_mut() {
                    *v = v.abs() + 10.0; // positive energy
                }
            }
            ("saxpy", 0) => {
                data[0] = 2.5; // the scalar a
            }
            _ => {}
        }

        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(&data);
        Ok(if dims.len() == 1 { lit } else { lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))? })
    }

    /// Execute a workload by name with deterministic inputs.
    pub fn execute_named(&self, name: &str, seed: u64) -> Result<ExecOutcome> {
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("workload `{name}` not in manifest"))?;
        let exe = self.compile(art)?;
        let mut rng = Pcg32::new(seed, 7);
        let inputs: Vec<xla::Literal> = art
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| Self::build_input(name, i, art.inputs.len(), s, &mut rng))
            .collect::<Result<_>>()?;

        let result = exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        // aot.py lowers with return_tuple=True.
        let parts = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;

        let mut sum = 0.0f64;
        let mut elements = 0usize;
        let nparts = parts.len();
        for part in parts {
            let ty = part.ty().map_err(|e| anyhow!("{e:?}"))?;
            match ty {
                xla::ElementType::F32 => {
                    let v: Vec<f32> = part.to_vec().map_err(|e| anyhow!("{e:?}"))?;
                    for x in &v {
                        if !x.is_finite() {
                            bail!("{name}: non-finite output value");
                        }
                        sum += *x as f64;
                    }
                    elements += v.len();
                }
                xla::ElementType::S32 => {
                    let v: Vec<i32> = part.to_vec().map_err(|e| anyhow!("{e:?}"))?;
                    sum += v.iter().map(|&x| x as f64).sum::<f64>();
                    elements += v.len();
                }
                other => bail!("{name}: unhandled output type {other:?}"),
            }
        }
        Ok(ExecOutcome { outputs: nparts, checksum: sum / elements.max(1) as f64, elements })
    }
}
