//! # CXL-GPU
//!
//! Production-grade reproduction of *"CXL-GPU: Pushing GPU Memory
//! Boundaries with the Integration of CXL Technologies"* (Gouk et al.,
//! 2025): a GPU memory-expansion system built on CXL root ports, a
//! low-latency layered CXL controller model, and the paper's two
//! controller optimizations — **Speculative Read** (SR) and
//! **Deterministic Store** (DS).
//!
//! The crate is a three-layer stack:
//! - **L3 (this crate)** — the full-system discrete-event simulator (GPU
//!   SMs → LLC → system bus → CXL root complex → EPs with DRAM/SSD
//!   media), the SR/DS engines, the UVM/GDS baselines, the pooled
//!   multi-GPU CXL fabric (`fabric/`), plus the experiment coordinator
//!   and the PJRT runtime that executes the real workload compute.
//! - **L2 (python/compile/model.py)** — the 13 evaluation workloads as
//!   JAX graphs, AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the workload
//!   hot-spots, validated against pure-jnp oracles.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod baselines;
pub mod coordinator;
pub mod cxl;
pub mod expander;
pub mod fabric;
pub mod gpu;
pub mod media;
pub mod obs;
pub mod ras;
pub mod rootcomplex;
/// PJRT artifact execution. Needs the vendored `xla` closure (plus
/// `anyhow`), which offline builds don't ship — hence feature-gated; the
/// simulator and coordinator never depend on it.
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workloads;
