//! Adaptive admission/bypass predictor for the expander-side device
//! cache (DESIGN.md §14).
//!
//! In the spirit of ICGMM's learned admission control, but fully
//! deterministic: per-region reuse counters over fixed-length epochs.
//! A 16 KiB device-address region that produced cache hits in the
//! current or previous epoch is *reusing* its lines — its misses are
//! admitted. A region that only streams through (touch-once scans)
//! never earns hits and is bypassed, except for a deterministic 1-in-N
//! probe that keeps the predictor able to discover new hot regions.
//! Streaming scans therefore cost the cache nothing, while reused
//! working sets are installed at full rate.

use crate::sim::Time;
use crate::util::hash::FxHashMap;

/// Admission operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// Epoch-based reuse prediction: streaming regions bypass.
    Adaptive,
    /// Admission disabled: every miss installs (the `cxl-cache-bypass`
    /// ablation — it isolates what the bypass predictor is worth by
    /// letting streams thrash the cache).
    AdmitAll,
}

/// Admission predictor parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitConfig {
    pub policy: AdmitPolicy,
    /// Region granularity: `1 << region_bits` bytes (16 KiB default —
    /// one [`crate::workloads::patterns::HOT_PAGE_BYTES`] page).
    pub region_bits: u32,
    /// Accesses (hits + admission checks) per epoch.
    pub epoch_accesses: u64,
    /// Hits a region needs in an epoch to have its misses admitted.
    pub reuse_threshold: u32,
    /// Bypassed misses between forced probe admissions (the predictor's
    /// only way to learn that a cold region turned hot).
    pub sample_period: u64,
}

impl Default for AdmitConfig {
    fn default() -> AdmitConfig {
        AdmitConfig {
            policy: AdmitPolicy::Adaptive,
            region_bits: 14, // 16 KiB
            epoch_accesses: 4096,
            reuse_threshold: 2,
            sample_period: 8,
        }
    }
}

/// Per-region reuse evidence (current + previous epoch).
#[derive(Debug, Clone, Copy, Default)]
struct Region {
    cur_hits: u32,
    prev_hits: u32,
}

/// Predictor counters (folded into the cache's stats by the caller).
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmitStats {
    /// Misses admitted because their region showed reuse.
    pub reuse_admits: u64,
    /// Misses admitted as discovery probes.
    pub probe_admits: u64,
    /// Epoch rotations performed.
    pub epochs: u64,
}

/// The deterministic admission filter. All state advances on counters —
/// no RNG, no wall clock — so runs are bit-reproducible.
#[derive(Debug)]
pub struct AdmissionFilter {
    cfg: AdmitConfig,
    /// Accesses observed since the last epoch rotation.
    accesses: u64,
    /// Global bypassed-miss counter driving probe admissions.
    probe_clock: u64,
    regions: FxHashMap<u64, Region>,
    pub stats: AdmitStats,
}

impl AdmissionFilter {
    pub fn new(cfg: AdmitConfig) -> AdmissionFilter {
        AdmissionFilter {
            cfg,
            accesses: 0,
            probe_clock: 0,
            regions: FxHashMap::default(),
            stats: AdmitStats::default(),
        }
    }

    fn region_of(&self, addr: u64) -> u64 {
        addr >> self.cfg.region_bits
    }

    /// Advance the epoch clock; rotate when the epoch budget is spent.
    fn tick(&mut self) {
        self.accesses += 1;
        if self.accesses >= self.cfg.epoch_accesses.max(1) {
            self.accesses = 0;
            self.stats.epochs += 1;
            // Rotate: this epoch's evidence becomes last epoch's, and
            // regions with no evidence at all are dropped — streaming
            // regions never accumulate, so the map stays bounded by the
            // live reused set plus one epoch's touch set. Entry updates
            // are independent, so map iteration order cannot leak into
            // any simulation-visible state.
            self.regions.retain(|_, r| {
                r.prev_hits = r.cur_hits;
                r.cur_hits = 0;
                r.prev_hits > 0
            });
        }
    }

    /// Record a cache hit at `addr` (reuse evidence for its region).
    pub fn on_hit(&mut self, addr: u64, _now: Time) {
        self.tick();
        let region = self.region_of(addr);
        self.regions.entry(region).or_default().cur_hits += 1;
    }

    /// Should the miss at `addr` be installed? Called once per read
    /// miss; the decision is part of the deterministic surface.
    pub fn should_admit(&mut self, addr: u64, _now: Time) -> bool {
        self.tick();
        if self.cfg.policy == AdmitPolicy::AdmitAll {
            // Predictor disabled: admit without touching the reuse
            // telemetry — `reuse_admits` must mean "region showed
            // reuse", and in this mode no reuse test ever ran.
            return true;
        }
        let region = self.region_of(addr);
        let t = self.cfg.reuse_threshold;
        let r = self.regions.entry(region).or_default();
        if r.prev_hits >= t || r.cur_hits >= t {
            self.stats.reuse_admits += 1;
            return true;
        }
        self.probe_clock += 1;
        if self.probe_clock % self.cfg.sample_period.max(1) == 0 {
            self.stats.probe_admits += 1;
            return true;
        }
        false
    }

    /// Live region entries (bounded-memory check for tests).
    pub fn tracked_regions(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adaptive() -> AdmissionFilter {
        AdmissionFilter::new(AdmitConfig::default())
    }

    #[test]
    fn admit_all_always_admits() {
        let mut f = AdmissionFilter::new(AdmitConfig {
            policy: AdmitPolicy::AdmitAll,
            ..AdmitConfig::default()
        });
        for i in 0..100u64 {
            assert!(f.should_admit(i * 64, 0));
        }
    }

    #[test]
    fn streaming_region_mostly_bypasses() {
        let mut f = adaptive();
        // A pure scan: every address distinct, no hits ever.
        let admitted = (0..1000u64).filter(|i| f.should_admit(i * 64, 0)).count();
        // Only the 1-in-8 probes get through.
        assert_eq!(admitted, 1000 / 8, "scan admitted {admitted}/1000");
    }

    #[test]
    fn reused_region_admits_after_hits() {
        let mut f = adaptive();
        let addr = 0x4000;
        let _ = f.should_admit(addr, 0); // cold miss; decision irrelevant
        f.on_hit(addr, 0);
        f.on_hit(addr + 64, 0);
        // Two hits this epoch clear the threshold: misses now admit.
        assert!(f.should_admit(addr + 128, 0));
        assert_eq!(f.stats.reuse_admits, 1);
    }

    #[test]
    fn evidence_survives_one_epoch_rotation() {
        let mut f = AdmissionFilter::new(AdmitConfig {
            epoch_accesses: 16,
            ..AdmitConfig::default()
        });
        f.on_hit(0x8000, 0);
        f.on_hit(0x8040, 0);
        // Burn through one rotation with foreign traffic.
        for i in 0..16u64 {
            f.should_admit(0x100_0000 + i * (1 << 14), 0);
        }
        assert!(f.stats.epochs >= 1);
        // prev_hits still vouches for the region...
        assert!(f.should_admit(0x8080, 0));
        // ...but a second hit-free rotation drops it.
        for i in 0..32u64 {
            f.should_admit(0x200_0000 + i * (1 << 14), 0);
        }
        assert!(f.tracked_regions() <= 33, "map must stay bounded");
    }

    #[test]
    fn probes_are_deterministic() {
        let run = || {
            let mut f = adaptive();
            (0..500u64).map(|i| f.should_admit(i * 4096, 0)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
