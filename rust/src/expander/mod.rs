//! Expander-side intelligent caching (DESIGN.md §14): the device's own
//! DRAM cache plus an adaptive admission predictor, living *inside* the
//! CXL endpoint between the controller and the media model.
//!
//! The paper hides backend-media latency variation from the host with
//! speculative reads and deterministic stores; this subsystem completes
//! the device half of that story (ICGMM-style intelligent caching, the
//! CXL-SSD full-system literature's controller-managed DRAM cache):
//!
//! * [`cache`] — a deterministic set-associative **writeback** cache
//!   over device DRAM: read hits serve at DRAM speed, writes to
//!   resident lines never reach the flash, dirty evictions drain
//!   through a writeback queue charged as real media writes and fed
//!   into the endpoint's DevLoad occupancy.
//! * [`admit`] — an epoch-based admission/bypass predictor with
//!   deterministic per-region reuse counters: streaming scans bypass
//!   the cache, reused lines admit.
//!
//! A zero-capacity spec builds no cache object at all, so every port
//! path stays byte-identical to the uncached code — the structural
//! guarantee behind the `cxl-cache`-at-zero-capacity determinism test.

pub mod admit;
pub mod cache;

pub use admit::{AdmissionFilter, AdmitConfig, AdmitPolicy, AdmitStats};
pub use cache::{
    CacheSpec, CacheStats, DeviceCache, Evicted, Lookup, DEV_DRAM_GBPS, WB_DRAIN_BATCH,
};
