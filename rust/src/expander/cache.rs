//! Deterministic set-associative writeback DRAM cache inside the CXL
//! endpoint (DESIGN.md §14).
//!
//! This is the controller-managed device cache that sits between the
//! endpoint's CXL controller and its media model: a read hit is served
//! from device DRAM (the cheap path the paper's two-digit-ns round-trip
//! claim depends on), a read miss admitted by the [`super::admit`]
//! predictor fetches the whole cache line from the media in one backend
//! read, and a write to a resident line is absorbed in device DRAM
//! (writeback-on-hit) instead of reaching the flash at all. Dirty
//! evictions enter a **writeback drain queue** whose backlog (a) is
//! retired against the media as real media writes by the owning port
//! and (b) feeds the endpoint's DevLoad occupancy
//! ([`crate::cxl::DevLoad::classify_with_drain`]).
//!
//! The cache is a pure deterministic state machine: no RNG, no wall
//! clock, true-LRU within each set via a monotonic stamp counter. All
//! timing charges (hit service, media fetches, writeback drains) are
//! made by the owning [`crate::rootcomplex::RootPort`], which keeps the
//! structure directly drivable by property tests.

use std::collections::VecDeque;

use crate::sim::{Time, NS};

use super::admit::{AdmissionFilter, AdmitConfig, AdmitPolicy};

/// Device-DRAM streaming bandwidth for hit-service serialization —
/// the media layer owns the single definition, so this hit path and
/// the SSD model's internal one share the same cost surface.
pub use crate::media::ssd::DEV_DRAM_GBPS;

/// Writebacks retired against the media per demand access (the drain
/// engine's opportunistic budget).
pub const WB_DRAIN_BATCH: usize = 2;

/// Device-cache geometry and policies. `capacity_bytes == 0` (or
/// `enabled == false`) means **no cache object at all** — the port's
/// paths are then byte-for-byte the pre-§14 code, which is what makes a
/// zero-capacity `cxl-cache` bit-identical to `cxl`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheSpec {
    pub enabled: bool,
    /// Total device-DRAM capacity dedicated to the cache, per endpoint.
    pub capacity_bytes: u64,
    /// Set associativity (clamped to the line count).
    pub ways: usize,
    /// Cache-line size in bytes (power of two, ≥ 64): a miss fetch
    /// installs this much with a single backend read, so it is also the
    /// cache's spatial-prefetch granule.
    pub line_bytes: u64,
    /// Device-DRAM access time (hit service).
    pub dram_lat: Time,
    /// Drain-queue depth treated as "full" for DevLoad classification
    /// (the queue itself never drops writebacks).
    pub wb_queue_cap: usize,
    pub admit: AdmitConfig,
}

impl Default for CacheSpec {
    fn default() -> CacheSpec {
        CacheSpec {
            enabled: false,
            capacity_bytes: 512 << 10,
            ways: 8,
            line_bytes: 256,
            dram_lat: 120 * NS,
            wb_queue_cap: 64,
            admit: AdmitConfig::default(),
        }
    }
}

impl CacheSpec {
    /// The `cxl-cache-bypass` ablation: same cache, admission predictor
    /// off (every miss installs).
    pub fn admit_all(mut self) -> CacheSpec {
        self.admit.policy = AdmitPolicy::AdmitAll;
        self
    }
}

/// One way of one set.
#[derive(Debug, Clone, Copy)]
struct Slot {
    /// Cache-line index (`line_base / line_bytes`); meaningful iff
    /// `valid`.
    tag: u64,
    valid: bool,
    dirty: bool,
    /// Fill completion: a hit before `ready` waits for the in-flight
    /// fetch (mirrors the SSD model's in-flight prefetch semantics).
    ready: Time,
    /// LRU stamp (monotonic per-cache counter; larger = more recent).
    stamp: u64,
}

const EMPTY_SLOT: Slot = Slot { tag: 0, valid: false, dirty: false, ready: 0, stamp: 0 };

/// Result of a cache lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lookup {
    /// Every covering line is resident; data is served once the latest
    /// in-flight fill (`ready`) lands.
    Hit { ready: Time },
    Miss,
}

/// A line pushed out by an install.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Evicted {
    /// Line base address (device-relative).
    pub addr: u64,
    pub dirty: bool,
}

/// Counters wired through `RunMetrics` (and the determinism
/// fingerprint — see `tests/determinism.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Demand lookups (loads + stores) served by the cache.
    pub hits: u64,
    /// Demand lookups that missed.
    pub misses: u64,
    /// Read misses the admission predictor refused to install.
    pub bypasses: u64,
    /// Dirty evictions enqueued for media writeback.
    pub writebacks: u64,
    pub writeback_bytes: u64,
    /// Writeback-queue depth high-water mark.
    pub wb_hwm: u64,
    /// Clean→dirty line transitions (conservation invariant:
    /// `dirtied == writebacks + dirty_dropped + dirty lines resident`).
    pub dirtied: u64,
    /// Dirty lines discarded by range invalidation (their data is
    /// subsumed by the migration copy that triggered it).
    pub dirty_dropped: u64,
    /// Queued writebacks cancelled by range invalidation before they
    /// drained (flow invariant: `writebacks == drained + pending +
    /// wb_cancelled`).
    pub wb_cancelled: u64,
    /// Lines installed by MemSpecRd prefetch (admission-exempt).
    pub prefetch_installs: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The expander-side device DRAM cache.
#[derive(Debug)]
pub struct DeviceCache {
    spec: CacheSpec,
    /// Power-of-two set count (decode by mask).
    sets: u64,
    ways: usize,
    /// `sets * ways` slots, set-major.
    slots: Vec<Slot>,
    stamp: u64,
    admit: AdmissionFilter,
    /// Dirty-eviction drain queue (line base addresses, FIFO).
    wb: VecDeque<u64>,
    pub stats: CacheStats,
}

impl DeviceCache {
    /// Build a cache, or `None` when the spec describes no cache (the
    /// structural guarantee behind the zero-capacity determinism test).
    pub fn new(spec: CacheSpec) -> Option<DeviceCache> {
        if !spec.enabled {
            return None;
        }
        debug_assert!(spec.line_bytes.is_power_of_two() && spec.line_bytes >= 64);
        let lines = spec.capacity_bytes / spec.line_bytes;
        if lines == 0 {
            return None;
        }
        let ways = spec.ways.clamp(1, lines as usize);
        // Largest power-of-two set count that fits the capacity.
        let mut sets = 1u64;
        while sets * 2 * ways as u64 <= lines {
            sets *= 2;
        }
        Some(DeviceCache {
            spec,
            sets,
            ways,
            slots: vec![EMPTY_SLOT; (sets as usize) * ways],
            stamp: 0,
            admit: AdmissionFilter::new(spec.admit),
            wb: VecDeque::new(),
            stats: CacheStats::default(),
        })
    }

    pub fn dram_lat(&self) -> Time {
        self.spec.dram_lat
    }

    pub fn line_bytes(&self) -> u64 {
        self.spec.line_bytes
    }

    pub fn wb_queue_cap(&self) -> usize {
        self.spec.wb_queue_cap
    }

    /// Total line slots (capacity rounded to the set grid).
    pub fn capacity_lines(&self) -> u64 {
        self.sets * self.ways as u64
    }

    fn line_of(&self, addr: u64) -> u64 {
        addr / self.spec.line_bytes
    }

    /// Line-aligned covering span of `[addr, addr + len)`.
    pub fn span(&self, addr: u64, len: u64) -> (u64, u64) {
        let lb = self.spec.line_bytes;
        let base = addr / lb * lb;
        let end = (addr + len.max(1)).div_ceil(lb) * lb;
        (base, end - base)
    }

    fn set_range(&self, line: u64) -> (usize, usize) {
        let set = (line & (self.sets - 1)) as usize;
        (set * self.ways, set * self.ways + self.ways)
    }

    fn find(&self, line: u64) -> Option<usize> {
        let (lo, hi) = self.set_range(line);
        (lo..hi).find(|&i| self.slots[i].valid && self.slots[i].tag == line)
    }

    fn touch(&mut self, idx: usize) {
        self.stamp += 1;
        self.slots[idx].stamp = self.stamp;
    }

    /// Demand lookup of `[addr, addr + len)`. A hit requires every
    /// covering line resident; hits refresh LRU and (for writes) dirty
    /// the lines. Exactly one of `hits`/`misses` increments per call.
    pub fn lookup(&mut self, now: Time, addr: u64, len: u64, is_write: bool) -> Lookup {
        let first = self.line_of(addr);
        let last = self.line_of(addr + len.max(1) - 1);
        // Pass 1: residency (no state change on a miss, so a bypassed
        // miss leaves the cache untouched).
        for line in first..=last {
            if self.find(line).is_none() {
                self.stats.misses += 1;
                return Lookup::Miss;
            }
        }
        let mut ready = 0;
        for line in first..=last {
            let idx = self.find(line).expect("checked resident above");
            ready = ready.max(self.slots[idx].ready);
            if is_write && !self.slots[idx].dirty {
                self.slots[idx].dirty = true;
                self.stats.dirtied += 1;
            }
            self.touch(idx);
        }
        self.stats.hits += 1;
        self.admit.on_hit(addr, now);
        Lookup::Hit { ready }
    }

    /// Admission decision for the read miss at `addr`; a refusal is a
    /// counted bypass.
    pub fn should_admit(&mut self, addr: u64, now: Time) -> bool {
        if self.admit.should_admit(addr, now) {
            true
        } else {
            self.stats.bypasses += 1;
            false
        }
    }

    /// Install one line; returns the pushed-out victim, if any. Dirty
    /// victims are queued for media writeback.
    pub fn install_line(&mut self, addr: u64, ready: Time, dirty: bool) -> Option<Evicted> {
        let line = self.line_of(addr);
        if let Some(idx) = self.find(line) {
            // Refresh in place (e.g. prefetch racing a demand install).
            // The earliest fill wins: a redundant refetch of a line whose
            // data is already (or sooner) available must never push its
            // readiness into the future.
            let s = &mut self.slots[idx];
            s.ready = s.ready.min(ready);
            if dirty && !s.dirty {
                s.dirty = true;
                self.stats.dirtied += 1;
            }
            self.touch(idx);
            return None;
        }
        let (lo, hi) = self.set_range(line);
        // Victim: an invalid way, else the smallest stamp (true LRU).
        let victim = (lo..hi)
            .find(|&i| !self.slots[i].valid)
            .unwrap_or_else(|| {
                (lo..hi)
                    .min_by_key(|&i| self.slots[i].stamp)
                    .expect("ways >= 1")
            });
        let evicted = if self.slots[victim].valid {
            let v = self.slots[victim];
            let v_addr = v.tag * self.spec.line_bytes;
            if v.dirty {
                self.wb.push_back(v_addr);
                self.stats.writebacks += 1;
                self.stats.writeback_bytes += self.spec.line_bytes;
                self.stats.wb_hwm = self.stats.wb_hwm.max(self.wb.len() as u64);
            }
            Some(Evicted { addr: v_addr, dirty: v.dirty })
        } else {
            None
        };
        self.stamp += 1;
        self.slots[victim] =
            Slot { tag: line, valid: true, dirty, ready, stamp: self.stamp };
        if dirty {
            self.stats.dirtied += 1;
        }
        evicted
    }

    /// Install every line covering `[addr, addr + len)` (a miss fetch or
    /// a MemSpecRd window), all becoming ready at `ready`.
    pub fn install(&mut self, addr: u64, len: u64, ready: Time, dirty: bool) {
        let (base, span) = self.span(addr, len);
        let mut a = base;
        while a < base + span {
            self.install_line(a, ready, dirty);
            a += self.spec.line_bytes;
        }
    }

    /// Admission-exempt prefetch install (SR windows carry their own
    /// DevLoad-driven rate control).
    pub fn prefetch_install(&mut self, addr: u64, len: u64, ready: Time) {
        let (base, span) = self.span(addr, len);
        let mut a = base;
        while a < base + span {
            if self.find(self.line_of(a)).is_none() {
                self.stats.prefetch_installs += 1;
            }
            self.install_line(a, ready, false);
            a += self.spec.line_bytes;
        }
    }

    /// Is the whole span device-resident? Read-only probe (no LRU
    /// refresh, no stats) — the SR reader uses it to suppress hints for
    /// already-cached windows.
    pub fn contains_span(&self, addr: u64, len: u64) -> bool {
        let first = self.line_of(addr);
        let last = self.line_of(addr + len.max(1) - 1);
        (first..=last).all(|line| self.find(line).is_some())
    }

    /// Next queued writeback to retire against the media (FIFO).
    pub fn pop_writeback(&mut self) -> Option<u64> {
        self.wb.pop_front()
    }

    /// Writebacks still queued (the DevLoad drain-pressure input).
    pub fn wb_pending(&self) -> usize {
        self.wb.len()
    }

    /// Drop the lines covering `[addr, addr + len)` by direct set probe
    /// — O(covering lines × ways), cheap enough for the tiering
    /// engine's per-chunk calls (≤ a page per chunk). The invalidating
    /// writer (the migration copy) owns the newest bytes for the whole
    /// range, so dirty residents are dropped, not written back — and
    /// writebacks already queued for the range are cancelled for the
    /// same reason: draining them would model stale bytes overwriting
    /// the freshly-migrated page.
    pub fn invalidate_span(&mut self, addr: u64, len: u64) {
        let first = self.line_of(addr);
        let last = self.line_of(addr + len.max(1) - 1);
        for line in first..=last {
            if let Some(idx) = self.find(line) {
                if self.slots[idx].dirty {
                    self.stats.dirty_dropped += 1;
                }
                self.slots[idx].valid = false;
                self.slots[idx].dirty = false;
            }
        }
        let lo = first * self.spec.line_bytes;
        let hi = (last + 1) * self.spec.line_bytes;
        let before = self.wb.len();
        self.wb.retain(|&a| a < lo || a >= hi);
        self.stats.wb_cancelled += (before - self.wb.len()) as u64;
    }

    /// Reconcile resident lines with a write-through store of
    /// `[addr, addr + len)` that missed the cache. Lines the store
    /// overwrites *fully* are superseded (dropped — the flash now holds
    /// newer bytes for their whole extent); a *partially* covered
    /// resident line keeps the freshest bytes for its uncovered portion
    /// in device DRAM, so it is dirtied and stays resident instead of
    /// being dropped. (Unreachable for today's 64 B stores — a single
    /// covering line that is resident is a write hit — but the port API
    /// accepts arbitrary spans.)
    pub fn on_write_through(&mut self, addr: u64, len: u64) {
        let lb = self.spec.line_bytes;
        let first = self.line_of(addr);
        let last = self.line_of(addr + len.max(1) - 1);
        for line in first..=last {
            let base = line * lb;
            let fully = addr <= base && base + lb <= addr + len.max(1);
            if fully {
                self.invalidate_span(base, lb);
            } else if let Some(idx) = self.find(line) {
                if !self.slots[idx].dirty {
                    self.slots[idx].dirty = true;
                    self.stats.dirtied += 1;
                }
                self.touch(idx);
            }
        }
    }

    /// Pre-degradation rescue drain (DESIGN.md §15): flush *every* dirty
    /// byte out of the cache before its endpoint is marked degraded —
    /// both the already-queued writebacks and the still-resident dirty
    /// lines — so no dirty byte is lost when the device stops being
    /// trustworthy. Returns the line base addresses to retire against
    /// the media, oldest-queued first, then residents in address order
    /// (deterministic). Resident flushes count as writebacks (they are
    /// exactly that, just drained eagerly), which keeps both
    /// conservation invariants intact:
    /// `dirtied == writebacks + dirty_dropped + dirty_lines()` and
    /// `writebacks == drained + pending + wb_cancelled`. Post-state:
    /// `dirty_lines() == 0`, `wb_pending() == 0`; clean residents stay
    /// (reads may still be served from device DRAM).
    pub fn drain_all_dirty(&mut self) -> Vec<u64> {
        let mut out: Vec<u64> = self.wb.drain(..).collect();
        let flush_from = out.len();
        for s in &mut self.slots {
            if s.valid && s.dirty {
                s.dirty = false;
                out.push(s.tag * self.spec.line_bytes);
                self.stats.writebacks += 1;
                self.stats.writeback_bytes += self.spec.line_bytes;
            }
        }
        out[flush_from..].sort_unstable();
        out
    }

    /// Resident line count.
    pub fn lines(&self) -> u64 {
        self.slots.iter().filter(|s| s.valid).count() as u64
    }

    /// Resident dirty-line count (conservation checks).
    pub fn dirty_lines(&self) -> u64 {
        self.slots.iter().filter(|s| s.valid && s.dirty).count() as u64
    }

    /// Admission-predictor epoch count (telemetry).
    pub fn admit_epochs(&self) -> u64 {
        self.admit.stats.epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(capacity: u64, ways: usize) -> DeviceCache {
        DeviceCache::new(CacheSpec {
            enabled: true,
            capacity_bytes: capacity,
            ways,
            ..CacheSpec::default()
        })
        .expect("nonzero capacity")
    }

    #[test]
    fn zero_capacity_or_disabled_builds_nothing() {
        assert!(DeviceCache::new(CacheSpec::default()).is_none(), "disabled");
        let z = CacheSpec { enabled: true, capacity_bytes: 0, ..CacheSpec::default() };
        assert!(DeviceCache::new(z).is_none(), "zero capacity");
    }

    #[test]
    fn geometry_is_power_of_two_sets() {
        let c = cache(512 << 10, 8);
        assert_eq!(c.capacity_lines(), 2048);
        assert_eq!(c.sets, 256);
        // Capacity that doesn't divide evenly rounds down, never up.
        let c = cache(300 << 10, 8);
        assert!(c.capacity_lines() * c.line_bytes() <= 300 << 10);
    }

    #[test]
    fn miss_then_install_then_hit() {
        let mut c = cache(64 << 10, 4);
        assert_eq!(c.lookup(0, 0x1000, 64, false), Lookup::Miss);
        c.install(0x1000, 64, 500, false);
        match c.lookup(1000, 0x1000, 64, false) {
            Lookup::Hit { ready } => assert_eq!(ready, 500),
            Lookup::Miss => panic!("installed line must hit"),
        }
        // The whole 256 B line came in with the fetch.
        assert!(matches!(c.lookup(1000, 0x10c0, 64, false), Lookup::Hit { .. }));
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn write_hit_dirties_and_eviction_queues_writeback() {
        let mut c = cache(4 << 10, 1); // 16 direct-mapped 256B lines
        c.install(0x0, 64, 0, false);
        assert!(matches!(c.lookup(0, 0x0, 64, true), Lookup::Hit { .. }));
        assert_eq!(c.stats.dirtied, 1);
        assert_eq!(c.dirty_lines(), 1);
        // Conflict-evict line 0 (same set: 16 sets, line 16 maps to set 0).
        let conflict = 16 * 256;
        c.install(conflict, 64, 0, false);
        assert_eq!(c.stats.writebacks, 1);
        assert_eq!(c.pop_writeback(), Some(0));
        assert_eq!(c.wb_pending(), 0);
        assert_eq!(c.stats.writeback_bytes, 256);
    }

    #[test]
    fn lru_evicts_least_recent_way() {
        let mut c = cache(2 << 10, 8); // one set of 8 ways
        assert_eq!(c.sets, 1);
        for i in 0..8u64 {
            c.install_line(i * 256, 0, false);
        }
        // Touch line 0 so line 1 becomes LRU.
        assert!(matches!(c.lookup(0, 0, 64, false), Lookup::Hit { .. }));
        let ev = c.install_line(8 * 256, 0, false).expect("full set evicts");
        assert_eq!(ev.addr, 256, "line 1 was least recently used");
        assert!(!ev.dirty);
    }

    #[test]
    fn invalidate_span_drops_dirty_without_writeback() {
        let mut c = cache(4 << 10, 4);
        c.install(0x2000, 256, 0, true);
        assert_eq!(c.dirty_lines(), 1);
        c.invalidate_span(0x2000, 0x1000);
        assert_eq!(c.dirty_lines(), 0);
        assert_eq!(c.lines(), 0);
        assert_eq!(c.stats.dirty_dropped, 1);
        assert_eq!(c.wb_pending(), 0, "invalidation is not a writeback");
        assert_eq!(c.lookup(0, 0x2000, 64, false), Lookup::Miss);
    }

    #[test]
    fn contains_span_is_side_effect_free() {
        let mut c = cache(4 << 10, 4);
        c.install(0x400, 512, 0, false);
        let (h, m) = (c.stats.hits, c.stats.misses);
        assert!(c.contains_span(0x400, 512));
        assert!(!c.contains_span(0x400, 1024));
        assert_eq!((c.stats.hits, c.stats.misses), (h, m));
    }

    #[test]
    fn in_flight_fill_gates_hit_readiness() {
        let mut c = cache(4 << 10, 4);
        c.prefetch_install(0x800, 512, 9_000);
        match c.lookup(100, 0x900, 64, false) {
            Lookup::Hit { ready } => assert_eq!(ready, 9_000, "hit waits for the fill"),
            Lookup::Miss => panic!("prefetched span must hit"),
        }
        assert_eq!(c.stats.prefetch_installs, 2);
    }
}
