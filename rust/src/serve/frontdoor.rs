//! Serving front door: bounded admission queue, token-bucket admission
//! control, per-request deadlines with bounded timeout-and-retry, and
//! load shedding that drops oldest-beyond-deadline work first.
//!
//! The front door sits between the open-loop arrival process
//! ([`super::arrivals::ArrivalGen`]) and the warp scheduler. Each
//! admitted request expands into a short burst of warp work — a
//! weight-read phase of loads followed by a KV-append phase of stores,
//! generated from the existing workload [`Pattern`]s — so service time
//! is charged through the real SR/DS/cache/tiering/pool path, not a
//! synthetic service-time distribution.
//!
//! Request lifecycle (DESIGN.md §16):
//!
//! ```text
//! arrival ──token bucket──▶ admitted ──queue──▶ dispatched ──▶ completed
//!     │ no token                │ cap reached       │ expired
//!     ▼                         ▼                   ▼
//!  rejected                   shed          retried (≤ max) / timed_out
//! ```
//!
//! Overload therefore degrades by design: excess work exits through the
//! `rejected`/`shed`/`timed_out` counters while the queue stays bounded,
//! instead of collapsing into unbounded queue growth.

use std::collections::VecDeque;

use crate::gpu::warp::Op;
use crate::sim::{Time, MS};
use crate::util::prng::Pcg32;
use crate::workloads::patterns::{Pattern, PatternKind};

use super::arrivals::{ArrivalGen, ArrivalKind, PS_PER_SEC};

/// PCG stream id for request expansion (addresses of the weight-read and
/// KV-append phases). Distinct from the arrival stream so reordering
/// dispatches cannot perturb arrival times.
pub const EXPAND_STREAM: u64 = 0x5E4E;

/// Serving-layer configuration. `Default` is inert: a config carrying a
/// default `ServeSpec` builds no front door and is bit-identical to the
/// same config without one (the determinism suite pins this).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeSpec {
    /// Master switch; `false` (default) leaves the system closed-loop.
    pub enabled: bool,
    /// Arrival process.
    pub kind: ArrivalKind,
    /// Offered load in requests per second; `<= 0` is inert.
    pub rate_rps: f64,
    /// Total requests to emit; `0` derives `total_ops / ops-per-request`
    /// so serve runs consume the same op budget as closed-loop runs.
    pub requests: u64,
    /// Bounded admission-queue capacity (requests beyond it shed work).
    pub queue_cap: usize,
    /// Per-request deadline (SLO) measured from arrival.
    pub slo: Time,
    /// Retries granted to a request found expired at dispatch time.
    pub max_retries: u32,
    /// Token-bucket refill rate in requests per second; `<= 0` disables
    /// the bucket (every arrival is admitted to the queue).
    pub bucket_rps: f64,
    /// Token-bucket burst capacity.
    pub bucket_burst: f64,
    /// Weight-read phase: loads per request.
    pub weight_loads: u32,
    /// KV-append phase: stores per request.
    pub kv_stores: u32,
    /// Address pattern both phases draw from.
    pub pattern: PatternKind,
}

impl Default for ServeSpec {
    fn default() -> ServeSpec {
        ServeSpec {
            enabled: false,
            kind: ArrivalKind::Poisson,
            rate_rps: 0.0,
            requests: 0,
            queue_cap: 64,
            slo: MS,
            max_retries: 2,
            bucket_rps: 0.0,
            bucket_burst: 32.0,
            // 64 weight reads + 16 KV appends ≈ a decode step touching
            // 4 KiB of weights and 1 KiB of KV cache per request.
            weight_loads: 64,
            kv_stores: 16,
            pattern: PatternKind::HotCold { hot_permille: 850, hot_pages: 64 },
        }
    }
}

impl ServeSpec {
    /// The armed spec the `cxl-serve` configs carry: Poisson arrivals at
    /// a rate comfortably below the DDR5 expander knee, 1 ms SLO.
    pub fn representative() -> ServeSpec {
        ServeSpec { enabled: true, rate_rps: 200_000.0, ..ServeSpec::default() }
    }

    /// True when the spec cannot generate any request: disabled, zero
    /// rate, or requests that would expand to zero ops. An inert spec
    /// builds no [`FrontDoor`], so the run is bit-identical to the same
    /// config with serving absent.
    pub fn is_inert(&self) -> bool {
        !self.enabled || self.rate_rps <= 0.0 || self.weight_loads + self.kv_stores == 0
    }
}

/// Counters the coordinator copies into `RunMetrics` (all fingerprinted).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Open-loop arrivals generated.
    pub arrivals: u64,
    /// Arrivals that passed the token bucket.
    pub admitted: u64,
    /// Arrivals refused by the token bucket.
    pub rejected: u64,
    /// Queued requests dropped to make room (oldest-beyond-deadline
    /// first, then oldest).
    pub shed: u64,
    /// Requests abandoned after exhausting their retry budget.
    pub timed_out: u64,
    /// Deadline extensions granted (a request can contribute several).
    pub retried: u64,
    /// Requests whose warp work ran to completion.
    pub completed: u64,
    /// Completions that beat their (possibly extended) deadline.
    pub completed_in_slo: u64,
    /// Admission-queue high-water mark.
    pub queue_hwm: u64,
}

/// One admitted request waiting for, or occupying, a warp.
#[derive(Debug, Clone, Copy)]
struct Pending {
    arrived: Time,
    deadline: Time,
    retries: u32,
}

/// The serving front door (see module docs for the state machine).
#[derive(Debug)]
pub struct FrontDoor {
    spec: ServeSpec,
    gen: ArrivalGen,
    rng: Pcg32,
    /// Per-warp address generators: requests dispatched to warp `w` draw
    /// from `pats[w]`, so the fleet covers the footprint the same way a
    /// closed-loop run's warps do.
    pats: Vec<Pattern>,
    queue: VecDeque<Pending>,
    /// `running[w]` is the request currently occupying warp `w`.
    running: Vec<Option<Pending>>,
    tokens: f64,
    last_refill: Time,
    /// Requests the run will emit / has emitted.
    total: u64,
    emitted: u64,
    in_flight: usize,
    pub stats: ServeStats,
}

impl FrontDoor {
    /// Build the front door, or `None` when the spec is inert (the
    /// coordinator then takes the exact closed-loop code path).
    pub fn new(
        spec: &ServeSpec,
        footprint: u64,
        warps: usize,
        total_ops: u64,
        seed: u64,
    ) -> Option<FrontDoor> {
        if spec.is_inert() {
            return None;
        }
        assert!(warps > 0, "serve needs at least one warp");
        let mut rng = Pcg32::new(seed, EXPAND_STREAM);
        let pats = (0..warps)
            .map(|w| Pattern::new(spec.pattern, footprint, w, warps, &mut rng))
            .collect();
        let ops_per_req = (spec.weight_loads + spec.kv_stores) as u64;
        let total =
            if spec.requests > 0 { spec.requests } else { (total_ops / ops_per_req).max(1) };
        Some(FrontDoor {
            spec: *spec,
            gen: ArrivalGen::new(spec.kind, spec.rate_rps, seed),
            rng,
            pats,
            queue: VecDeque::new(),
            running: (0..warps).map(|_| None).collect(),
            tokens: spec.bucket_burst,
            last_refill: 0,
            total,
            emitted: 0,
            in_flight: 0,
            stats: ServeStats::default(),
        })
    }

    /// Gap to the first arrival (the coordinator schedules the first
    /// `RequestArrival` event at this offset).
    pub fn first_gap(&mut self) -> Time {
        self.gen.next_gap(0)
    }

    /// Process one arrival at `now`. Dispatched work is appended to
    /// `out` as `(warp, ops)` pairs; returns the gap to the next arrival
    /// or `None` once the emission budget is spent.
    pub fn on_arrival(&mut self, now: Time, out: &mut Vec<(usize, VecDeque<Op>)>) -> Option<Time> {
        self.emitted += 1;
        self.stats.arrivals += 1;
        if self.take_token(now) {
            self.stats.admitted += 1;
            if self.queue.len() >= self.spec.queue_cap.max(1) {
                // Shed the oldest request already past its deadline —
                // it is the least likely to still produce goodput. If
                // none has expired yet, shed the oldest outright.
                let victim =
                    self.queue.iter().position(|p| p.deadline < now).unwrap_or(0);
                self.queue.remove(victim);
                self.stats.shed += 1;
            }
            self.queue.push_back(Pending {
                arrived: now,
                deadline: now + self.spec.slo,
                retries: 0,
            });
            self.stats.queue_hwm = self.stats.queue_hwm.max(self.queue.len() as u64);
            self.dispatch(now, out);
        } else {
            self.stats.rejected += 1;
        }
        if self.emitted < self.total {
            Some(self.gen.next_gap(now))
        } else {
            None
        }
    }

    /// Token-bucket admission check. A disabled bucket admits everything.
    fn take_token(&mut self, now: Time) -> bool {
        if self.spec.bucket_rps <= 0.0 {
            return true;
        }
        let dt = now.saturating_sub(self.last_refill) as f64;
        self.last_refill = now;
        self.tokens =
            (self.tokens + dt * self.spec.bucket_rps / PS_PER_SEC).min(self.spec.bucket_burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Move queued requests onto idle warps. A request found expired at
    /// dispatch gets a retry with an exponentially-backed-off deadline
    /// (the §15 RAS timeout idiom: `slo << retries`) until its retry
    /// budget runs out, then counts as timed out.
    fn dispatch(&mut self, now: Time, out: &mut Vec<(usize, VecDeque<Op>)>) {
        while !self.queue.is_empty() {
            let Some(w) = self.running.iter().position(|r| r.is_none()) else { return };
            let mut p = self.queue.pop_front().expect("queue non-empty");
            if p.deadline < now {
                if p.retries < self.spec.max_retries {
                    p.retries += 1;
                    p.deadline = now + (self.spec.slo << p.retries.min(20));
                    self.stats.retried += 1;
                    self.queue.push_back(p);
                    continue;
                }
                self.stats.timed_out += 1;
                continue;
            }
            let ops = self.expand(w);
            self.running[w] = Some(p);
            self.in_flight += 1;
            out.push((w, ops));
        }
    }

    /// Expand a request into warp work: the weight-read loads, then the
    /// KV-append stores.
    fn expand(&mut self, w: usize) -> VecDeque<Op> {
        let n = (self.spec.weight_loads + self.spec.kv_stores) as usize;
        let mut ops = VecDeque::with_capacity(n);
        for _ in 0..self.spec.weight_loads {
            ops.push_back(Op::Load { addr: self.pats[w].next_load(&mut self.rng) });
        }
        for _ in 0..self.spec.kv_stores {
            ops.push_back(Op::Store { addr: self.pats[w].next_store(&mut self.rng) });
        }
        ops
    }

    /// Warp `warp` finished its request's ops: record the completion and
    /// backfill idle warps from the queue. Returns `(arrived, deadline)`
    /// of the completed request so the caller can charge end-to-end
    /// latency, or `None` if the warp held no request (stale wakeup).
    pub fn on_warp_drained(
        &mut self,
        now: Time,
        warp: usize,
        out: &mut Vec<(usize, VecDeque<Op>)>,
    ) -> Option<(Time, Time)> {
        let p = self.running[warp].take()?;
        self.in_flight -= 1;
        self.stats.completed += 1;
        if now <= p.deadline {
            self.stats.completed_in_slo += 1;
        }
        self.dispatch(now, out);
        Some((p.arrived, p.deadline))
    }

    /// All requests emitted and none queued or in flight: the run is
    /// over (the coordinator retires the remaining idle warps).
    pub fn drained(&self) -> bool {
        self.emitted >= self.total && self.queue.is_empty() && self.in_flight == 0
    }

    /// Requests currently occupying warps.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Requests currently queued.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::US;

    fn armed(rate: f64) -> ServeSpec {
        ServeSpec { enabled: true, rate_rps: rate, ..ServeSpec::default() }
    }

    fn door(spec: &ServeSpec, warps: usize) -> FrontDoor {
        FrontDoor::new(spec, 32 << 20, warps, 300_000, 0xC11A).expect("armed spec")
    }

    #[test]
    fn inert_specs_build_no_front_door() {
        let fp = 32 << 20;
        assert!(FrontDoor::new(&ServeSpec::default(), fp, 4, 1000, 1).is_none());
        let zero_rate = ServeSpec { rate_rps: 0.0, ..armed(1.0) };
        assert!(FrontDoor::new(&zero_rate, fp, 4, 1000, 1).is_none());
        let no_ops = ServeSpec { weight_loads: 0, kv_stores: 0, ..armed(1e6) };
        assert!(FrontDoor::new(&no_ops, fp, 4, 1000, 1).is_none());
        assert!(FrontDoor::new(&armed(1e6), fp, 4, 1000, 1).is_some());
    }

    #[test]
    fn request_budget_derives_from_total_ops() {
        // 300k ops / 80 ops-per-request = 3750 requests.
        let fd = door(&armed(1e6), 4);
        assert_eq!(fd.total, 3750);
        let pinned = ServeSpec { requests: 17, ..armed(1e6) };
        assert_eq!(door(&pinned, 4).total, 17);
    }

    #[test]
    fn arrivals_replay_bit_for_bit() {
        let spec = armed(5e5);
        let (mut a, mut b) = (door(&spec, 2), door(&spec, 2));
        let mut out = Vec::new();
        let (mut ta, mut tb) = (a.first_gap(), b.first_gap());
        assert_eq!(ta, tb);
        for _ in 0..200 {
            let ga = a.on_arrival(ta, &mut out);
            out.clear();
            let gb = b.on_arrival(tb, &mut out);
            out.clear();
            assert_eq!(ga, gb);
            match ga {
                Some(g) => {
                    ta += g;
                    tb += g;
                }
                None => break,
            }
        }
        assert_eq!(a.stats.arrivals, b.stats.arrivals);
    }

    #[test]
    fn dispatch_fills_idle_warps_and_expands_both_phases() {
        let mut fd = door(&armed(1e6), 2);
        let mut out = Vec::new();
        fd.on_arrival(10, &mut out);
        assert_eq!(out.len(), 1);
        let (w, ops) = &out[0];
        assert_eq!(*w, 0);
        assert_eq!(ops.len(), (64 + 16) as usize);
        let loads = ops.iter().filter(|o| matches!(o, Op::Load { .. })).count();
        assert_eq!(loads, 64, "weight-read phase first");
        assert!(matches!(ops[79], Op::Store { .. }), "KV-append phase last");
        assert_eq!(fd.in_flight(), 1);
        // Second and third arrivals: warp 1, then queued (no idle warp).
        out.clear();
        fd.on_arrival(20, &mut out);
        assert_eq!(out[0].0, 1);
        out.clear();
        fd.on_arrival(30, &mut out);
        assert!(out.is_empty());
        assert_eq!(fd.queued(), 1);
    }

    #[test]
    fn completion_backfills_from_the_queue_and_reports_latency_pair() {
        let mut fd = door(&armed(1e6), 1);
        let mut out = Vec::new();
        fd.on_arrival(10, &mut out);
        out.clear();
        fd.on_arrival(20, &mut out);
        assert!(out.is_empty());
        let (arrived, deadline) = fd.on_warp_drained(500, 0, &mut out).expect("held a request");
        assert_eq!(arrived, 10);
        assert_eq!(deadline, 10 + MS);
        assert_eq!(out.len(), 1, "queued request backfills the warp");
        assert_eq!(fd.stats.completed, 1);
        assert_eq!(fd.stats.completed_in_slo, 1);
        // Stale wakeup on an idle warp is a no-op.
        out.clear();
        fd.on_warp_drained(600, 0, &mut out);
        assert!(fd.on_warp_drained(700, 0, &mut out).is_none());
        assert_eq!(fd.stats.completed, 2);
    }

    #[test]
    fn full_queue_sheds_expired_first_then_oldest() {
        let spec = ServeSpec { queue_cap: 2, slo: 100 * US, ..armed(1e6) };
        let mut fd = door(&spec, 1);
        let mut out = Vec::new();
        fd.on_arrival(0, &mut out); // occupies the only warp
        fd.on_arrival(1, &mut out); // queued, deadline 1 + 100µs
        fd.on_arrival(2, &mut out); // queued, deadline 2 + 100µs
        out.clear();
        // Queue full; the queued entries are now expired → shed the
        // oldest expired one each time.
        fd.on_arrival(200 * US, &mut out);
        assert_eq!(fd.stats.shed, 1);
        assert_eq!(fd.queued(), 2);
        fd.on_arrival(200 * US + 10, &mut out);
        assert_eq!(fd.stats.shed, 2);
        assert_eq!(fd.queued(), 2);
        // Queue now holds only fresh entries; nothing expired → the
        // oldest goes outright.
        fd.on_arrival(200 * US + 20, &mut out);
        assert_eq!(fd.stats.shed, 3);
        assert_eq!(fd.queued(), 2);
    }

    #[test]
    fn expired_dispatch_retries_with_backoff_then_times_out() {
        let spec =
            ServeSpec { queue_cap: 64, slo: 10 * US, max_retries: 1, ..armed(1e6) };
        let mut fd = door(&spec, 1);
        let mut out = Vec::new();
        fd.on_arrival(0, &mut out); // A occupies the only warp
        fd.on_arrival(1, &mut out); // B queued, deadline 1 + 10 µs
        fd.on_arrival(2, &mut out); // C queued, deadline 2 + 10 µs
        out.clear();
        // A drains long after both queued deadlines: B and C each get
        // their retry (deadline now + slo<<1); B takes the freed warp, C
        // stays queued behind it.
        let drain = 50 * US;
        fd.on_warp_drained(drain, 0, &mut out);
        assert_eq!(fd.stats.retried, 2);
        assert_eq!(out.len(), 1, "retried request redispatches");
        assert_eq!(fd.running[0].expect("occupied").deadline, drain + (10 * US << 1));
        assert_eq!(fd.queued(), 1);
        // B drains past C's extended deadline too; C's retry budget is
        // spent, so it dies instead of dispatching.
        out.clear();
        fd.on_warp_drained(drain + 500 * US, 0, &mut out);
        assert_eq!(fd.stats.timed_out, 1);
        assert!(out.is_empty());
        assert_eq!(fd.queued(), 0);
    }

    #[test]
    fn token_bucket_rejects_past_burst_and_refills_over_time() {
        let spec = ServeSpec {
            bucket_rps: 1e6, // one token per µs
            bucket_burst: 2.0,
            queue_cap: 1024,
            ..armed(1e6)
        };
        let mut fd = door(&spec, 1);
        let mut out = Vec::new();
        // Burst of 4 at t≈0: two tokens, then rejections.
        for t in 0..4 {
            fd.on_arrival(t, &mut out);
        }
        assert_eq!(fd.stats.admitted, 2);
        assert_eq!(fd.stats.rejected, 2);
        // 3 µs later the bucket refilled (capped at burst=2): admits again.
        fd.on_arrival(3 * US, &mut out);
        assert_eq!(fd.stats.admitted, 3);
    }

    #[test]
    fn conservation_holds_under_synthetic_overload() {
        // One slow warp, high rate, tight queue: most work sheds or
        // times out, and the books must still balance.
        let spec = ServeSpec {
            queue_cap: 4,
            slo: 50 * US,
            max_retries: 1,
            ..armed(2e6)
        };
        let mut fd = door(&spec, 2);
        let mut out = Vec::new();
        let mut now = fd.first_gap();
        let mut drain_at = 100 * US; // a warp drains every 100 µs
        for _ in 0..5_000 {
            if now >= drain_at {
                let w = (drain_at / (100 * US)) as usize % 2;
                fd.on_warp_drained(drain_at, w, &mut out);
                out.clear();
                drain_at += 100 * US;
            }
            let Some(gap) = fd.on_arrival(now, &mut out) else { break };
            out.clear();
            now += gap;
        }
        let s = &fd.stats;
        assert_eq!(s.arrivals, s.admitted + s.rejected);
        assert_eq!(
            s.admitted,
            s.completed
                + s.shed
                + s.timed_out
                + fd.in_flight() as u64
                + fd.queued() as u64,
            "conservation: {s:?} in_flight={} queued={}",
            fd.in_flight(),
            fd.queued()
        );
        assert!(s.shed + s.timed_out > 0, "overload must shed or time out");
        assert!(s.queue_hwm <= 4);
    }
}
