//! Online serving layer (DESIGN.md §16): open-loop arrival processes
//! plus an admission-controlled front door that turns requests into warp
//! work, so every config can be asked "what request rate do you sustain
//! at an SLO, and how do you fail past it?".

pub mod arrivals;
pub mod frontdoor;

pub use arrivals::{ArrivalGen, ArrivalKind};
pub use frontdoor::{FrontDoor, ServeSpec, ServeStats};
