//! Deterministic open-loop arrival processes for the serving front door.
//!
//! Open-loop means the generator decides inter-arrival gaps independently
//! of service state — requests keep landing whether or not the backend
//! keeps up, which is what exposes the knee and the overload regime
//! (closed-loop clients self-throttle and can never push past saturation).
//!
//! Three processes cover the shapes serving traffic actually takes:
//! Poisson (memoryless steady state), MMPP bursts (a two-state Markov-
//! modulated Poisson process — flash crowds), and a diurnal ramp (slow
//! rate swing across the run). All draws come from a private [`Pcg32`]
//! stream, so arrival sequences are bit-replayable from the seed and
//! adding serving to a config cannot perturb any other subsystem's RNG.

use crate::sim::Time;
use crate::util::prng::Pcg32;

/// Picoseconds per second: converts requests/s to a mean gap in sim time.
pub const PS_PER_SEC: f64 = 1e12;

/// PCG stream id for arrival draws (distinct from the system stream
/// `0xD15C` and the RAS stream `0xFA17`).
pub const ARRIVAL_STREAM: u64 = 0x5EAF;

/// Arrival process taxonomy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless: i.i.d. exponential gaps at the configured mean rate.
    Poisson,
    /// Two-state Markov-modulated Poisson process: a quiet state at the
    /// base rate and a burst state at `burst_mult` times it. State flips
    /// are evaluated once per arrival: quiet enters the burst with
    /// probability `enter`, the burst exits with probability `exit`, so
    /// bursts last `1/exit` arrivals on average. Bursts ride *on top of*
    /// the base rate — the long-run mean rate is above the configured
    /// one, which is the point: the knee must survive flash crowds.
    Mmpp { burst_mult: f64, enter: f64, exit: f64 },
    /// Diurnal ramp: the rate is modulated by a triangle wave of the
    /// given `period`, swinging by `±amp` around the base rate (floored
    /// at 5 % so the trough never stalls the run). A triangle (not a
    /// sinusoid) keeps the modulation pure arithmetic — bit-identical
    /// across platforms, where `sin` would be at libm's mercy.
    Diurnal { amp: f64, period: Time },
}

/// Stateful gap generator for one arrival process.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    kind: ArrivalKind,
    /// Mean inter-arrival gap at the base rate, in picoseconds.
    mean_gap: f64,
    rng: Pcg32,
    /// MMPP state: currently inside a burst.
    burst: bool,
}

impl ArrivalGen {
    /// Generator for `rate_rps` requests per second (must be > 0).
    pub fn new(kind: ArrivalKind, rate_rps: f64, seed: u64) -> ArrivalGen {
        assert!(rate_rps > 0.0, "arrival rate must be positive, got {rate_rps}");
        ArrivalGen {
            kind,
            mean_gap: PS_PER_SEC / rate_rps,
            rng: Pcg32::new(seed, ARRIVAL_STREAM),
            burst: false,
        }
    }

    /// Draw the gap to the next arrival, given the current sim time (the
    /// diurnal process needs `now` to locate itself on the wave). Gaps
    /// are clamped to ≥ 1 ps so consecutive arrivals always advance time.
    pub fn next_gap(&mut self, now: Time) -> Time {
        let mean = match self.kind {
            ArrivalKind::Poisson => self.mean_gap,
            ArrivalKind::Mmpp { burst_mult, enter, exit } => {
                if self.burst {
                    if self.rng.chance(exit) {
                        self.burst = false;
                    }
                } else if self.rng.chance(enter) {
                    self.burst = true;
                }
                if self.burst {
                    self.mean_gap / burst_mult.max(1.0)
                } else {
                    self.mean_gap
                }
            }
            ArrivalKind::Diurnal { amp, period } => {
                debug_assert!(period > 0);
                let phase = (now % period) as f64 / period as f64;
                // Triangle in [-1, 1]: peak at phase 0.5, trough at 0/1.
                let tri = 1.0 - 4.0 * (phase - 0.5).abs();
                self.mean_gap / (1.0 + amp * tri).max(0.05)
            }
        };
        (self.rng.exponential(mean) as u64).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MS, US};

    #[test]
    fn poisson_gaps_replay_bit_for_bit() {
        let mut a = ArrivalGen::new(ArrivalKind::Poisson, 1e6, 42);
        let mut b = ArrivalGen::new(ArrivalKind::Poisson, 1e6, 42);
        let mut now = 0;
        for _ in 0..10_000 {
            let (ga, gb) = (a.next_gap(now), b.next_gap(now));
            assert_eq!(ga, gb);
            now += ga;
        }
    }

    #[test]
    fn poisson_empirical_rate_matches() {
        // 1M rps → mean gap 1 µs. 200k draws pin the mean within 1 %.
        let mut g = ArrivalGen::new(ArrivalKind::Poisson, 1e6, 7);
        let n = 200_000u64;
        let total: Time = (0..n).map(|_| g.next_gap(0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - US as f64).abs() / US as f64 < 0.01, "mean gap {mean} ps");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson_but_visits_both_states() {
        // enter == exit → the chain spends half its arrivals in the
        // burst state, whose gaps are 8x shorter. The gap mixture's true
        // squared coefficient of variation is ~2.21, comfortably above
        // the exponential's 1 (sampling noise at 100k draws is ~0.03).
        let kind = ArrivalKind::Mmpp { burst_mult: 8.0, enter: 0.05, exit: 0.05 };
        let mut g = ArrivalGen::new(kind, 1e6, 9);
        let gaps: Vec<f64> = (0..100_000).map(|_| g.next_gap(0) as f64).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        // Bursts ride on top of the base rate: long-run mean gap shrinks.
        assert!(mean < US as f64, "mmpp mean gap {mean} not below base");
        // Squared coefficient of variation well above the exponential's 1.
        let var = gaps.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!(scv > 1.5, "mmpp scv {scv} not burstier than Poisson");
    }

    #[test]
    fn diurnal_peak_outpaces_trough() {
        let kind = ArrivalKind::Diurnal { amp: 0.8, period: 10 * MS };
        let mut g = ArrivalGen::new(kind, 1e6, 3);
        let at = |g: &mut ArrivalGen, t: Time| -> f64 {
            (0..20_000).map(|_| g.next_gap(t) as f64).sum::<f64>() / 20_000.0
        };
        let peak = at(&mut g, 5 * MS); // phase 0.5
        let trough = at(&mut g, 0); // phase 0
        assert!(peak < trough * 0.8, "peak gap {peak} vs trough {trough}");
    }

    #[test]
    fn gaps_always_advance_time() {
        // Absurd rate: exponential draws round to 0 ps, clamp must hold.
        let mut g = ArrivalGen::new(ArrivalKind::Poisson, 1e13, 1);
        for _ in 0..1000 {
            assert!(g.next_gap(0) >= 1);
        }
    }
}
