//! §18 Observability: causal span tracing + a latency-attribution ledger
//! across the full CXL memory path.
//!
//! Every demand op's journey — warp issue → LLC → host bridge → fabric
//! switch ingress/WRR → root-port queue → controller legs → SR/DS →
//! expander cache → media → RAS retry legs — is decomposable into
//! *stages*: each [`Stage`] duration is a difference of two successive
//! path timestamps, so the per-op [`StageTrace`] ledger telescopes and
//! its stages sum **bit-exactly** to the end-to-end latency the metrics
//! already record. That conservation invariant is the whole design: a
//! breakdown that cannot drift from the numbers it explains
//! (property-tested in `tests/props.rs`).
//!
//! Determinism: sampling draws no randomness and never touches a
//! timestamp. Each span kind keeps its own op counter and samples the
//! ops whose sequence number has the low `sample_shift` bits clear, so
//! the same config produces the same spans on every run — and because
//! tracing only *reads* the timestamps the simulation computes anyway,
//! an armed tracer leaves `RunMetrics::fingerprint()` bit-identical to
//! a disabled one (guarded in `tests/determinism.rs`). The aggregated
//! [`ObsReport`] itself is fingerprint-exempt, like the percentile
//! reservoirs.
//!
//! Sampled spans land in a compact fixed-size binary ring buffer
//! ([`SpanRec`]: 8 words + the stage array) that overwrites oldest;
//! [`chrome_trace`] exports the ring as Chrome/Perfetto trace-event
//! JSON (`--trace-out run.json`, see `docs/TRACING.md`).

use crate::sim::Time;
use crate::util::json::Json;
use crate::util::stats::{Percentiles, Summary};
use std::collections::BTreeMap;

/// One attributable leg of an op's path. Durations are picosecond
/// differences of successive path timestamps, so a trace's stages
/// telescope to the end-to-end latency (the conservation invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// GPU LLC lookup (hit latency; the on-package leg of a miss is
    /// folded into the expander path below).
    Llc = 0,
    /// Host bridge / root complex traversal (both directions).
    HostBridge = 1,
    /// Fabric switch admission: token-bucket pacing, ingress-slot and
    /// WRR share-slot waits (multi-tenant pools only).
    SwitchArb = 2,
    /// Fabric switch hop latency (both directions).
    SwitchHop = 3,
    /// Root-port memory-queue slot wait (MSHR-style occupancy).
    PortQueue = 4,
    /// Request-direction controller + link leg (flit SER/DES, PHY).
    ReqLink = 5,
    /// RAS retry/replay extra charged on the request leg.
    RasReq = 6,
    /// Deterministic-store buffering or read-intercept served from the
    /// DS buffer (the op never reaches media).
    DsLocal = 7,
    /// Expander device-cache hit service (DRAM-class, media bypassed).
    CacheHit = 8,
    /// Backend media access (DRAM or Z-NAND, including cache fetch+drain
    /// and GC interference).
    Media = 9,
    /// Response-direction controller + link leg.
    RespLink = 10,
    /// RAS retry/replay extra charged on the response leg.
    RasResp = 11,
}

/// Number of ledger stages (the fixed width of every trace array).
pub const N_STAGES: usize = 12;

impl Stage {
    /// Every stage, in canonical path order (also the exporter's layout
    /// order).
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Llc,
        Stage::HostBridge,
        Stage::SwitchArb,
        Stage::SwitchHop,
        Stage::PortQueue,
        Stage::ReqLink,
        Stage::RasReq,
        Stage::DsLocal,
        Stage::CacheHit,
        Stage::Media,
        Stage::RespLink,
        Stage::RasResp,
    ];

    /// Short display name (table columns, trace-event names).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Llc => "llc",
            Stage::HostBridge => "host-bridge",
            Stage::SwitchArb => "switch-arb",
            Stage::SwitchHop => "switch-hop",
            Stage::PortQueue => "port-queue",
            Stage::ReqLink => "req-link",
            Stage::RasReq => "ras-req",
            Stage::DsLocal => "ds-local",
            Stage::CacheHit => "cache-hit",
            Stage::Media => "media",
            Stage::RespLink => "resp-link",
            Stage::RasResp => "ras-resp",
        }
    }
}

/// What kind of op a span covers (one deterministic sampling counter
/// per kind, so e.g. rare writebacks still get sampled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// GPU LLC hit (never leaves the package).
    LlcHit = 0,
    /// Demand load serviced by the CXL expander path.
    Load = 1,
    /// Writeback store to the CXL expander path.
    Store = 2,
    /// Demand fill from local on-package HBM/DRAM.
    LocalFill = 3,
}

/// Number of span kinds.
pub const N_KINDS: usize = 4;

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::LlcHit => "llc-hit",
            SpanKind::Load => "load",
            SpanKind::Store => "store",
            SpanKind::LocalFill => "local-fill",
        }
    }
}

/// Per-op scratch ledger: one duration slot per [`Stage`]. The path
/// code adds each leg as it is computed; [`total`](StageTrace::total)
/// must equal the op's end-to-end latency (conservation).
#[derive(Debug, Clone, Default)]
pub struct StageTrace {
    pub stages: [Time; N_STAGES],
}

impl StageTrace {
    pub fn reset(&mut self) {
        self.stages = [0; N_STAGES];
    }

    /// Attribute `dt` picoseconds to `stage` (accumulates: a stage may
    /// be charged from both path directions).
    pub fn add(&mut self, stage: Stage, dt: Time) {
        self.stages[stage as usize] += dt;
    }

    /// Duration attributed to one stage.
    pub fn get(&self, stage: Stage) -> Time {
        self.stages[stage as usize]
    }

    /// Sum of every stage — bit-exactly the end-to-end latency when the
    /// path threading is correct.
    pub fn total(&self) -> Time {
        self.stages.iter().sum()
    }
}

/// Tracing configuration. Disabled by default and structurally inert:
/// `ObsState::new` returns `None` for a disabled spec, so no armed
/// config path even exists unless requested.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObsSpec {
    pub enabled: bool,
    /// Sample 1 of every `2^sample_shift` ops per span kind (0 = trace
    /// every op; 6 = 1/64, the bench's overhead point).
    pub sample_shift: u32,
    /// Span ring-buffer capacity (overwrites oldest beyond this).
    pub ring_cap: usize,
}

impl Default for ObsSpec {
    fn default() -> Self {
        ObsSpec { enabled: false, sample_shift: 6, ring_cap: 4096 }
    }
}

/// One sampled span: a compact fixed-size binary record in the ring.
#[derive(Debug, Clone, Copy)]
pub struct SpanRec {
    /// Monotonic span id (allocation order across all kinds).
    pub id: u64,
    pub kind: SpanKind,
    /// Issue timestamp (ps).
    pub start: Time,
    /// Completion timestamp (ps).
    pub end: Time,
    /// The ledger: per-stage durations summing to `end - start`.
    pub stages: [Time; N_STAGES],
}

/// Live tracer state carried by a `System` when the spec is armed.
#[derive(Debug, Clone)]
pub struct ObsState {
    /// `2^shift - 1`: an op is sampled iff its kind counter has these
    /// bits clear.
    mask: u64,
    ring_cap: usize,
    /// Per-kind op counters (deterministic sampling clock — no RNG).
    seq: [u64; N_KINDS],
    /// Reusable per-op ledger, reset before each sampled op.
    pub scratch: StageTrace,
    stage: [Summary; N_STAGES],
    stage_pctl: [Percentiles; N_STAGES],
    e2e: Summary,
    spans: u64,
    violations: u64,
    next_id: u64,
    ring: Vec<SpanRec>,
    ring_next: usize,
    dropped: u64,
}

impl ObsState {
    /// Build a tracer for an armed spec; `None` when disabled (the
    /// structural-inertness contract: nothing exists to consult).
    pub fn new(spec: &ObsSpec) -> Option<ObsState> {
        if !spec.enabled {
            return None;
        }
        Some(ObsState {
            mask: (1u64 << spec.sample_shift.min(63)) - 1,
            ring_cap: spec.ring_cap,
            seq: [0; N_KINDS],
            scratch: StageTrace::default(),
            stage: Default::default(),
            stage_pctl: Default::default(),
            e2e: Summary::new(),
            spans: 0,
            violations: 0,
            next_id: 0,
            ring: Vec::new(),
            ring_next: 0,
            dropped: 0,
        })
    }

    /// Tick the kind's op counter; true iff this op is sampled. When it
    /// is, the caller resets `scratch`, threads it through the path,
    /// then calls [`finish`](ObsState::finish).
    pub fn sample(&mut self, kind: SpanKind) -> bool {
        let s = &mut self.seq[kind as usize];
        let hit = *s & self.mask == 0;
        *s += 1;
        hit
    }

    /// Close a sampled span: verify conservation, fold the ledger into
    /// the per-stage aggregates, and push the record into the ring.
    pub fn finish(&mut self, kind: SpanKind, start: Time, end: Time) {
        let e2e = end - start;
        if self.scratch.total() != e2e {
            // Counted, not asserted: the property suite pins this at
            // zero; a release run reports instead of aborting.
            self.violations += 1;
        }
        self.spans += 1;
        self.e2e.add(e2e as f64);
        for (i, &d) in self.scratch.stages.iter().enumerate() {
            if d > 0 {
                self.stage[i].add(d as f64);
                self.stage_pctl[i].add(d as f64);
            }
        }
        let rec = SpanRec { id: self.next_id, kind, start, end, stages: self.scratch.stages };
        self.next_id += 1;
        if self.ring.len() < self.ring_cap {
            self.ring.push(rec);
        } else if self.ring_cap > 0 {
            self.ring[self.ring_next] = rec;
            self.ring_next = (self.ring_next + 1) % self.ring_cap;
            self.dropped += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// Spans whose ledger failed conservation (must stay 0).
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Snapshot the aggregates + ring (oldest span first) for
    /// `RunMetrics`.
    pub fn report(&self) -> ObsReport {
        let mut ring = Vec::with_capacity(self.ring.len());
        ring.extend_from_slice(&self.ring[self.ring_next..]);
        ring.extend_from_slice(&self.ring[..self.ring_next]);
        ObsReport {
            stage: self.stage.clone(),
            stage_pctl: self.stage_pctl.clone(),
            e2e: self.e2e.clone(),
            spans: self.spans,
            ops_seen: self.seq.iter().sum(),
            violations: self.violations,
            dropped: self.dropped,
            ring,
        }
    }
}

/// Aggregated span ledgers, harvested into `RunMetrics::obs`.
/// Deterministic for a fixed config but **fingerprint-exempt** (like
/// the percentile reservoirs): the breakdown explains the fingerprinted
/// numbers, it is not one of them.
#[derive(Debug, Clone, Default)]
pub struct ObsReport {
    /// Per-stage duration summaries over sampled spans where the stage
    /// was present (zero-duration stages are not folded in, so `mean`
    /// reads "mean when traversed" and `sum` is total attributed ps).
    pub stage: [Summary; N_STAGES],
    /// Per-stage percentile reservoirs (same presence rule).
    pub stage_pctl: [Percentiles; N_STAGES],
    /// End-to-end latency summary over sampled spans.
    pub e2e: Summary,
    /// Sampled span count.
    pub spans: u64,
    /// Total ops the sampler clocked (sampled + skipped).
    pub ops_seen: u64,
    /// Conservation violations (stages ≠ end-to-end; must be 0).
    pub violations: u64,
    /// Spans evicted from the ring after it filled.
    pub dropped: u64,
    /// The span ring, oldest first.
    pub ring: Vec<SpanRec>,
}

impl ObsReport {
    /// Total picoseconds attributed to one stage across sampled spans.
    pub fn stage_sum_ps(&self, s: Stage) -> f64 {
        self.stage[s as usize].sum()
    }

    /// Total attributed picoseconds across every stage.
    pub fn attributed_ps(&self) -> f64 {
        self.stage.iter().map(|s| s.sum()).sum()
    }

    /// One stage's share of the total attributed time, in [0, 1].
    pub fn stage_share(&self, s: Stage) -> f64 {
        let total = self.attributed_ps();
        if total == 0.0 { 0.0 } else { self.stage_sum_ps(s) / total }
    }

    /// Mean duration of one stage when traversed, in ns.
    pub fn stage_mean_ns(&self, s: Stage) -> f64 {
        self.stage[s as usize].mean() / 1_000.0
    }

    /// p99 duration of one stage when traversed, in ns.
    pub fn stage_p99_ns(&self, s: Stage) -> f64 {
        self.stage_pctl[s as usize].percentile(99.0) / 1_000.0
    }

    /// Mean attributed time per span, in ns — the stacked-breakdown
    /// column: over all sampled spans these sum to the mean end-to-end
    /// latency.
    pub fn stage_per_span_ns(&self, s: Stage) -> f64 {
        if self.spans == 0 {
            return 0.0;
        }
        self.stage_sum_ps(s) / self.spans as f64 / 1_000.0
    }
}

/// Export span rings as a Chrome/Perfetto trace-event document: one
/// `pid` per named report, one `tid` per span kind, an enclosing `X`
/// event per span and its ledger stages laid out sequentially inside it
/// in canonical [`Stage::ALL`] order (an *attribution* layout — stage
/// offsets within a span are the ledger telescoped, not re-simulated
/// wall-clock positions; see `docs/TRACING.md`). Timestamps are µs as
/// the format requires.
pub fn chrome_trace(reports: &[(String, ObsReport)]) -> Json {
    const PS_PER_US: f64 = 1e6;
    let mut events = Vec::new();
    for (pid, (name, rep)) in reports.iter().enumerate() {
        let mut meta = BTreeMap::new();
        meta.insert("ph".to_string(), Json::Str("M".to_string()));
        meta.insert("name".to_string(), Json::Str("process_name".to_string()));
        meta.insert("pid".to_string(), Json::Num(pid as f64));
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Json::Str(name.clone()));
        meta.insert("args".to_string(), Json::Obj(args));
        events.push(Json::Obj(meta));
        for span in &rep.ring {
            let mut ev = BTreeMap::new();
            ev.insert("ph".to_string(), Json::Str("X".to_string()));
            ev.insert("name".to_string(), Json::Str(span.kind.name().to_string()));
            ev.insert("cat".to_string(), Json::Str("span".to_string()));
            ev.insert("ts".to_string(), Json::Num(span.start as f64 / PS_PER_US));
            ev.insert("dur".to_string(), Json::Num((span.end - span.start) as f64 / PS_PER_US));
            ev.insert("pid".to_string(), Json::Num(pid as f64));
            ev.insert("tid".to_string(), Json::Num(span.kind as usize as f64));
            events.push(Json::Obj(ev));
            let mut cursor = span.start;
            for stage in Stage::ALL {
                let d = span.stages[stage as usize];
                if d == 0 {
                    continue;
                }
                let mut ev = BTreeMap::new();
                ev.insert("ph".to_string(), Json::Str("X".to_string()));
                ev.insert("name".to_string(), Json::Str(stage.name().to_string()));
                ev.insert("cat".to_string(), Json::Str("stage".to_string()));
                ev.insert("ts".to_string(), Json::Num(cursor as f64 / PS_PER_US));
                ev.insert("dur".to_string(), Json::Num(d as f64 / PS_PER_US));
                ev.insert("pid".to_string(), Json::Num(pid as f64));
                ev.insert("tid".to_string(), Json::Num(span.kind as usize as f64));
                events.push(Json::Obj(ev));
                cursor += d;
            }
        }
    }
    let mut top = BTreeMap::new();
    top.insert("displayTimeUnit".to_string(), Json::Str("ns".to_string()));
    top.insert("traceEvents".to_string(), Json::Arr(events));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed(shift: u32, ring_cap: usize) -> ObsState {
        ObsState::new(&ObsSpec { enabled: true, sample_shift: shift, ring_cap })
            .expect("armed spec builds a state")
    }

    #[test]
    fn disabled_spec_builds_nothing() {
        assert!(ObsState::new(&ObsSpec::default()).is_none());
    }

    #[test]
    fn trace_telescopes_and_resets() {
        let mut t = StageTrace::default();
        t.add(Stage::PortQueue, 5);
        t.add(Stage::Media, 100);
        t.add(Stage::HostBridge, 2);
        t.add(Stage::HostBridge, 2);
        assert_eq!(t.get(Stage::HostBridge), 4, "stages accumulate across directions");
        assert_eq!(t.total(), 109);
        t.reset();
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn sampling_is_a_deterministic_per_kind_clock() {
        let mut o = armed(2, 16);
        let hits: Vec<bool> = (0..8).map(|_| o.sample(SpanKind::Load)).collect();
        assert_eq!(hits, [true, false, false, false, true, false, false, false]);
        // A different kind has its own counter, so its first op samples.
        assert!(o.sample(SpanKind::Store));
        // Shift 0 samples everything.
        let mut all = armed(0, 16);
        assert!((0..5).all(|_| all.sample(SpanKind::Load)));
    }

    #[test]
    fn finish_checks_conservation_and_aggregates() {
        let mut o = armed(0, 16);
        o.scratch.reset();
        o.scratch.add(Stage::PortQueue, 30);
        o.scratch.add(Stage::Media, 70);
        o.finish(SpanKind::Load, 1_000, 1_100);
        assert_eq!(o.violations(), 0);
        o.scratch.reset();
        o.scratch.add(Stage::Media, 60);
        o.finish(SpanKind::Load, 0, 100);
        assert_eq!(o.violations(), 1, "a 40 ps leak must be counted");
        let rep = o.report();
        assert_eq!(rep.spans, 2);
        assert_eq!(rep.violations, 1);
        assert_eq!(rep.stage[Stage::Media as usize].count(), 2);
        assert_eq!(rep.stage[Stage::PortQueue as usize].count(), 1);
        assert_eq!(rep.stage_sum_ps(Stage::Media), 130.0);
        assert_eq!(rep.attributed_ps(), 160.0);
        assert!((rep.stage_share(Stage::Media) - 130.0 / 160.0).abs() < 1e-12);
        assert_eq!(rep.e2e.mean(), 100.0);
    }

    #[test]
    fn ring_overwrites_oldest_and_reports_in_order() {
        let mut o = armed(0, 2);
        for i in 0..5u64 {
            o.scratch.reset();
            o.scratch.add(Stage::Media, 10);
            o.finish(SpanKind::Load, i * 100, i * 100 + 10);
        }
        let rep = o.report();
        assert_eq!(rep.spans, 5);
        assert_eq!(rep.dropped, 3);
        let ids: Vec<u64> = rep.ring.iter().map(|s| s.id).collect();
        assert_eq!(ids, [3, 4], "ring keeps the newest spans, oldest first");
    }

    #[test]
    fn per_span_columns_sum_to_mean_e2e() {
        let mut o = armed(0, 16);
        for (q, m) in [(30u64, 70u64), (10, 110), (20, 100)] {
            o.scratch.reset();
            o.scratch.add(Stage::PortQueue, q);
            o.scratch.add(Stage::Media, m);
            o.finish(SpanKind::Load, 0, q + m);
        }
        let rep = o.report();
        let stacked: f64 = Stage::ALL.iter().map(|&s| rep.stage_per_span_ns(s)).sum();
        assert!(
            (stacked - rep.e2e.mean() / 1_000.0).abs() < 1e-9,
            "stacked columns must reassemble the mean end-to-end latency"
        );
    }

    #[test]
    fn chrome_trace_emits_parseable_nested_events() {
        let mut o = armed(0, 16);
        o.scratch.reset();
        o.scratch.add(Stage::PortQueue, 2_000_000);
        o.scratch.add(Stage::Media, 3_000_000);
        o.finish(SpanKind::Load, 1_000_000, 6_000_000);
        let doc = chrome_trace(&[("cxl".to_string(), o.report())]);
        let parsed = crate::util::json::parse(&doc.to_string()).expect("exporter emits JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // Metadata + enclosing span + two stage events.
        assert_eq!(events.len(), 4);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        let span = &events[1];
        assert_eq!(span.get("name").unwrap().as_str(), Some("load"));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(5.0));
        // Stages tile the span back-to-back in path order.
        assert_eq!(events[2].get("name").unwrap().as_str(), Some("port-queue"));
        assert_eq!(events[2].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(events[3].get("name").unwrap().as_str(), Some("media"));
        assert_eq!(events[3].get("ts").unwrap().as_f64(), Some(3.0));
    }
}
