//! RAS (reliability / availability / serviceability) layer: deterministic
//! fault injection and recovery for the CXL stack (DESIGN.md §15).
//!
//! Every layer built so far — controller legs, pooled switch, tiered HDM,
//! expander cache — assumes a perfect fabric. This module injects the
//! three fault classes that dominate real deployments and wires the
//! recovery machinery that contains them:
//!
//! * **Link CRC errors** — per-flit Bernoulli draws (optionally
//!   multiplied inside periodic burst windows) corrupt a transfer leg;
//!   the port's link-layer [`crate::cxl::ReplayBuffer`] retries it with
//!   charged retry legs until it delivers or exhausts `max_retries` and
//!   escalates to a *poison*.
//! * **Media misbehaviour** — per-access latency spikes (exponential
//!   tail) and controller timeouts with exponential backoff model a
//!   flaky endpoint device.
//! * **Hard degradation** — at a configured sim time one endpoint is
//!   marked degraded: its dirty device-cache lines are drained first (no
//!   dirty byte is lost), every subsequent access pays a penalty, the
//!   pooled switch demotes its WRR share, and the tiering engine stops
//!   migrating pages onto it.
//!
//! Determinism contract: all draws come from a *forked* PRNG sub-stream
//! ([`crate::util::prng::Pcg32::fork`], label = port id, parent stream
//! `0xFA17`), so RAS never consumes from the workload/SR/tiering
//! sequences — and an **inert** [`FaultSpec`] (all rates zero, no
//! scheduled degradation) builds no [`RasState`] at all, which is what
//! makes `cxl-ras` at zero fault rates *bit-identical* to `cxl`
//! (`tests/determinism.rs`), mirroring the zero-capacity device-cache
//! identity of §14.

use crate::cxl::ReplayBuffer;
use crate::sim::{Time, MS, US};
use crate::util::prng::Pcg32;

/// Seeded fault schedule, carried by `SystemConfig` (`ras` field). All
/// fields inert by default; the `cxl-ras` config family arms them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Master switch: build the RAS layer (an enabled spec whose every
    /// rate is zero still builds *nothing* — see [`FaultSpec::is_inert`]).
    pub enabled: bool,
    /// Per-flit CRC-error probability on a link transfer leg.
    pub crc_error_rate: f64,
    /// Burst-window period (0 = no bursts): within the first
    /// `burst_len` of every `burst_every` of sim time the CRC rate is
    /// multiplied by `burst_mult` (correlated error bursts, the pattern
    /// link-retry buffers are sized for).
    pub burst_every: Time,
    /// Burst-window width.
    pub burst_len: Time,
    /// CRC-rate multiplier inside a burst window.
    pub burst_mult: f64,
    /// Per-access probability of a media latency spike.
    pub media_spike_rate: f64,
    /// Mean of the exponential extra latency added by a spike.
    pub media_spike_mean: Time,
    /// Per-access probability of a controller timeout.
    pub timeout_rate: f64,
    /// Base controller timeout; consecutive timeouts back off
    /// exponentially (`timeout << attempt`).
    pub timeout: Time,
    /// Link retries before a transfer escalates to poison, and the cap
    /// on consecutive timeout backoffs.
    pub max_retries: u32,
    /// Sim time at which `degrade_port` hard-degrades (`Time::MAX` =
    /// never).
    pub degrade_at: Time,
    /// Which port index degrades at `degrade_at`.
    pub degrade_port: usize,
    /// Extra latency every access to a degraded endpoint pays.
    pub degrade_penalty: Time,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            enabled: false,
            crc_error_rate: 0.0,
            burst_every: 0,
            burst_len: 0,
            burst_mult: 1.0,
            media_spike_rate: 0.0,
            media_spike_mean: 0,
            timeout_rate: 0.0,
            timeout: 0,
            max_retries: 3,
            degrade_at: Time::MAX,
            degrade_port: 0,
            degrade_penalty: 0,
        }
    }
}

impl FaultSpec {
    /// The `cxl-ras` config family's representative fault schedule: a
    /// 1e-6 per-flit CRC rate with 100x bursts every 2 ms, rare media
    /// latency spikes and controller timeouts. Hard degradation stays
    /// unscheduled — benches and experiments arm `degrade_at` per
    /// scenario.
    pub fn representative() -> FaultSpec {
        FaultSpec {
            enabled: true,
            crc_error_rate: 1e-6,
            burst_every: 2 * MS,
            burst_len: 50 * US,
            burst_mult: 100.0,
            media_spike_rate: 1e-4,
            media_spike_mean: 20 * US,
            timeout_rate: 1e-5,
            timeout: 5 * US,
            ..FaultSpec::default()
        }
    }

    /// An inert schedule can never fire: no CRC errors, no spikes, no
    /// timeouts, no scheduled degradation. Inert specs build no
    /// [`RasState`] — the structural guarantee behind the zero-rate
    /// bit-transparency test.
    pub fn is_inert(&self) -> bool {
        !self.enabled
            || (self.crc_error_rate <= 0.0
                && self.media_spike_rate <= 0.0
                && self.timeout_rate <= 0.0
                && self.degrade_at == Time::MAX)
    }
}

/// RAS counters a port exports into `RunMetrics` (all fingerprinted in
/// `tests/determinism.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct RasStats {
    /// Link retry attempts (each charged one extra transfer leg).
    pub retries: u64,
    /// Flits re-transmitted from the replay buffer across all retries.
    pub replays: u64,
    /// Transfers that exhausted `max_retries` and escalated to poison.
    pub poisons: u64,
    /// Controller timeouts (each charged an exponential-backoff wait).
    pub timeouts: u64,
    /// Degradation events observed (port marked degraded, switch share
    /// demoted, tier swap vetoed).
    pub failovers: u64,
    /// Dirty device-cache bytes flushed to media by the pre-degradation
    /// drain — the "no dirty byte lost" guarantee, made countable.
    pub dirty_rescued_bytes: u64,
}

/// Outcome of pushing one transfer through the faulty link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkResult {
    /// Extra latency charged by retry legs (0 on a clean pass).
    pub extra: Time,
    /// The transfer exhausted its retries: the payload is poisoned and
    /// the caller must contain it (re-fetch / recovery path).
    pub poisoned: bool,
}

/// Per-port fault-injection + recovery state. Built only for non-inert
/// schedules ([`RasState::new`] returns `None` otherwise), so fault-free
/// configurations stay structurally identical to the pre-RAS stack.
#[derive(Debug)]
pub struct RasState {
    spec: FaultSpec,
    /// Forked sub-stream: draws here never advance the system RNG.
    rng: Pcg32,
    /// Link-layer ack/replay buffer (exactly-once, in-order).
    pub replay: ReplayBuffer,
    /// Hard-degraded flag, latched by [`RasState::mark_degraded`].
    pub degraded: bool,
    pub stats: RasStats,
}

impl RasState {
    /// Build the RAS layer for port `port` under `spec`, or `None` when
    /// the schedule is inert. The RNG is a fork of a dedicated parent
    /// stream (`0xFA17`) labelled by the port id, so every port draws an
    /// independent, reproducible fault sequence.
    pub fn new(spec: FaultSpec, seed: u64, port: usize) -> Option<RasState> {
        if spec.is_inert() {
            return None;
        }
        let parent = Pcg32::new(seed, 0xFA17);
        Some(RasState {
            rng: parent.fork(port as u64),
            replay: ReplayBuffer::new(spec.max_retries),
            degraded: false,
            stats: RasStats::default(),
            spec,
        })
    }

    /// The effective per-flit CRC rate at `now` (burst windows fold in).
    pub fn crc_rate(&self, now: Time) -> f64 {
        let mut r = self.spec.crc_error_rate;
        if self.spec.burst_every > 0 && now % self.spec.burst_every < self.spec.burst_len {
            r *= self.spec.burst_mult;
        }
        r.clamp(0.0, 1.0)
    }

    /// Per-transfer corruption probability for a `flits`-flit sequence:
    /// `1 - (1 - rate)^flits` — any corrupted flit spoils the transfer.
    fn transfer_error_p(&self, now: Time, flits: u64) -> f64 {
        let r = self.crc_rate(now);
        if r <= 0.0 {
            0.0
        } else if r >= 1.0 {
            1.0
        } else {
            1.0 - (1.0 - r).powi(flits.clamp(1, i32::MAX as u64) as i32)
        }
    }

    /// Push one `flits`-flit transfer leg through the link: draw
    /// corruption, drive the replay buffer until the transfer delivers
    /// exactly once (each retry charges one extra `leg`) or exhausts its
    /// retries and poisons.
    pub fn link_transfer(&mut self, now: Time, flits: u64, leg: Time) -> LinkResult {
        let p = self.transfer_error_p(now, flits);
        self.replay.send(flits);
        let mut extra: Time = 0;
        loop {
            let corrupted = p > 0.0 && self.rng.chance(p);
            match self.replay.attempt(corrupted) {
                crate::cxl::Attempt::Retried { .. } => {
                    self.stats.retries += 1;
                    self.stats.replays += flits;
                    extra += leg;
                }
                crate::cxl::Attempt::Poisoned { .. } => {
                    self.stats.poisons += 1;
                    return LinkResult { extra, poisoned: true };
                }
                // Delivered — or Idle, which cannot happen right after a
                // send but terminates the loop safely if it ever did.
                _ => return LinkResult { extra, poisoned: false },
            }
        }
    }

    /// Draw the media latency-spike tail for one endpoint access
    /// (0 almost always; an exponential extra when the spike fires).
    pub fn media_spike(&mut self) -> Time {
        if self.spec.media_spike_rate > 0.0
            && self.spec.media_spike_mean > 0
            && self.rng.chance(self.spec.media_spike_rate)
        {
            self.rng.exponential(self.spec.media_spike_mean as f64) as Time
        } else {
            0
        }
    }

    /// Draw consecutive controller timeouts for one access; each fires
    /// with `timeout_rate` and waits `timeout << attempt` (exponential
    /// backoff), capped at `max_retries` rounds.
    pub fn timeout_wait(&mut self) -> Time {
        if self.spec.timeout_rate <= 0.0 || self.spec.timeout == 0 {
            return 0;
        }
        let mut wait: Time = 0;
        for attempt in 0..self.spec.max_retries.max(1) {
            if !self.rng.chance(self.spec.timeout_rate) {
                break;
            }
            self.stats.timeouts += 1;
            wait += self.spec.timeout << attempt.min(20);
        }
        wait
    }

    /// Whether this port is scheduled to degrade at or before `now` and
    /// has not yet been marked.
    pub fn due_degrade(&self, now: Time, port: usize) -> bool {
        !self.degraded && port == self.spec.degrade_port && now >= self.spec.degrade_at
    }

    /// Latch the degraded flag (after the dirty-line drain) and count
    /// the failover.
    pub fn mark_degraded(&mut self) {
        self.degraded = true;
        self.stats.failovers += 1;
    }

    /// Base controller timeout — the wait a requester pays before
    /// re-issuing a transfer whose completion was poisoned (containment
    /// re-fetch path in `rootcomplex/rootport.rs`).
    pub fn base_timeout(&self) -> Time {
        self.spec.timeout
    }

    /// Per-access latency penalty on a degraded endpoint.
    pub fn degrade_penalty(&self) -> Time {
        if self.degraded {
            self.spec.degrade_penalty
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{NS, US};

    fn spec(rate: f64) -> FaultSpec {
        FaultSpec { enabled: true, crc_error_rate: rate, ..FaultSpec::default() }
    }

    #[test]
    fn inert_specs_build_no_state() {
        assert!(FaultSpec::default().is_inert());
        assert!(RasState::new(FaultSpec::default(), 1, 0).is_none());
        // Enabled but all-zero rates is still inert — the zero-rate
        // bit-transparency contract.
        let zeroed = FaultSpec { enabled: true, ..FaultSpec::default() };
        assert!(zeroed.is_inert());
        assert!(RasState::new(zeroed, 1, 0).is_none());
        // Any live knob arms it.
        assert!(!spec(1e-6).is_inert());
        assert!(RasState::new(spec(1e-6), 1, 0).is_some());
        let deg = FaultSpec { enabled: true, degrade_at: 5, ..FaultSpec::default() };
        assert!(!deg.is_inert());
    }

    #[test]
    fn clean_link_charges_nothing() {
        let mut r = RasState::new(spec(1e-12), 7, 0).expect("armed");
        for i in 0..200 {
            let out = r.link_transfer(i * NS, 5, 10 * NS);
            assert!(!out.poisoned);
            // At 1e-12 no draw fires in 200 tries (p ≈ 5e-12/transfer).
            assert_eq!(out.extra, 0);
        }
        assert_eq!(r.stats.retries, 0);
        assert_eq!(r.stats.poisons, 0);
    }

    #[test]
    fn certain_corruption_poisons_after_bounded_retries() {
        let mut s = spec(1.0);
        s.max_retries = 3;
        let mut r = RasState::new(s, 7, 0).expect("armed");
        let out = r.link_transfer(0, 2, 10 * NS);
        assert!(out.poisoned);
        assert_eq!(out.extra, 3 * 10 * NS, "every allowed retry charges a leg");
        assert_eq!(r.stats.retries, 3);
        assert_eq!(r.stats.poisons, 1);
        assert_eq!(r.stats.replays, 3 * 2);
        // Exactly-once bookkeeping: nothing remains in flight.
        assert_eq!(r.replay.in_flight(), 0);
    }

    #[test]
    fn burst_window_multiplies_the_rate() {
        let mut s = spec(0.01);
        s.burst_every = 100 * US;
        s.burst_len = 10 * US;
        s.burst_mult = 50.0;
        let r = RasState::new(s, 7, 0).expect("armed");
        assert!((r.crc_rate(5 * US) - 0.5).abs() < 1e-12, "inside the burst");
        assert!((r.crc_rate(50 * US) - 0.01).abs() < 1e-12, "outside the burst");
        // Rates clamp at 1.
        let mut s2 = spec(0.5);
        s2.burst_every = 10;
        s2.burst_len = 10;
        s2.burst_mult = 100.0;
        let r2 = RasState::new(s2, 7, 0).expect("armed");
        assert_eq!(r2.crc_rate(0), 1.0);
    }

    #[test]
    fn fault_draws_are_reproducible_and_per_port_independent() {
        let mut s = spec(0.3);
        s.media_spike_rate = 0.2;
        s.media_spike_mean = 5 * US;
        let run = |port: usize| -> (Vec<Time>, RasStats) {
            let mut r = RasState::new(s, 0xC11A, port).expect("armed");
            let mut v = Vec::new();
            for i in 0..200u64 {
                let out = r.link_transfer(i * NS, 3, NS);
                v.push(out.extra);
                v.push(r.media_spike());
            }
            (v, r.stats)
        };
        let (a, sa) = run(0);
        let (b, sb) = run(0);
        assert_eq!(a, b, "fixed-seed fault schedules must replay bit-for-bit");
        assert_eq!(sa.retries, sb.retries);
        let (c, _) = run(1);
        assert_ne!(a, c, "ports must draw independent fault sequences");
    }

    #[test]
    fn degradation_latches_once_and_charges_the_penalty() {
        let mut s = spec(0.0);
        s.enabled = true;
        s.degrade_at = 100;
        s.degrade_port = 2;
        s.degrade_penalty = 7 * US;
        let mut r = RasState::new(s, 1, 2).expect("degrade schedule arms RAS");
        assert!(!r.due_degrade(50, 2), "not due yet");
        assert!(!r.due_degrade(200, 1), "wrong port never degrades");
        assert!(r.due_degrade(200, 2));
        assert_eq!(r.degrade_penalty(), 0);
        r.mark_degraded();
        assert!(!r.due_degrade(300, 2), "latches once");
        assert_eq!(r.degrade_penalty(), 7 * US);
        assert_eq!(r.stats.failovers, 1);
    }

    #[test]
    fn timeout_backoff_grows_exponentially() {
        let mut s = spec(0.0);
        s.enabled = true;
        s.timeout_rate = 1.0;
        s.timeout = 2 * US;
        s.max_retries = 3;
        let mut r = RasState::new(s, 1, 0).expect("armed");
        // Certain timeouts: 2 + 4 + 8 µs, then the cap stops the loop.
        assert_eq!(r.timeout_wait(), (2 + 4 + 8) * US);
        assert_eq!(r.stats.timeouts, 3);
    }
}
