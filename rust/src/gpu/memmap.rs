//! The GPU system-bus memory map (Fig. 5b).
//!
//! After EP enumeration, the bus address space is segmented by function:
//! GPU local memory, the host segment behind the PCIe EP, and one HDM
//! segment per CXL root port. The system bus consults this map (and the
//! root complex its HDM decoder) on every LLC miss.

/// Address-space regions of the system bus map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// On-board GPU memory (GDDR).
    Local,
    /// Host memory behind the PCIe EP (UVM's backing store).
    Host,
    /// CXL expander space: handled by the root complex's HDM decoder.
    Expander,
}

/// The memory map: `[0, local)` local, `[local, local+expander)` CXL HDM,
/// `[local+expander, ..)` host.
#[derive(Debug, Clone, Copy)]
pub struct MemMap {
    pub local_bytes: u64,
    pub expander_bytes: u64,
}

impl MemMap {
    pub fn new(local_bytes: u64, expander_bytes: u64) -> MemMap {
        MemMap { local_bytes, expander_bytes }
    }

    pub fn region(&self, addr: u64) -> Region {
        if addr < self.local_bytes {
            Region::Local
        } else if addr < self.local_bytes + self.expander_bytes {
            Region::Expander
        } else {
            Region::Host
        }
    }

    /// Offset of an expander address within HDM space.
    pub fn hdm_offset(&self, addr: u64) -> u64 {
        debug_assert_eq!(self.region(addr), Region::Expander);
        addr - self.local_bytes
    }

    /// Total directly-addressable bytes (local + expander).
    pub fn device_visible(&self) -> u64 {
        self.local_bytes + self.expander_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_partition_the_space() {
        let m = MemMap::new(4 << 20, 40 << 20);
        assert_eq!(m.region(0), Region::Local);
        assert_eq!(m.region((4 << 20) - 1), Region::Local);
        assert_eq!(m.region(4 << 20), Region::Expander);
        assert_eq!(m.region((44 << 20) - 1), Region::Expander);
        assert_eq!(m.region(44 << 20), Region::Host);
    }

    #[test]
    fn hdm_offset_is_relative() {
        let m = MemMap::new(4 << 20, 40 << 20);
        assert_eq!(m.hdm_offset(4 << 20), 0);
        assert_eq!(m.hdm_offset((4 << 20) + 123), 123);
    }
}
