//! GPU-side model: the Vortex-style compute front-end and cache system.
//!
//! Mirrors Fig. 5a's left half: streaming multiprocessors (SMs) issue
//! memory requests through a shared last-level cache (LLC) onto the
//! system bus, which routes by physical address to the local-memory
//! controller, the PCIe EP (host), or the CXL root complex.
//!
//! The paper's evaluation drives this front-end from Vortex performance
//! counters; ours drives it from the instruction mixes of Table 1b and
//! the access streams of the real workload kernels executed via PJRT
//! (see `workloads/` and `runtime/`).

pub mod cache;
pub mod memmap;
pub mod warp;

pub use cache::{AccessResult, Llc, LlcConfig};
pub use memmap::{MemMap, Region};
pub use warp::{Op, OpSource, Warp, WarpStats};

/// Cache-line size used throughout (CXL.mem demand granularity).
pub const LINE: u64 = 64;

/// Align an address down to its cache line.
#[inline]
pub fn line_of(addr: u64) -> u64 {
    addr & !(LINE - 1)
}
