//! Warp (SM hardware-thread) front-end.
//!
//! Each warp executes an in-order instruction stream of compute bursts,
//! loads and stores (already coalesced to 64 B lines, as Vortex's LSU
//! does before the LLC). Loads are non-blocking up to a memory-level-
//! parallelism limit; stores are fire-and-forget into the LLC unless the
//! cache backpressures. The coordinator's `System` owns the clock and
//! drives these state machines.
//!
//! The instruction stream is pulled from an [`OpSource`] one op at a
//! time — the warp holds at most a single lookahead op, so a warp's
//! memory cost is independent of how many dynamic instructions it will
//! execute. `workloads::OpStream` is the production source; a
//! materialized `VecDeque<Op>` also implements the trait for tests.

use std::collections::VecDeque;

use crate::sim::Time;

/// One dynamic instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Execute for `dur` picoseconds without touching memory.
    Compute { dur: Time },
    /// 64 B coalesced load.
    Load { addr: u64 },
    /// 64 B coalesced store.
    Store { addr: u64 },
}

/// Anything that can feed a warp its next dynamic instruction.
///
/// Sources are consumed strictly in order; `None` is final (a source must
/// keep returning `None` once exhausted — the warp caches exhaustion via
/// its lookahead slot either way).
///
/// `Send` is part of the contract: sharded pool runs (`fabric::shard`)
/// move whole `System`s — and thus their warps' sources — across worker
/// threads between epochs.
pub trait OpSource: std::fmt::Debug + Send {
    /// Produce the next op, advancing the source.
    fn next_op(&mut self) -> Option<Op>;

    /// Ops left, if the source knows (progress reporting only).
    fn remaining_hint(&self) -> usize {
        0
    }
}

/// Materialized op list as a source (tests, hand-built scenarios).
impl OpSource for VecDeque<Op> {
    fn next_op(&mut self) -> Option<Op> {
        self.pop_front()
    }

    fn remaining_hint(&self) -> usize {
        self.len()
    }
}

/// Per-warp execution statistics.
#[derive(Debug, Clone, Default)]
pub struct WarpStats {
    pub computes: u64,
    pub loads: u64,
    pub stores: u64,
    pub compute_time: Time,
    pub stall_time: Time,
    pub finish: Time,
}

/// An in-order warp.
#[derive(Debug)]
pub struct Warp {
    pub id: usize,
    source: Box<dyn OpSource>,
    /// Single-op lookahead so `peek` works over a pull-based source.
    peeked: Option<Op>,
    /// Loads issued but not yet completed.
    pub outstanding: usize,
    /// Max outstanding loads before the warp stalls (MLP).
    pub mlp: usize,
    /// The warp is stalled waiting for any load completion.
    pub waiting: bool,
    /// Set when the op stream is exhausted *and* all loads returned.
    pub done: bool,
    pub stats: WarpStats,
}

impl Warp {
    /// Warp over a materialized op list (tests, tools).
    pub fn new(id: usize, ops: Vec<Op>, mlp: usize) -> Warp {
        Warp::from_source(id, Box::new(VecDeque::from(ops)), mlp)
    }

    /// Warp over any op source (the simulator feeds a lazy `OpStream`).
    pub fn from_source(id: usize, source: Box<dyn OpSource>, mlp: usize) -> Warp {
        Warp {
            id,
            source,
            peeked: None,
            outstanding: 0,
            mlp: mlp.max(1),
            waiting: false,
            done: false,
            stats: WarpStats::default(),
        }
    }

    /// Next op without consuming it (fills the lookahead slot).
    pub fn peek(&mut self) -> Option<&Op> {
        if self.peeked.is_none() {
            self.peeked = self.source.next_op();
        }
        self.peeked.as_ref()
    }

    /// Consume the next op.
    pub fn pop(&mut self) -> Option<Op> {
        self.peeked.take().or_else(|| self.source.next_op())
    }

    /// True when the warp can issue another load without stalling.
    pub fn can_issue_load(&self) -> bool {
        self.outstanding < self.mlp
    }

    /// Record a load issue.
    pub fn issue_load(&mut self) {
        debug_assert!(self.can_issue_load());
        self.outstanding += 1;
        self.stats.loads += 1;
    }

    /// Record a load completion; returns true if the warp was stalled on
    /// it (caller should reschedule the warp).
    pub fn complete_load(&mut self) -> bool {
        debug_assert!(self.outstanding > 0);
        self.outstanding -= 1;
        let was_waiting = self.waiting;
        self.waiting = false;
        was_waiting
    }

    /// Remaining ops (for progress reporting).
    pub fn remaining(&self) -> usize {
        self.peeked.is_some() as usize + self.source.remaining_hint()
    }

    /// Mark final completion.
    pub fn finish(&mut self, now: Time) {
        self.done = true;
        self.stats.finish = now;
    }

    /// Hand the warp a fresh op stream (the serving front door reuses
    /// idle warps across requests). Only legal between requests: the
    /// previous source must be drained with no loads outstanding, and
    /// the warp must not have been retired via [`Warp::finish`].
    pub fn refill(&mut self, source: Box<dyn OpSource>) {
        debug_assert_eq!(self.outstanding, 0, "refill with loads in flight");
        debug_assert!(!self.done, "refill on a finished warp");
        self.source = source;
        self.peeked = None;
        self.waiting = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS;

    #[test]
    fn ops_pop_in_order() {
        let mut w = Warp::new(
            0,
            vec![Op::Compute { dur: NS }, Op::Load { addr: 64 }, Op::Store { addr: 128 }],
            4,
        );
        assert_eq!(w.pop(), Some(Op::Compute { dur: NS }));
        assert_eq!(w.pop(), Some(Op::Load { addr: 64 }));
        assert_eq!(w.pop(), Some(Op::Store { addr: 128 }));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut w = Warp::new(0, vec![Op::Load { addr: 64 }, Op::Store { addr: 128 }], 4);
        assert_eq!(w.remaining(), 2);
        assert_eq!(w.peek(), Some(&Op::Load { addr: 64 }));
        assert_eq!(w.peek(), Some(&Op::Load { addr: 64 }), "peek is idempotent");
        // The lookahead slot holds one op pulled from the source.
        assert_eq!(w.remaining(), 2);
        assert_eq!(w.pop(), Some(Op::Load { addr: 64 }));
        assert_eq!(w.pop(), Some(Op::Store { addr: 128 }));
        assert_eq!(w.peek(), None);
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn source_backed_warp_streams_ops() {
        /// A source that yields `Load {addr: 64*i}` for i in 0..n without
        /// ever materializing the list.
        #[derive(Debug)]
        struct Counter {
            i: u64,
            n: u64,
        }
        impl OpSource for Counter {
            fn next_op(&mut self) -> Option<Op> {
                if self.i == self.n {
                    return None;
                }
                self.i += 1;
                Some(Op::Load { addr: 64 * (self.i - 1) })
            }
            fn remaining_hint(&self) -> usize {
                (self.n - self.i) as usize
            }
        }
        let mut w = Warp::from_source(0, Box::new(Counter { i: 0, n: 3 }), 4);
        assert_eq!(w.remaining(), 3);
        assert_eq!(w.peek(), Some(&Op::Load { addr: 0 }));
        assert_eq!(w.remaining(), 3, "lookahead still counted");
        assert_eq!(w.pop(), Some(Op::Load { addr: 0 }));
        assert_eq!(w.pop(), Some(Op::Load { addr: 64 }));
        assert_eq!(w.pop(), Some(Op::Load { addr: 128 }));
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn mlp_limits_outstanding_loads() {
        let mut w = Warp::new(0, vec![], 2);
        assert!(w.can_issue_load());
        w.issue_load();
        w.issue_load();
        assert!(!w.can_issue_load());
        w.complete_load();
        assert!(w.can_issue_load());
    }

    #[test]
    fn completion_wakes_waiting_warp() {
        let mut w = Warp::new(0, vec![], 1);
        w.issue_load();
        w.waiting = true;
        assert!(w.complete_load(), "waiting warp must be woken");
        assert!(!w.waiting);
        w.issue_load();
        assert!(!w.complete_load(), "non-waiting warp needs no wake");
    }

    #[test]
    fn refill_restarts_a_drained_warp() {
        let mut w = Warp::new(0, vec![Op::Load { addr: 64 }], 2);
        assert_eq!(w.pop(), Some(Op::Load { addr: 64 }));
        assert_eq!(w.peek(), None, "first stream drained");
        w.waiting = true;
        w.refill(Box::new(VecDeque::from(vec![Op::Store { addr: 128 }])));
        assert!(!w.waiting, "refill clears the stall flag");
        assert_eq!(w.peek(), Some(&Op::Store { addr: 128 }));
        assert_eq!(w.remaining(), 1);
    }

    #[test]
    fn refill_discards_stale_lookahead() {
        let mut w = Warp::new(0, vec![Op::Load { addr: 64 }, Op::Load { addr: 192 }], 2);
        assert_eq!(w.peek(), Some(&Op::Load { addr: 64 }), "lookahead filled");
        w.refill(Box::new(VecDeque::from(vec![Op::Compute { dur: NS }])));
        assert_eq!(w.pop(), Some(Op::Compute { dur: NS }), "old lookahead dropped");
        assert_eq!(w.pop(), None);
    }

    #[test]
    fn stats_count_issues() {
        let mut w = Warp::new(0, vec![], 8);
        w.issue_load();
        w.issue_load();
        assert_eq!(w.stats.loads, 2);
        w.finish(42);
        assert!(w.done);
        assert_eq!(w.stats.finish, 42);
    }
}
