//! Set-associative write-back LLC with MSHRs.
//!
//! The Vortex LLC between the SMs and the system bus (Fig. 5a). Misses
//! allocate an MSHR; further accesses to an in-flight line merge into it.
//! Dirty victims produce writebacks that the memory system must absorb —
//! the path that makes SSD tail latency visible to reads (Fig. 9e) and
//! that the DS engine exists to decouple.
//!
//! Hot-path discipline (see DESIGN.md §7): the steady state allocates
//! nothing. Ways live in one flat array (set-major), MSHR waiters are
//! intrusive chains over a free-listed arena instead of a `Vec` per miss,
//! fills drain into a caller-owned scratch buffer ([`Llc::fill_into`]),
//! and the MSHR map uses the deterministic Fx hasher.

use crate::sim::{Time, NS};
use crate::util::hash::FxHashMap;

use super::{line_of, LINE};

/// LLC geometry + timing.
#[derive(Debug, Clone, Copy)]
pub struct LlcConfig {
    pub capacity: u64,
    pub ways: usize,
    /// Hit service latency.
    pub hit_lat: Time,
    /// Max in-flight misses (global MSHR count).
    pub mshrs: usize,
}

impl LlcConfig {
    /// Vortex-scale default: 2 MiB, 16-way, 5 ns hits. The in-flight-miss
    /// window is sized like a replayable-fault buffer (4096) rather than
    /// a classic MSHR file so every strategy sees the same concurrency
    /// envelope — EP-side limits (port memory queues, media channels)
    /// provide the real backpressure.
    pub fn default_vortex() -> LlcConfig {
        LlcConfig { capacity: 2 << 20, ways: 16, hit_lat: 5 * NS, mshrs: 4096 }
    }

    pub fn sets(&self) -> usize {
        (self.capacity / LINE) as usize / self.ways
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct WayState {
    tag: u64,
    valid: bool,
    dirty: bool,
    last_use: u64,
}

/// Outcome of an LLC lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessResult {
    /// Served by the cache at the returned time.
    Hit { done: Time },
    /// Line must be fetched; an MSHR was allocated. The caller routes the
    /// fill. `victim_writeback` carries a dirty victim line address that
    /// must be written back to memory.
    Miss { victim_writeback: Option<u64> },
    /// Line already being fetched: merged into the existing MSHR.
    MergedMiss,
    /// All MSHRs busy: the access must retry after `free_at`.
    MshrFull { free_at: Time },
}

/// Sentinel for "no next waiter" in the arena chains.
const NIL: u32 = u32::MAX;

/// One MSHR's waiter chain: head/tail indices into the arena. Appending
/// at the tail and draining from the head preserves request order, which
/// is part of the deterministic-wakeup contract.
#[derive(Debug, Clone, Copy)]
struct WaiterChain {
    head: u32,
    tail: u32,
}

#[derive(Debug, Clone, Copy)]
struct WaiterNode {
    req: u64,
    next: u32,
}

/// Free-listed arena of waiter nodes: misses and merges reuse slots freed
/// by earlier fills, so the steady state never touches the allocator.
#[derive(Debug)]
struct WaiterArena {
    nodes: Vec<WaiterNode>,
    free_head: u32,
}

impl WaiterArena {
    fn new() -> WaiterArena {
        WaiterArena { nodes: Vec::new(), free_head: NIL }
    }

    fn alloc(&mut self, req: u64) -> u32 {
        if self.free_head != NIL {
            let i = self.free_head;
            self.free_head = self.nodes[i as usize].next;
            self.nodes[i as usize] = WaiterNode { req, next: NIL };
            i
        } else {
            self.nodes.push(WaiterNode { req, next: NIL });
            (self.nodes.len() - 1) as u32
        }
    }

    fn free(&mut self, i: u32) {
        self.nodes[i as usize].next = self.free_head;
        self.free_head = i;
    }
}

/// The last-level cache.
#[derive(Debug)]
pub struct Llc {
    cfg: LlcConfig,
    num_sets: usize,
    /// Flat set-major way array (`set * cfg.ways + way`): one allocation,
    /// cache-friendly scans.
    ways: Vec<WayState>,
    tick: u64,
    /// line -> waiter chain for in-flight fills.
    mshr: FxHashMap<u64, WaiterChain>,
    waiters: WaiterArena,
    /// Earliest time an MSHR frees (conservative bookkeeping for retry).
    mshr_free_hint: Time,
    pub stats: LlcStats,
}

#[derive(Debug, Clone, Default)]
pub struct LlcStats {
    pub hits: u64,
    pub misses: u64,
    pub merged: u64,
    pub writebacks: u64,
    pub mshr_stalls: u64,
}

impl LlcStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.merged;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl Llc {
    pub fn new(cfg: LlcConfig) -> Llc {
        let num_sets = cfg.sets();
        assert!(num_sets.is_power_of_two(), "set count must be a power of two");
        Llc {
            cfg,
            num_sets,
            ways: vec![WayState::default(); num_sets * cfg.ways],
            tick: 0,
            mshr: FxHashMap::default(),
            waiters: WaiterArena::new(),
            mshr_free_hint: 0,
            stats: LlcStats::default(),
        }
    }

    fn set_and_tag(&self, line: u64) -> (usize, u64) {
        let idx = (line / LINE) as usize & (self.num_sets - 1);
        (idx, line)
    }

    #[inline]
    fn set_mut(&mut self, set_idx: usize) -> &mut [WayState] {
        let w = self.cfg.ways;
        &mut self.ways[set_idx * w..(set_idx + 1) * w]
    }

    /// Look up `addr` at time `now`. For writes, a hit marks the line
    /// dirty; a write miss write-allocates (fill then dirty).
    pub fn access(&mut self, now: Time, addr: u64, is_write: bool, req_id: u64) -> AccessResult {
        self.tick += 1;
        let tick = self.tick;
        let line = line_of(addr);
        let (set_idx, tag) = self.set_and_tag(line);

        // In-flight? Must be checked before the hit scan: lines are
        // installed at allocate time but their data arrives with the
        // fill, so accesses to a pending line merge into its MSHR.
        let ways = self.cfg.ways;
        if let Some(chain) = self.mshr.get_mut(&line) {
            let node = self.waiters.alloc(req_id);
            self.waiters.nodes[chain.tail as usize].next = node;
            chain.tail = node;
            self.stats.merged += 1;
            if is_write {
                for way in &mut self.ways[set_idx * ways..(set_idx + 1) * ways] {
                    if way.valid && way.tag == tag {
                        way.dirty = true;
                    }
                }
            }
            return AccessResult::MergedMiss;
        }

        // Hit? (field-level slice borrow so stats stay accessible)
        for way in &mut self.ways[set_idx * ways..(set_idx + 1) * ways] {
            if way.valid && way.tag == tag {
                way.last_use = tick;
                if is_write {
                    way.dirty = true;
                }
                self.stats.hits += 1;
                return AccessResult::Hit { done: now + self.cfg.hit_lat };
            }
        }

        // Coalesced full-line store miss: install the line dirty without
        // fetching it (write-validate — GPU L2s do not read-for-ownership
        // on full-line writes). No MSHR, no fill; only the victim needs
        // writing back.
        if is_write {
            self.stats.misses += 1;
            let victim = self.evict_for(set_idx, tag, true);
            return AccessResult::Miss { victim_writeback: victim };
        }

        // MSHR available?
        if self.mshr.len() >= self.cfg.mshrs {
            self.stats.mshr_stalls += 1;
            let hint = self.mshr_free_hint.max(now + self.cfg.hit_lat);
            return AccessResult::MshrFull { free_at: hint };
        }
        let node = self.waiters.alloc(req_id);
        self.mshr.insert(line, WaiterChain { head: node, tail: node });
        self.stats.misses += 1;

        // Victim selection happens now so the writeback can start with the
        // fill (standard eviction-on-allocate).
        let victim = self.evict_for(set_idx, tag, false);
        AccessResult::Miss { victim_writeback: victim }
    }

    /// Pick (and replace) the LRU way for an incoming line. Returns the
    /// dirty victim's line address, if any.
    fn evict_for(&mut self, set_idx: usize, tag: u64, incoming_dirty: bool) -> Option<u64> {
        let tick = self.tick;
        let set = self.set_mut(set_idx);
        // Prefer an invalid way.
        let way_idx = if let Some(i) = set.iter().position(|w| !w.valid) {
            i
        } else {
            set.iter()
                .enumerate()
                .min_by_key(|(_, w)| w.last_use)
                .map(|(i, _)| i)
                .unwrap()
        };
        let victim = &mut set[way_idx];
        let wb = if victim.valid && victim.dirty { Some(victim.tag) } else { None };
        *victim = WayState { tag, valid: true, dirty: incoming_dirty, last_use: tick };
        if wb.is_some() {
            self.stats.writebacks += 1;
        }
        wb
    }

    /// A fill returned from memory: release the MSHR and append the
    /// waiting request ids, in arrival order, to `out` (cleared first).
    /// The line itself was installed at `access` time. Waiter nodes go
    /// straight back to the free list — no allocation either way.
    pub fn fill_into(&mut self, line: u64, fill_done: Time, out: &mut Vec<u64>) {
        out.clear();
        self.mshr_free_hint = self.mshr_free_hint.max(fill_done);
        if let Some(chain) = self.mshr.remove(&line_of(line)) {
            let mut i = chain.head;
            while i != NIL {
                let node = self.waiters.nodes[i as usize];
                out.push(node.req);
                self.waiters.free(i);
                i = node.next;
            }
        }
    }

    /// Allocating convenience wrapper around [`Llc::fill_into`] for tests
    /// and cold paths.
    pub fn fill(&mut self, line: u64, fill_done: Time) -> Vec<u64> {
        let mut out = Vec::new();
        self.fill_into(line, fill_done, &mut out);
        out
    }

    pub fn inflight(&self) -> usize {
        self.mshr.len()
    }

    /// Number of valid lines (for occupancy assertions).
    pub fn resident_lines(&self) -> usize {
        self.ways.iter().filter(|w| w.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llc() -> Llc {
        Llc::new(LlcConfig { capacity: 64 * LINE * 4, ways: 4, hit_lat: 5 * NS, mshrs: 4 })
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut c = llc();
        match c.access(0, 0x1000, false, 1) {
            AccessResult::Miss { victim_writeback: None } => {}
            r => panic!("expected clean miss, got {r:?}"),
        }
        let waiters = c.fill(0x1000, 100);
        assert_eq!(waiters, vec![1]);
        match c.access(200, 0x1000, false, 2) {
            AccessResult::Hit { done } => assert_eq!(done, 200 + 5 * NS),
            r => panic!("expected hit, got {r:?}"),
        }
    }

    #[test]
    fn inflight_misses_merge() {
        let mut c = llc();
        c.access(0, 0x2000, false, 1);
        match c.access(1, 0x2010, false, 2) {
            AccessResult::MergedMiss => {}
            r => panic!("expected merge (same line), got {r:?}"),
        }
        let waiters = c.fill(0x2000, 50);
        assert_eq!(waiters, vec![1, 2]);
    }

    #[test]
    fn mshr_exhaustion_backpressures() {
        let mut c = llc();
        for i in 0..4u64 {
            c.access(0, i * 0x10000, false, i);
        }
        match c.access(0, 0x90000, false, 99) {
            AccessResult::MshrFull { .. } => {}
            r => panic!("expected MshrFull, got {r:?}"),
        }
        assert_eq!(c.stats.mshr_stalls, 1);
    }

    #[test]
    fn dirty_eviction_produces_writeback() {
        let mut c = llc();
        // Fill all 4 ways of set 0 with dirty lines. Set index uses
        // (line/64) % sets; sets = 64. Stride of 64*64 bytes maps to the
        // same set.
        let stride = 64 * LINE;
        for i in 0..4u64 {
            c.access(0, i * stride, true, i);
            c.fill(i * stride, 10);
        }
        // Fifth distinct line in the same set evicts the LRU dirty line.
        match c.access(100, 4 * stride, false, 9) {
            AccessResult::Miss { victim_writeback: Some(victim) } => {
                assert_eq!(victim, 0, "LRU victim should be the first line");
            }
            r => panic!("expected dirty eviction, got {r:?}"),
        }
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = llc();
        c.access(0, 0x3000, false, 1);
        c.fill(0x3000, 10);
        c.access(20, 0x3000, true, 2); // write hit -> dirty
        // Evict it by filling the set with four distinct same-set lines.
        let stride = 64 * LINE;
        for i in 1..=4u64 {
            c.access(100, 0x3000 + i * stride, false, 10 + i);
            c.fill(0x3000 + i * stride, 110);
        }
        assert!(c.stats.writebacks >= 1, "dirty line should have been written back");
    }

    #[test]
    fn lru_prefers_invalid_ways() {
        let mut c = llc();
        c.access(0, 0x0, false, 1);
        c.fill(0x0, 5);
        // Second line in same set must not evict the first (3 ways free).
        match c.access(10, 64 * LINE, false, 2) {
            AccessResult::Miss { victim_writeback: None } => {}
            r => panic!("unexpected {r:?}"),
        }
        assert_eq!(c.resident_lines(), 2);
    }

    #[test]
    fn hit_rate_accounts_all_outcomes() {
        let mut c = llc();
        c.access(0, 0x0, false, 1);
        c.fill(0x0, 5);
        c.access(10, 0x0, false, 2);
        c.access(10, 0x0, false, 3);
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.misses, 1);
        assert!((c.stats.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn waiter_arena_recycles_nodes() {
        let mut c = llc();
        let mut scratch = Vec::new();
        // Churn misses + merges through fills: the arena must stop
        // growing once the first generation of nodes is freed.
        for round in 0..50u64 {
            let addr = round * 0x10000;
            c.access(0, addr, false, 1);
            c.access(0, addr + 8, false, 2);
            c.access(0, addr + 16, false, 3);
            c.fill_into(addr, 10, &mut scratch);
            assert_eq!(scratch, vec![1, 2, 3], "round {round}: waiter order");
        }
        assert!(
            c.waiters.nodes.len() <= 3,
            "arena grew to {} nodes despite recycling",
            c.waiters.nodes.len()
        );
    }

    #[test]
    fn fill_into_clears_stale_scratch() {
        let mut c = llc();
        let mut scratch = vec![42, 43];
        c.fill_into(0x5000, 10, &mut scratch); // no such MSHR
        assert!(scratch.is_empty());
    }
}
