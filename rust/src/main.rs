//! `cxl-gpu` — CLI launcher for the CXL-GPU reproduction.
//!
//! Subcommands:
//!   run          one (workload, config, media) simulation
//!   suite        all 13 workloads under one config
//!   experiments  reproduce the paper's figures/tables (--fig to select)
//!   latency      Fig. 3b controller round-trip comparison
//!   execute      run an AOT workload artifact through PJRT (real compute)
//!   list         show workloads, configs, media

use cxl_gpu::coordinator::config::{media_from_name, SystemConfig};
use cxl_gpu::coordinator::experiments::{self, Scale};
use cxl_gpu::coordinator::runner::run_suite;
use cxl_gpu::media::MediaKind;
use cxl_gpu::util::bench::Table;
use cxl_gpu::util::cli::{self, OptSpec};
use cxl_gpu::workloads::table1b::ALL_WORKLOADS;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(
        &argv,
        &[
            "workload", "config", "media", "ops", "fig", "toml", "artifacts", "seed", "json",
            "trace-out", "telemetry-out",
        ],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };

    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("suite") => cmd_suite(&args),
        Some("experiments") => cmd_experiments(&args),
        Some("latency") => {
            experiments::fig3b(true);
            Ok(())
        }
        Some("execute") => cmd_execute(&args),
        Some("list") => {
            cmd_list();
            Ok(())
        }
        _ => {
            print!("{}", usage());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    cli::usage(
        "cxl-gpu",
        "GPU memory expansion over CXL: full-system simulator + PJRT workload runtime",
        &[
            ("run", "simulate one workload under one configuration"),
            ("suite", "simulate all 13 workloads under one configuration"),
            ("experiments", "reproduce the paper's figures (--fig 3b|9a|9b|9c|9d|9e|table1b|headline|tier|mt|cache|ras|serve|pool-scale|obs|telemetry)"),
            ("latency", "Fig. 3b controller round-trip comparison"),
            ("execute", "run an AOT workload artifact via PJRT (real compute)"),
            ("list", "show workloads, configurations and media"),
        ],
        &[
            OptSpec { name: "workload", help: "workload name (see `list`)", takes_value: true },
            OptSpec { name: "config", help: "configuration name (default cxl-sr)", takes_value: true },
            OptSpec { name: "media", help: "dram|optane|znand|nand (default znand)", takes_value: true },
            OptSpec { name: "ops", help: "total dynamic ops (default 300000)", takes_value: true },
            OptSpec { name: "fig", help: "figure selector for `experiments`", takes_value: true },
            OptSpec { name: "toml", help: "TOML config file with [sim] overrides", takes_value: true },
            OptSpec { name: "artifacts", help: "artifacts dir for `execute` (default artifacts/)", takes_value: true },
            OptSpec { name: "trace-out", help: "with --fig obs: write a Chrome/Perfetto trace JSON here", takes_value: true },
            OptSpec { name: "telemetry-out", help: "with --fig telemetry: write JSONL frames here (+ `.prom` Prometheus exposition)", takes_value: true },
            OptSpec { name: "quick", help: "smaller sweeps for experiments", takes_value: false },
        ],
    )
}

fn parse_media(args: &cxl_gpu::util::cli::Args) -> Result<MediaKind, String> {
    let name = args.get_or("media", "znand");
    media_from_name(name).ok_or_else(|| format!("unknown media `{name}`"))
}

fn cmd_run(args: &cxl_gpu::util::cli::Args) -> Result<(), String> {
    let workload = args.get_or("workload", "vadd");
    let config = args.get_or("config", "cxl-sr");
    let media = parse_media(args)?;
    // Config-path errors (unknown names, TOML overrides describing an
    // impossible topology) surface as messages, not panics.
    let mut cfg = SystemConfig::try_named(config, media)?;
    if let Some(path) = args.get("toml") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        cfg.apply_toml(&cxl_gpu::util::toml::parse(&text)?);
    }
    cfg.total_ops = args.get_u64("ops", cfg.total_ops as u64)? as usize;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let spec = cxl_gpu::workloads::table1b::spec(workload);
    let metrics = cxl_gpu::coordinator::system::System::try_new(spec, &cfg)?.run();
    println!("{} on {} ({}): {}", workload, config, media.name(), metrics.summary_line());
    Ok(())
}

fn cmd_suite(args: &cxl_gpu::util::cli::Args) -> Result<(), String> {
    let config = args.get_or("config", "cxl-sr");
    let media = parse_media(args)?;
    SystemConfig::try_named(config, media)?; // fail with a message, not a panic
    let ops = args.get_u64("ops", 120_000)? as usize;
    let results = run_suite(config, media, Some(ops));
    if let Some(path) = args.get("json") {
        write_json_report(path, config, &results)?;
        println!("wrote {path}");
    }
    let mut t = Table::new(
        &format!("suite: {config} on {}", media.name()),
        &["workload", "exec (ms)", "load avg", "llc hit", "ep hit", "faults", "gc"],
    );
    for r in &results {
        t.rowv(vec![
            r.workload.into(),
            format!("{:.3}", r.metrics.exec_ms()),
            format!("{:.1} µs", r.metrics.load_latency.mean() / 1e6),
            format!("{:.1}%", r.metrics.llc.hit_rate() * 100.0),
            format!("{:.1}%", r.metrics.ep_hit_rate() * 100.0),
            r.metrics.faults.to_string(),
            r.metrics.gc_episodes.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_experiments(args: &cxl_gpu::util::cli::Args) -> Result<(), String> {
    let scale = if args.has_flag("quick") { Scale::quick() } else { Scale::default() };
    let which = args.get_or("fig", "all");
    let run_one = |f: &str| -> Result<(), String> {
        match f {
            "3b" => {
                experiments::fig3b(true);
            }
            "table1b" => {
                experiments::table1b(true);
            }
            "9a" => {
                experiments::fig9a(scale, true);
            }
            "9b" => {
                experiments::fig9b(scale, true);
            }
            "9c" => {
                experiments::fig9c(scale, true);
            }
            "9d" => {
                experiments::fig9d(scale, true);
            }
            "9e" => {
                experiments::fig9e(scale, true);
            }
            "headline" => {
                experiments::headline(scale, true);
            }
            "tier" => {
                experiments::tiering(scale, true);
            }
            "mt" | "fabric" => {
                experiments::multi_tenant(scale, true);
            }
            "cache" => {
                experiments::expander_cache(scale, true);
            }
            "ras" => {
                experiments::ras(scale, true);
            }
            "serve" => {
                experiments::serve(scale, true);
            }
            "pool-scale" => {
                experiments::pool_scale(scale, true);
            }
            "obs" => {
                let sweep = experiments::obs(scale, true);
                if let Some(path) = args.get("trace-out") {
                    let reports: Vec<(String, cxl_gpu::obs::ObsReport)> = sweep
                        .rows
                        .iter()
                        .map(|r| (r.name.to_string(), r.report.clone()))
                        .collect();
                    let json = cxl_gpu::obs::chrome_trace(&reports);
                    cxl_gpu::util::json::write_file(path, &json)?;
                    println!("wrote {path} (chrome://tracing / Perfetto trace-event JSON)");
                }
            }
            "telemetry" => {
                let sweep = experiments::telemetry(scale, true);
                if let Some(path) = args.get("telemetry-out") {
                    let runs = sweep.runs();
                    let mut lines = String::new();
                    for (name, rep) in &runs {
                        lines.push_str(&cxl_gpu::telemetry::jsonl(name, rep));
                    }
                    std::fs::write(path, lines).map_err(|e| format!("{path}: {e}"))?;
                    let prom = format!("{path}.prom");
                    std::fs::write(&prom, cxl_gpu::telemetry::prometheus(&runs))
                        .map_err(|e| format!("{prom}: {e}"))?;
                    println!("wrote {path} (JSONL frames) and {prom} (Prometheus exposition)");
                }
            }
            other => return Err(format!("unknown figure `{other}`")),
        }
        Ok(())
    };
    if which == "all" {
        for f in [
            "3b", "table1b", "9a", "9b", "9c", "9d", "9e", "headline", "tier", "mt", "cache",
            "ras", "serve", "pool-scale", "obs", "telemetry",
        ] {
            run_one(f)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

#[cfg(feature = "pjrt")]
fn cmd_execute(args: &cxl_gpu::util::cli::Args) -> Result<(), String> {
    let dir = args.get_or("artifacts", "artifacts");
    let workload = args.get_or("workload", "vadd");
    let rt = cxl_gpu::runtime::Runtime::load(dir).map_err(|e| e.to_string())?;
    let out = rt.execute_named(workload, 42).map_err(|e| e.to_string())?;
    println!(
        "{workload}: executed via PJRT ({} outputs) — checksum {:.6}, {} elements",
        out.outputs, out.checksum, out.elements
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_execute(_args: &cxl_gpu::util::cli::Args) -> Result<(), String> {
    Err("this build has no PJRT runtime; rebuild with `--features pjrt` to execute artifacts".into())
}

fn cmd_list() {
    println!("workloads (Table 1b):");
    for w in ALL_WORKLOADS {
        println!(
            "  {:8} {:18} compute {:.1}% load {:.1}%",
            w.name,
            w.category.name(),
            w.compute_ratio * 100.0,
            w.load_ratio * 100.0
        );
    }
    println!("\nconfigurations: {}", SystemConfig::known_names().join(", "));
    println!("media: dram, optane, znand, nand");
}


/// Emit a machine-readable run report (consumed by external tooling and
/// by EXPERIMENTS.md bookkeeping).
fn write_json_report(
    path: &str,
    config: &str,
    results: &[cxl_gpu::coordinator::runner::RunResult],
) -> Result<(), String> {
    use cxl_gpu::util::json::{write_file, Json, JsonObj};
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            JsonObj::new()
                .set("workload", r.workload)
                .set("config", r.config.clone())
                .set("media", r.media.name())
                .set("exec_ms", r.metrics.exec_ms())
                .set("load_lat_ns", r.metrics.load_latency.mean() / 1e3)
                .set("llc_hit", r.metrics.llc.hit_rate())
                .set("ep_hit", r.metrics.ep_hit_rate())
                .set("faults", r.metrics.faults)
                .set("gc_episodes", r.metrics.gc_episodes)
                .set("sr_issued", r.metrics.sr_issued)
                .build()
        })
        .collect();
    let doc = JsonObj::new().set("suite", config).set("results", rows).build();
    write_file(path, &doc)
}