//! CXL.io configuration space and HDM capability registers.
//!
//! The paper's simplified core performs EP enumeration at initialization:
//! "firmware identifies CXL EPs by examining their configuration space
//! and PCIe BARs. It aggregates each EP's memory address space by
//! analyzing the HDM capability registers" (§System configuration). This
//! module models that handshake: a little register file per EP exposing
//! DVSEC-style identity + HDM decoder capability, and the firmware walk
//! that reads them to program the host bridge.

use crate::media::MediaKind;

/// PCIe/CXL identity registers (subset the firmware reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConfigSpace {
    pub vendor_id: u16,
    pub device_id: u16,
    /// CXL DVSEC revision: 2 = CXL 2.0, 3 = CXL 3.x.
    pub cxl_dvsec_rev: u8,
    /// Device supports CXL.mem.
    pub mem_capable: bool,
    /// Device supports the MemSpecRd opcode (CXL 2.0+ feature).
    pub spec_rd_capable: bool,
    /// HDM capability: decoded memory size in 256 MiB units on real
    /// hardware; here raw bytes for the scaled simulator.
    pub hdm_size: u64,
    /// Media class advertised through vendor DVSEC (drives firmware's
    /// choice of SR/DS applicability).
    pub media: MediaKind,
}

impl ConfigSpace {
    /// The register image a DRAM expander EP exposes.
    pub fn dram_ep(hdm_size: u64) -> ConfigSpace {
        ConfigSpace {
            vendor_id: 0x1AC1, // "Panmnesia" stand-in vendor id
            device_id: 0x0D3A,
            cxl_dvsec_rev: 3,
            mem_capable: true,
            spec_rd_capable: true,
            hdm_size,
            media: MediaKind::Ddr5,
        }
    }

    /// The register image an SSD-backed EP exposes.
    pub fn ssd_ep(hdm_size: u64, media: MediaKind) -> ConfigSpace {
        debug_assert!(media.is_ssd());
        ConfigSpace {
            vendor_id: 0x1AC1,
            device_id: 0x055D,
            cxl_dvsec_rev: 3,
            mem_capable: true,
            spec_rd_capable: true,
            hdm_size,
            media,
        }
    }

    /// Is this a CXL memory expander the root complex can map?
    pub fn is_hdm_capable(&self) -> bool {
        self.mem_capable && self.cxl_dvsec_rev >= 2 && self.hdm_size > 0
    }

    /// Raw dword read at a config-space offset (firmware-facing view).
    /// Layout (dword index):
    ///   0: vendor/device id    1: DVSEC rev + capability bits
    ///   2: HDM size low        3: HDM size high
    pub fn read_dword(&self, index: u32) -> u32 {
        match index {
            0 => (self.device_id as u32) << 16 | self.vendor_id as u32,
            1 => {
                (self.cxl_dvsec_rev as u32)
                    | (self.mem_capable as u32) << 8
                    | (self.spec_rd_capable as u32) << 9
            }
            2 => (self.hdm_size & 0xFFFF_FFFF) as u32,
            3 => (self.hdm_size >> 32) as u32,
            _ => 0xFFFF_FFFF, // unimplemented register
        }
    }

    /// Decode a register image read back over CXL.io (the inverse of
    /// [`Self::read_dword`], as the firmware reconstructs it).
    pub fn from_dwords(d0: u32, d1: u32, d2: u32, d3: u32, media: MediaKind) -> ConfigSpace {
        ConfigSpace {
            vendor_id: (d0 & 0xFFFF) as u16,
            device_id: (d0 >> 16) as u16,
            cxl_dvsec_rev: (d1 & 0xFF) as u8,
            mem_capable: d1 & (1 << 8) != 0,
            spec_rd_capable: d1 & (1 << 9) != 0,
            hdm_size: d2 as u64 | (d3 as u64) << 32,
            media,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_roundtrip() {
        let cs = ConfigSpace::ssd_ep(10 << 30, MediaKind::Znand);
        let back = ConfigSpace::from_dwords(
            cs.read_dword(0),
            cs.read_dword(1),
            cs.read_dword(2),
            cs.read_dword(3),
            MediaKind::Znand,
        );
        assert_eq!(cs, back);
    }

    #[test]
    fn hdm_capability_gates() {
        assert!(ConfigSpace::dram_ep(1 << 20).is_hdm_capable());
        let mut cs = ConfigSpace::dram_ep(1 << 20);
        cs.hdm_size = 0;
        assert!(!cs.is_hdm_capable());
        cs = ConfigSpace::dram_ep(1 << 20);
        cs.cxl_dvsec_rev = 1; // CXL 1.1: no MemSpecRd, no HDM ranges here
        assert!(!cs.is_hdm_capable());
    }

    #[test]
    fn unimplemented_registers_read_ffffffff() {
        let cs = ConfigSpace::dram_ep(4096);
        assert_eq!(cs.read_dword(9), 0xFFFF_FFFF);
    }

    #[test]
    fn large_hdm_sizes_span_two_dwords() {
        let cs = ConfigSpace::dram_ep(5 << 32);
        let lo = cs.read_dword(2) as u64;
        let hi = cs.read_dword(3) as u64;
        assert_eq!(lo | hi << 32, 5 << 32);
    }
}
