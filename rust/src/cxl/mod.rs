//! CXL protocol substrate: flits, sub-protocol opcodes, QoS telemetry
//! (DevLoad), and the layered controller latency model.
//!
//! The paper's contribution here is a siliconized controller whose
//! phy/link/transaction stack achieves a **two-digit-nanosecond** round
//! trip (Fig. 3b) versus ~250 ns for the PCIe-derived controllers behind
//! the SMT and TPP prototypes. We model each hardware layer's one-way
//! cost explicitly so the benches can report per-layer breakdowns exactly
//! as Fig. 3a draws them.

pub mod config_space;
pub mod controller;
pub mod devload;
pub mod flit;
pub mod replay;

pub use config_space::ConfigSpace;
pub use controller::{ControllerKind, CxlController, LayerCosts};
pub use devload::DevLoad;
pub use flit::{Flit, MemOpcode, FLIT_DATA_BYTES, SPECRD_OFFSET_UNIT};
pub use replay::{Attempt, ReplayBuffer, ReplayStats};
