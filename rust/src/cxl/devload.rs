//! CXL QoS telemetry: the 2-bit `DevLoad` field.
//!
//! Every CXL.mem completion carries a DevLoad indication classifying the
//! endpoint's instantaneous load (CXL 3.1 §3.3.4). The paper's queue
//! logic uses it to modulate SpecRd granularity/rate and to throttle
//! writes around SSD internal tasks (GC), so the model computes it from
//! ingress-queue occupancy plus an internal-task flag, exactly the two
//! signals the paper says the EP folds in.

/// The four DevLoad states of the CXL standard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DevLoad {
    /// Light load: spare bandwidth available (paper: grow SR granularity).
    Light,
    /// Optimal load: at capacity without queueing (hold granularity).
    Optimal,
    /// Moderate overload: queue building up (shrink SR granularity).
    Moderate,
    /// Severe overload: queue saturated or internal task running (halt SR,
    /// divert writes).
    Severe,
}

impl DevLoad {
    /// Classify from ingress-queue occupancy and the internal-task flag.
    ///
    /// Thresholds follow the usual quartile telemetry encoding: <25 %
    /// light, <50 % optimal, <75 % moderate, else severe. An active
    /// internal task (GC, wear-leveling) reports at least Moderate, and
    /// Severe once it also has a backlog — the paper's EP "reports this
    /// condition through the DevLoad field *before* scheduling the task".
    pub fn classify(occupancy: usize, capacity: usize, internal_task: bool) -> DevLoad {
        debug_assert!(capacity > 0);
        let frac = occupancy as f64 / capacity as f64;
        let base = if frac < 0.25 {
            DevLoad::Light
        } else if frac < 0.50 {
            DevLoad::Optimal
        } else if frac < 0.75 {
            DevLoad::Moderate
        } else {
            DevLoad::Severe
        };
        if internal_task {
            // Internal tasks are pre-announced as Severe so write traffic
            // diverts *before* the stall (§Fine control for internal
            // tasks: the EP reports the condition before scheduling it).
            DevLoad::Severe
        } else {
            base
        }
    }

    /// [`DevLoad::classify`] with the expander cache's writeback-drain
    /// backlog folded in (DESIGN.md §14): queued dirty-eviction
    /// writebacks are ingress work the endpoint still owes its media,
    /// so the reported class is the worse of the queue-occupancy class
    /// and the drain-backlog class. With an empty drain queue this is
    /// exactly [`DevLoad::classify`] — which is what keeps uncached
    /// (and zero-capacity-cache) endpoints bit-identical.
    pub fn classify_with_drain(
        occupancy: usize,
        capacity: usize,
        wb_pending: usize,
        wb_capacity: usize,
        internal_task: bool,
    ) -> DevLoad {
        let base = DevLoad::classify(occupancy, capacity, internal_task);
        if wb_pending == 0 {
            return base;
        }
        base.max(DevLoad::classify(wb_pending, wb_capacity.max(1), false))
    }

    /// Two-bit wire encoding (00=light per the paper's "light load (11)"
    /// typo normalized to spec order: we use spec order L=0,O=1,M=2,S=3).
    pub fn encode(self) -> u8 {
        match self {
            DevLoad::Light => 0b00,
            DevLoad::Optimal => 0b01,
            DevLoad::Moderate => 0b10,
            DevLoad::Severe => 0b11,
        }
    }

    pub fn decode(bits: u8) -> DevLoad {
        match bits & 0b11 {
            0b00 => DevLoad::Light,
            0b01 => DevLoad::Optimal,
            0b10 => DevLoad::Moderate,
            _ => DevLoad::Severe,
        }
    }

    /// True if the EP asks requesters to back off (moderate or severe).
    pub fn overloaded(self) -> bool {
        self >= DevLoad::Moderate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_quartiles() {
        assert_eq!(DevLoad::classify(0, 64, false), DevLoad::Light);
        assert_eq!(DevLoad::classify(15, 64, false), DevLoad::Light);
        assert_eq!(DevLoad::classify(16, 64, false), DevLoad::Optimal);
        assert_eq!(DevLoad::classify(32, 64, false), DevLoad::Moderate);
        assert_eq!(DevLoad::classify(48, 64, false), DevLoad::Severe);
        assert_eq!(DevLoad::classify(64, 64, false), DevLoad::Severe);
    }

    #[test]
    fn internal_task_is_always_severe() {
        assert_eq!(DevLoad::classify(0, 64, true), DevLoad::Severe);
        assert_eq!(DevLoad::classify(20, 64, true), DevLoad::Severe);
        assert_eq!(DevLoad::classify(60, 64, true), DevLoad::Severe);
    }

    #[test]
    fn drain_backlog_raises_the_class_and_empty_backlog_is_identity() {
        // No backlog: identical to plain classify at every occupancy.
        for occ in [0usize, 16, 32, 48, 64] {
            for task in [false, true] {
                assert_eq!(
                    DevLoad::classify_with_drain(occ, 64, 0, 64, task),
                    DevLoad::classify(occ, 64, task),
                );
            }
        }
        // A deep drain queue raises a lightly-loaded endpoint.
        assert_eq!(DevLoad::classify_with_drain(0, 64, 48, 64, false), DevLoad::Severe);
        assert_eq!(DevLoad::classify_with_drain(0, 64, 20, 64, false), DevLoad::Optimal);
        // But never lowers a loaded one.
        assert_eq!(DevLoad::classify_with_drain(48, 64, 1, 64, false), DevLoad::Severe);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for d in [DevLoad::Light, DevLoad::Optimal, DevLoad::Moderate, DevLoad::Severe] {
            assert_eq!(DevLoad::decode(d.encode()), d);
        }
    }

    #[test]
    fn ordering_and_overload() {
        assert!(DevLoad::Light < DevLoad::Optimal);
        assert!(DevLoad::Optimal < DevLoad::Moderate);
        assert!(DevLoad::Moderate < DevLoad::Severe);
        assert!(!DevLoad::Optimal.overloaded());
        assert!(DevLoad::Moderate.overloaded());
    }
}
