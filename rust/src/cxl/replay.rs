//! Link-level ack/replay buffer (DESIGN.md §15).
//!
//! CXL links run a retry protocol under the transaction layer: every
//! transmitted flit sequence is held in a replay buffer until the far
//! end acks it; a CRC-corrupted transfer is NAKed and replayed from the
//! buffer, and a transfer that exhausts its retry budget escalates to a
//! *poison* (the payload is declared lost and containment takes over).
//!
//! This model keeps the protocol a pure, deterministic state machine —
//! the fault draws live in [`crate::ras`], which feeds `corrupted`
//! verdicts in; property tests (`tests/props.rs`) drive it directly with
//! arbitrary corruption patterns to prove exactly-once, in-order
//! delivery and flit conservation:
//!
//! `sent == delivered + poisoned + in_flight` (all in flits), and every
//! completion (delivery *or* poison) pops in send order.

use std::collections::VecDeque;

/// One buffered transfer awaiting ack.
#[derive(Debug, Clone, Copy)]
struct Pending {
    seq: u64,
    flits: u64,
    /// Corrupted attempts so far.
    attempts: u32,
}

/// Conservation counters, all in flits (except `retries`, which counts
/// retry *attempts*).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayStats {
    /// Flits handed to [`ReplayBuffer::send`].
    pub sent: u64,
    /// Flits delivered exactly once.
    pub delivered: u64,
    /// Flits lost to retry exhaustion.
    pub poisoned: u64,
    /// Retry attempts (NAKed transfers replayed from the buffer).
    pub retries: u64,
    /// Flits re-transmitted across all retries.
    pub replayed_flits: u64,
}

/// Outcome of one transmission attempt on the head-of-line transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attempt {
    /// Nothing in flight.
    Idle,
    /// The head transfer was acked and retired — exactly once, in order.
    Delivered { seq: u64, flits: u64 },
    /// The head transfer was NAKed and stays buffered for replay.
    Retried { seq: u64 },
    /// The head transfer exhausted its retries and was dropped as
    /// poisoned — containment (re-fetch, DS copy) is the caller's job.
    Poisoned { seq: u64, flits: u64 },
}

/// Go-back-style replay buffer: transfers retire strictly in send order,
/// each exactly once (as a delivery or a poison, never both, never
/// twice).
#[derive(Debug)]
pub struct ReplayBuffer {
    max_retries: u32,
    next_seq: u64,
    /// Next sequence number that may retire; completions must match it.
    next_complete: u64,
    pending: VecDeque<Pending>,
    pub stats: ReplayStats,
}

impl ReplayBuffer {
    /// A buffer that allows `max_retries` replays per transfer before
    /// escalating to poison (0 = first corruption poisons immediately).
    pub fn new(max_retries: u32) -> ReplayBuffer {
        ReplayBuffer {
            max_retries,
            next_seq: 0,
            next_complete: 0,
            pending: VecDeque::new(),
            stats: ReplayStats::default(),
        }
    }

    /// Buffer a `flits`-flit transfer for transmission; returns its
    /// sequence number.
    pub fn send(&mut self, flits: u64) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.stats.sent += flits;
        self.pending.push_back(Pending { seq, flits, attempts: 0 });
        seq
    }

    /// One transmission attempt on the head-of-line transfer with the
    /// link's `corrupted` verdict for this pass.
    pub fn attempt(&mut self, corrupted: bool) -> Attempt {
        let Some(head) = self.pending.front_mut() else { return Attempt::Idle };
        if corrupted && head.attempts < self.max_retries {
            head.attempts += 1;
            let seq = head.seq;
            let flits = head.flits;
            self.stats.retries += 1;
            self.stats.replayed_flits += flits;
            return Attempt::Retried { seq };
        }
        // Retire the head — delivery on a clean pass, poison when the
        // corruption outlived the retry budget. Either way it completes
        // exactly once, in send order.
        let e = match self.pending.pop_front() {
            Some(e) => e,
            None => return Attempt::Idle, // unreachable: front checked above
        };
        debug_assert_eq!(e.seq, self.next_complete, "completion out of order");
        self.next_complete += 1;
        if corrupted {
            self.stats.poisoned += e.flits;
            Attempt::Poisoned { seq: e.seq, flits: e.flits }
        } else {
            self.stats.delivered += e.flits;
            Attempt::Delivered { seq: e.seq, flits: e.flits }
        }
    }

    /// Flits currently buffered (sent, not yet delivered or poisoned).
    pub fn in_flight(&self) -> u64 {
        self.pending.iter().map(|p| p.flits).sum()
    }

    /// Transfers currently buffered.
    pub fn pending_transfers(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_transfers_deliver_in_order_exactly_once() {
        let mut b = ReplayBuffer::new(3);
        for flits in [1u64, 4, 2] {
            b.send(flits);
        }
        for (want_seq, want_flits) in [(0u64, 1u64), (1, 4), (2, 2)] {
            match b.attempt(false) {
                Attempt::Delivered { seq, flits } => {
                    assert_eq!((seq, flits), (want_seq, want_flits));
                }
                other => panic!("expected delivery, got {other:?}"),
            }
        }
        assert_eq!(b.attempt(false), Attempt::Idle);
        assert_eq!(b.stats.sent, 7);
        assert_eq!(b.stats.delivered, 7);
        assert_eq!(b.stats.poisoned, 0);
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn corruption_retries_then_delivers() {
        let mut b = ReplayBuffer::new(3);
        b.send(5);
        assert_eq!(b.attempt(true), Attempt::Retried { seq: 0 });
        assert_eq!(b.attempt(true), Attempt::Retried { seq: 0 });
        assert_eq!(b.attempt(false), Attempt::Delivered { seq: 0, flits: 5 });
        assert_eq!(b.stats.retries, 2);
        assert_eq!(b.stats.replayed_flits, 10);
        assert_eq!(b.stats.delivered, 5);
    }

    #[test]
    fn retry_exhaustion_poisons() {
        let mut b = ReplayBuffer::new(2);
        b.send(3);
        assert_eq!(b.attempt(true), Attempt::Retried { seq: 0 });
        assert_eq!(b.attempt(true), Attempt::Retried { seq: 0 });
        assert_eq!(b.attempt(true), Attempt::Poisoned { seq: 0, flits: 3 });
        assert_eq!(b.stats.poisoned, 3);
        assert_eq!(b.in_flight(), 0);
        // Zero budget: first corruption poisons immediately.
        let mut z = ReplayBuffer::new(0);
        z.send(1);
        assert_eq!(z.attempt(true), Attempt::Poisoned { seq: 0, flits: 1 });
    }

    #[test]
    fn conservation_holds_mid_stream() {
        let mut b = ReplayBuffer::new(1);
        b.send(4);
        b.send(6);
        let _ = b.attempt(true); // retry seq 0
        let _ = b.attempt(true); // poison seq 0
        assert_eq!(
            b.stats.sent,
            b.stats.delivered + b.stats.poisoned + b.in_flight(),
            "sent = delivered + poisoned + in-flight"
        );
        assert_eq!(b.in_flight(), 6);
        let _ = b.attempt(false); // deliver seq 1
        assert_eq!(b.stats.sent, b.stats.delivered + b.stats.poisoned);
    }
}
