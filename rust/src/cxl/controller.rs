//! Layered CXL controller latency model (Fig. 3a / Fig. 4).
//!
//! A memory request crosses, in order: protocol conversion (memory op ->
//! flit), the transaction layer, the link layer, the Flex Bus physical
//! layer, the wire, and the mirror stack on the EP side. The paper's
//! silicon achieves a **two-digit-nanosecond** total round trip including
//! protocol conversion; SMT's and TPP's prototype controllers — which the
//! paper hypothesizes reuse PCIe-era designs — sit near 250 ns.
//!
//! [`LayerCosts`] carries per-layer one-way costs so the Fig. 3b bench can
//! print the same per-layer breakdown the paper draws, and so the root
//! port and EP reuse the *same* numbers (both embed this controller).

use crate::sim::{transfer_time, Time, NS};

use super::flit::Flit;

/// Which silicon the controller models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControllerKind {
    /// The paper's custom CXL-optimized silicon (tens of ns round trip).
    Panmnesia,
    /// PCIe-architecture-derived prototype controller (SMT, Samsung).
    Smt,
    /// PCIe-architecture-derived prototype controller (TPP, Meta).
    Tpp,
}

/// One-way per-layer traversal costs, in picoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerCosts {
    /// Standard memory op <-> CXL flit conversion (transaction-layer edge).
    pub protocol_conv: Time,
    /// Transaction layer (sub-protocol mux, ordering, credits).
    pub transaction: Time,
    /// Link layer (flow control, buffering, acks).
    pub link: Time,
    /// Flex Bus physical layer (PCS, elastic buffers, lane (de)striping).
    pub phy: Time,
}

impl LayerCosts {
    /// One-way stack traversal cost (excluding wire serialization).
    pub fn one_way(&self) -> Time {
        self.protocol_conv + self.transaction + self.link + self.phy
    }

    /// Costs for the paper's controller: tuned so the full round trip
    /// (host stack down + wire + EP stack up + EP stack down + wire +
    /// host stack up) lands in the high two-digit-ns range (~70 ns),
    /// matching "round-trip latency in the range of tens of nanoseconds,
    /// including protocol conversion".
    pub fn panmnesia() -> LayerCosts {
        LayerCosts {
            protocol_conv: 2_500, // 2.5 ns
            transaction: 5_000,   // 5.0 ns
            link: 4_500,          // 4.5 ns
            phy: 4_000,           // 4.0 ns
        }
    }

    /// PCIe-derived prototype (SMT): dominated by PCIe transaction/link
    /// layers sized for block I/O, not load/store. Round trip ≈ 250 ns.
    pub fn smt() -> LayerCosts {
        LayerCosts {
            protocol_conv: 9_000,
            transaction: 22_000,
            link: 18_000,
            phy: 12_000,
        }
    }

    /// PCIe-derived prototype (TPP): Meta's tiered-memory testbed EP;
    /// the paper groups it with SMT at ~250 ns (Fig. 3b).
    pub fn tpp() -> LayerCosts {
        LayerCosts {
            protocol_conv: 8_000,
            transaction: 24_000,
            link: 19_000,
            phy: 11_000,
        }
    }
}

/// A CXL controller instance (one per root port, one per EP).
#[derive(Debug, Clone)]
pub struct CxlController {
    pub kind: ControllerKind,
    pub costs: LayerCosts,
    /// Link bandwidth in GB/s (PCIe 5.0 x8 ≈ 32 GB/s per direction).
    pub link_gbps: f64,
    /// Wire/board propagation per direction.
    pub wire: Time,
}

impl CxlController {
    pub fn new(kind: ControllerKind) -> CxlController {
        let costs = match kind {
            ControllerKind::Panmnesia => LayerCosts::panmnesia(),
            ControllerKind::Smt => LayerCosts::smt(),
            ControllerKind::Tpp => LayerCosts::tpp(),
        };
        CxlController { kind, costs, link_gbps: 32.0, wire: 2 * NS }
    }

    /// One-way latency for a request flit: host-side stack + wire +
    /// serialization of the header flit.
    pub fn request_leg(&self, flit: &Flit) -> Time {
        self.costs.one_way() + self.wire + transfer_time(64, self.link_gbps) + self.extra(flit)
    }

    /// One-way latency for the completion: EP-side stack + wire +
    /// serialization of the data flits.
    pub fn response_leg(&self, flit: &Flit) -> Time {
        self.costs.one_way()
            + self.wire
            + transfer_time(flit.data_flits() * 64, self.link_gbps)
    }

    /// Full protocol round trip for a 64 B access, *excluding* backend
    /// media time — the quantity Fig. 3b reports.
    pub fn round_trip_64b(&self) -> Time {
        // Down through host stack, across, up through EP stack (request),
        // then EP stack down, across, host stack up (completion).
        2 * (self.costs.one_way() + self.wire + transfer_time(64, self.link_gbps))
            + 2 * self.costs.one_way()
    }

    /// Full 64 B round trip *including* a device-side service time —
    /// the per-path number the expander-cache experiment (DESIGN.md
    /// §14) reports: with the service time of a device-DRAM cache hit
    /// (~120 ns) the total stays protocol-dominated near the paper's
    /// two-digit-ns regime, while a backend-media miss (µs flash reads)
    /// is media-bound on any controller.
    pub fn round_trip_64b_with(&self, device_service: Time) -> Time {
        self.round_trip_64b() + device_service
    }

    fn extra(&self, _flit: &Flit) -> Time {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::flit::MemOpcode;

    #[test]
    fn panmnesia_round_trip_is_two_digit_ns() {
        let c = CxlController::new(ControllerKind::Panmnesia);
        let rt_ns = c.round_trip_64b() as f64 / NS as f64;
        assert!(rt_ns >= 10.0 && rt_ns < 100.0, "round trip {rt_ns} ns not two-digit");
    }

    #[test]
    fn pcie_derived_controllers_are_about_250ns() {
        for kind in [ControllerKind::Smt, ControllerKind::Tpp] {
            let c = CxlController::new(kind);
            let rt_ns = c.round_trip_64b() as f64 / NS as f64;
            assert!((200.0..300.0).contains(&rt_ns), "{kind:?} rt {rt_ns} ns");
        }
    }

    #[test]
    fn paper_claims_over_3x_faster() {
        let ours = CxlController::new(ControllerKind::Panmnesia).round_trip_64b();
        let smt = CxlController::new(ControllerKind::Smt).round_trip_64b();
        let tpp = CxlController::new(ControllerKind::Tpp).round_trip_64b();
        assert!(smt as f64 / ours as f64 > 3.0);
        assert!(tpp as f64 / ours as f64 > 3.0);
    }

    #[test]
    fn cache_hit_path_stays_protocol_dominated() {
        let c = CxlController::new(ControllerKind::Panmnesia);
        let hit_ns = c.round_trip_64b_with(120 * NS) as f64 / NS as f64;
        let miss_ns = c.round_trip_64b_with(3_000 * NS) as f64 / NS as f64;
        assert!(hit_ns < 250.0, "device-DRAM hit path {hit_ns} ns");
        assert!(miss_ns > 10.0 * hit_ns, "a flash miss must be media-bound");
    }

    #[test]
    fn response_serialization_scales_with_len() {
        let c = CxlController::new(ControllerKind::Panmnesia);
        let small = Flit { op: MemOpcode::MemRd, addr: 0, len: 64, issued_at: 0, req_id: 0 };
        let big = Flit { op: MemOpcode::MemRd, addr: 0, len: 1024, issued_at: 0, req_id: 1 };
        assert!(c.response_leg(&big) > c.response_leg(&small));
    }
}
