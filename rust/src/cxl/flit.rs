//! CXL.mem flit model.
//!
//! CXL.mem moves packetized 64 B flits over the PCIe physical link. We
//! model the fields the GPU-side queue logic actually inspects: opcode,
//! host physical address, length, and — for `MemSpecRd` — the paper's
//! repurposed address format where the two least-significant bits encode
//! the request length in 256 B units (1..=4, i.e. 256 B..1024 B) and the
//! remaining bits a 256 B-aligned offset (§Accelerating Reads, Fig. 6).

use crate::sim::Time;

/// Payload bytes carried by one CXL.mem data flit.
pub const FLIT_DATA_BYTES: u64 = 64;

/// Memory-offset unit of a `MemSpecRd` request (the paper repurposes the
/// low bits so the remaining address specifies a 256 B offset).
pub const SPECRD_OFFSET_UNIT: u64 = 256;

/// CXL.mem master-to-subordinate opcodes we model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemOpcode {
    /// Demand read (MemRd), 64 B granularity.
    MemRd,
    /// Write (MemWr), 64 B granularity.
    MemWr,
    /// Speculative read hint introduced in CXL 2.0; no completion data is
    /// returned, the EP merely warms its backend (here: internal DRAM).
    MemSpecRd,
    /// Back-invalidate / management (stand-in for CXL.io config traffic).
    Config,
}

/// A flit in flight between a root port and an EP.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flit {
    pub op: MemOpcode,
    /// Host physical address (64 B aligned for MemRd/MemWr; 256 B aligned
    /// for MemSpecRd per the repurposed format).
    pub addr: u64,
    /// Request length in bytes (64 for demand ops; 256..=1024 for SpecRd).
    pub len: u64,
    /// Issue timestamp (for latency accounting).
    pub issued_at: Time,
    /// Request id used to match completions.
    pub req_id: u64,
}

impl Flit {
    /// Encode a `MemSpecRd` per the paper: two LSBs = length in 256 B
    /// units minus one, upper bits = 256 B-aligned offset.
    pub fn spec_rd(addr: u64, len: u64, issued_at: Time, req_id: u64) -> Flit {
        let units = (len / SPECRD_OFFSET_UNIT).clamp(1, 4);
        let aligned = addr & !(SPECRD_OFFSET_UNIT - 1);
        Flit {
            op: MemOpcode::MemSpecRd,
            addr: aligned,
            len: units * SPECRD_OFFSET_UNIT,
            issued_at,
            req_id,
        }
    }

    /// The wire encoding of a SpecRd address word (offset | units-1).
    pub fn spec_rd_encoding(&self) -> u64 {
        debug_assert_eq!(self.op, MemOpcode::MemSpecRd);
        let units = self.len / SPECRD_OFFSET_UNIT;
        (self.addr & !(SPECRD_OFFSET_UNIT - 1)) | (units - 1)
    }

    /// Decode a SpecRd wire word back to (addr, len).
    pub fn decode_spec_rd(word: u64) -> (u64, u64) {
        let units = (word & 0b11) + 1;
        let addr = word & !(SPECRD_OFFSET_UNIT - 1);
        (addr, units * SPECRD_OFFSET_UNIT)
    }

    /// Number of 64 B data flits needed for this request's data phase.
    pub fn data_flits(&self) -> u64 {
        match self.op {
            MemOpcode::MemRd | MemOpcode::MemWr => self.len.div_ceil(FLIT_DATA_BYTES),
            // SpecRd carries no data payload (a hint), Config is 1 flit.
            MemOpcode::MemSpecRd | MemOpcode::Config => 1,
        }
    }

    /// Total link-layer flits one transfer of this request occupies: the
    /// header/command flit plus the data phase. This is what the RAS
    /// layer's per-transfer CRC model scales with — a longer payload
    /// exposes more flits to corruption (`ras::RasState::link_transfer`).
    pub fn link_flits(&self) -> u64 {
        1 + self.data_flits()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_rd_aligns_and_clamps() {
        let f = Flit::spec_rd(0x1234, 1024, 0, 1);
        assert_eq!(f.addr, 0x1200);
        assert_eq!(f.len, 1024);
        let tiny = Flit::spec_rd(0x40, 64, 0, 2);
        assert_eq!(tiny.len, 256, "length clamps up to one 256B unit");
        let big = Flit::spec_rd(0x0, 8192, 0, 3);
        assert_eq!(big.len, 1024, "length clamps down to four units");
    }

    #[test]
    fn spec_rd_encoding_roundtrip() {
        for units in 1..=4u64 {
            let f = Flit::spec_rd(0x4000, units * 256, 7, 9);
            let word = f.spec_rd_encoding();
            let (addr, len) = Flit::decode_spec_rd(word);
            assert_eq!(addr, 0x4000);
            assert_eq!(len, units * 256);
        }
    }

    #[test]
    fn encoding_uses_two_lsbs() {
        let f = Flit::spec_rd(0x4000, 1024, 0, 0);
        assert_eq!(f.spec_rd_encoding() & 0b11, 3);
        let f = Flit::spec_rd(0x4000, 256, 0, 0);
        assert_eq!(f.spec_rd_encoding() & 0b11, 0);
    }

    #[test]
    fn data_flit_counts() {
        let rd = Flit { op: MemOpcode::MemRd, addr: 0, len: 64, issued_at: 0, req_id: 0 };
        assert_eq!(rd.data_flits(), 1);
        let wr = Flit { op: MemOpcode::MemWr, addr: 0, len: 256, issued_at: 0, req_id: 0 };
        assert_eq!(wr.data_flits(), 4);
        let sr = Flit::spec_rd(0, 1024, 0, 0);
        assert_eq!(sr.data_flits(), 1, "SpecRd is a hint, no data phase");
    }

    #[test]
    fn link_flits_add_the_header() {
        let rd = Flit { op: MemOpcode::MemRd, addr: 0, len: 64, issued_at: 0, req_id: 0 };
        assert_eq!(rd.link_flits(), 2, "header + one data flit");
        let wr = Flit { op: MemOpcode::MemWr, addr: 0, len: 256, issued_at: 0, req_id: 0 };
        assert_eq!(wr.link_flits(), 5);
    }
}
