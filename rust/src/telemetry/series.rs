//! The one time-series representation (§19): fixed-interval bucketed
//! samples with per-bucket means.
//!
//! [`Series`] started life as `sim::timeline::Timeline`, the ad-hoc
//! Fig. 9e DS time series; the flight recorder adopted it as the common
//! currency every telemetry consumer reads — `Fig9eSeries` carries three
//! of them (bit-identically to the pre-telemetry figure), and
//! [`super::TelemetryReport::series`] converts a frame stream into the
//! same shape so one plotting/printing path serves both. `crate::sim`
//! re-exports it under the historical `Timeline` name.

use crate::sim::Time;

/// Hard ceiling on a series' bucket count: samples past
/// `MAX_BUCKETS x bucket` saturate into the last bucket instead of
/// growing the vectors, so a multi-day diurnal serve run cannot inflate
/// a series unbounded (memory stays O(MAX_BUCKETS) per series).
pub const MAX_BUCKETS: usize = 1 << 16;

/// Fixed-interval time series: samples are bucketed into `bucket` wide
/// windows and averaged within each bucket.
#[derive(Debug, Clone)]
pub struct Series {
    bucket: Time,
    sums: Vec<f64>,
    counts: Vec<u64>,
    label: String,
}

impl Series {
    pub fn new(label: &str, bucket: Time) -> Self {
        assert!(bucket > 0);
        Series { bucket, sums: Vec::new(), counts: Vec::new(), label: label.to_string() }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn bucket_width(&self) -> Time {
        self.bucket
    }

    /// Record `value` at simulation time `at`. Samples beyond the
    /// [`MAX_BUCKETS`] horizon saturate into the last bucket.
    pub fn record(&mut self, at: Time, value: f64) {
        let idx = ((at / self.bucket) as usize).min(MAX_BUCKETS - 1);
        if idx >= self.sums.len() {
            self.sums.resize(idx + 1, 0.0);
            self.counts.resize(idx + 1, 0);
        }
        self.sums[idx] += value;
        self.counts[idx] += 1;
    }

    /// Bucketed series as (bucket_start_time, mean) pairs; empty buckets
    /// are skipped.
    pub fn series(&self) -> Vec<(Time, f64)> {
        self.sums
            .iter()
            .zip(&self.counts)
            .enumerate()
            .filter(|(_, (_, &c))| c > 0)
            .map(|(i, (&s, &c))| (i as Time * self.bucket, s / c as f64))
            .collect()
    }

    /// Max bucket mean (for quick assertions on spikes).
    pub fn max_mean(&self) -> f64 {
        self.series().iter().map(|&(_, v)| v).fold(0.0, f64::max)
    }

    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_and_averages() {
        let mut tl = Series::new("lat", 100);
        tl.record(10, 2.0);
        tl.record(20, 4.0);
        tl.record(250, 10.0);
        let s = tl.series();
        assert_eq!(s, vec![(0, 3.0), (200, 10.0)]);
    }

    #[test]
    fn skips_empty_buckets() {
        let mut tl = Series::new("q", 10);
        tl.record(5, 1.0);
        tl.record(95, 9.0);
        let s = tl.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[1].0, 90);
    }

    #[test]
    fn bucket_count_saturates_at_the_cap() {
        let mut tl = Series::new("diurnal", 10);
        // Far past the horizon: both land in the final bucket instead of
        // resizing the vectors to the sample's own index.
        let horizon = MAX_BUCKETS as Time * 10;
        tl.record(horizon, 4.0);
        tl.record(horizon * 1000, 8.0);
        tl.record(5, 1.0);
        let s = tl.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], (0, 1.0));
        assert_eq!(s[1], ((MAX_BUCKETS as Time - 1) * 10, 6.0));
        assert_eq!(tl.max_mean(), 6.0);
    }

    #[test]
    fn max_mean() {
        let mut tl = Series::new("x", 10);
        assert!(tl.is_empty());
        tl.record(0, 1.0);
        tl.record(11, 7.0);
        assert_eq!(tl.max_mean(), 7.0);
        assert!(!tl.is_empty());
    }
}
