//! SLO health monitors over the telemetry frame stream (§19).
//!
//! [`scan`] runs four deterministic monitors over a recorded frame
//! stream and emits timestamped [`Alert`] records:
//!
//! * **Multi-window burn rate** on serve deadline misses — the standard
//!   SRE pattern: the error budget is `1 - slo_target`, and an alert
//!   fires when the budget burns `fast_burn`x faster than sustainable
//!   over *both* a short and a long window (page-level), or `slow_burn`x
//!   over the long window alone (ticket-level). Requiring both windows
//!   keeps a single bad epoch from paging while still catching fast
//!   regressions quickly.
//! * **Latency inflation** — a victim-tenant detector: per-epoch mean
//!   expander load latency exceeding `latency_x` times the baseline
//!   established over the first frames of the run (the §15 degraded-pool
//!   scenario inflates the victim's tail exactly this way).
//! * **RAS degradation latch** — fires on every increase of the
//!   degraded-endpoint gauge and on failover deltas, timestamping the
//!   §15 latch transition.
//! * **Cache thrash** — device-cache traffic with a hit rate below
//!   `thrash_hit_rate` while writebacks are flowing: the working set no
//!   longer fits and the cache is churning instead of absorbing.
//!
//! Monitors are edge-triggered: each fires when its condition becomes
//! true and re-arms only after the condition clears, so a sustained
//! violation yields one alert with a deterministic timestamp rather than
//! one per frame. Everything is pure frame arithmetic — same frames in,
//! same alerts out, sharded or serial.

use crate::sim::Time;

use super::Frame;

/// Monitor thresholds. Defaults are deliberately conservative: an
/// unremarkable healthy run should produce zero alerts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthSpec {
    /// In-SLO completion target for served requests (budget = 1 - this).
    pub slo_target: f64,
    /// Short burn-rate window, in frames.
    pub short_frames: usize,
    /// Long burn-rate window, in frames.
    pub long_frames: usize,
    /// Fast-burn multiple (page severity): both windows above this.
    pub fast_burn: f64,
    /// Slow-burn multiple (ticket severity): long window above this.
    pub slow_burn: f64,
    /// Latency-inflation factor over the run-start baseline.
    pub latency_x: f64,
    /// Frames used to establish the latency baseline.
    pub baseline_frames: usize,
    /// Cache hit rate below which traffic counts as thrash.
    pub thrash_hit_rate: f64,
    /// Minimum per-frame cache accesses before thrash is judged.
    pub thrash_min_traffic: u64,
}

impl Default for HealthSpec {
    fn default() -> HealthSpec {
        HealthSpec {
            slo_target: 0.99,
            short_frames: 4,
            long_frames: 16,
            fast_burn: 14.0,
            slow_burn: 6.0,
            latency_x: 3.0,
            baseline_frames: 8,
            thrash_hit_rate: 0.2,
            thrash_min_traffic: 64,
        }
    }
}

/// Which monitor fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertKind {
    /// Both burn windows above `fast_burn` (page severity).
    SloFastBurn,
    /// Long burn window above `slow_burn` (ticket severity).
    SloSlowBurn,
    /// Mean expander load latency above `latency_x` times baseline.
    LatencyInflation,
    /// Degraded-endpoint gauge rose, or a failover was recorded.
    RasDegraded,
    /// Device cache churning: low hit rate under real traffic.
    CacheThrash,
}

impl AlertKind {
    /// Stable identifier used by the exporters.
    pub fn name(&self) -> &'static str {
        match self {
            AlertKind::SloFastBurn => "slo-fast-burn",
            AlertKind::SloSlowBurn => "slo-slow-burn",
            AlertKind::LatencyInflation => "latency-inflation",
            AlertKind::RasDegraded => "ras-degraded",
            AlertKind::CacheThrash => "cache-thrash",
        }
    }
}

/// One fired monitor: deterministic timestamp, observed value, and the
/// threshold it crossed.
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    /// Simulation time of the frame that fired (ps).
    pub at: Time,
    /// Sequence number of that frame.
    pub frame: u64,
    pub kind: AlertKind,
    /// The monitored value at fire time (burn multiple, latency ns, ...).
    pub value: f64,
    /// The configured threshold it crossed.
    pub threshold: f64,
}

impl Alert {
    /// Human-oriented one-liner for figure output.
    pub fn describe(&self) -> String {
        let what = match self.kind {
            AlertKind::SloFastBurn => "serve budget burning",
            AlertKind::SloSlowBurn => "serve budget burning",
            AlertKind::LatencyInflation => "load latency inflated",
            AlertKind::RasDegraded => "endpoints degraded",
            AlertKind::CacheThrash => "device-cache hit rate",
        };
        format!(
            "[{:>9.3} ms] {:<17} {} ({:.2} vs {:.2})",
            self.at as f64 / 1e9,
            self.kind.name(),
            what,
            self.value,
            self.threshold,
        )
    }
}

/// Burn multiple over the window of frames ending at `end` (inclusive):
/// miss-rate over the window divided by the error budget. `None` when
/// the window saw no arrivals (idle — no evidence either way).
fn burn(frames: &[Frame], end: usize, window: usize, budget: f64) -> Option<f64> {
    let lo = (end + 1).saturating_sub(window);
    let mut misses = 0u64;
    let mut arrivals = 0u64;
    for f in &frames[lo..=end] {
        misses += f.serve_missed();
        arrivals += f.d_serve_arrivals;
    }
    if arrivals == 0 {
        return None;
    }
    Some(misses as f64 / arrivals as f64 / budget)
}

/// Run every monitor over the frame stream. Pure and deterministic.
pub fn scan(frames: &[Frame], spec: &HealthSpec) -> Vec<Alert> {
    let mut alerts = Vec::new();
    let budget = (1.0 - spec.slo_target).max(f64::EPSILON);

    // Latency baseline: mean of per-frame load means over the first
    // `baseline_frames` frames that actually completed loads.
    let mut base_sum = 0.0;
    let mut base_n = 0usize;
    for f in frames {
        if f.d_load_count > 0 {
            base_sum += f.load_mean_ns();
            base_n += 1;
            if base_n == spec.baseline_frames {
                break;
            }
        }
    }
    let baseline = if base_n > 0 { base_sum / base_n as f64 } else { 0.0 };

    let mut fast_armed = true;
    let mut slow_armed = true;
    let mut lat_armed = true;
    let mut thrash_armed = true;
    let mut prev_degraded = 0u64;

    for (i, f) in frames.iter().enumerate() {
        // --- multi-window burn rate ---
        let short = burn(frames, i, spec.short_frames, budget);
        let long = burn(frames, i, spec.long_frames, budget);
        let fast_hot = match (short, long) {
            (Some(s), Some(l)) => s >= spec.fast_burn && l >= spec.fast_burn,
            _ => false,
        };
        if fast_hot && fast_armed {
            alerts.push(Alert {
                at: f.at,
                frame: f.seq,
                kind: AlertKind::SloFastBurn,
                value: short.unwrap().min(long.unwrap()),
                threshold: spec.fast_burn,
            });
        }
        fast_armed = !fast_hot;
        let slow_hot = long.map(|l| l >= spec.slow_burn).unwrap_or(false);
        if slow_hot && slow_armed {
            alerts.push(Alert {
                at: f.at,
                frame: f.seq,
                kind: AlertKind::SloSlowBurn,
                value: long.unwrap(),
                threshold: spec.slow_burn,
            });
        }
        slow_armed = !slow_hot;

        // --- latency inflation vs run-start baseline ---
        let lat_hot = baseline > 0.0
            && f.d_load_count > 0
            && f.load_mean_ns() > spec.latency_x * baseline;
        if lat_hot && lat_armed {
            alerts.push(Alert {
                at: f.at,
                frame: f.seq,
                kind: AlertKind::LatencyInflation,
                value: f.load_mean_ns(),
                threshold: spec.latency_x * baseline,
            });
        }
        lat_armed = !lat_hot;

        // --- RAS degradation latch: edge on the gauge, or failovers ---
        if f.ras_degraded > prev_degraded || f.d_ras_failovers > 0 {
            alerts.push(Alert {
                at: f.at,
                frame: f.seq,
                kind: AlertKind::RasDegraded,
                value: f.ras_degraded.max(prev_degraded + f.d_ras_failovers.min(1)) as f64,
                threshold: prev_degraded as f64,
            });
        }
        prev_degraded = f.ras_degraded;

        // --- cache thrash ---
        let traffic = f.d_cache_hits + f.d_cache_misses;
        let thrash_hot = traffic >= spec.thrash_min_traffic
            && f.cache_hit_rate() < spec.thrash_hit_rate
            && f.d_cache_writebacks > 0;
        if thrash_hot && thrash_armed {
            alerts.push(Alert {
                at: f.at,
                frame: f.seq,
                kind: AlertKind::CacheThrash,
                value: f.cache_hit_rate(),
                threshold: spec.thrash_hit_rate,
            });
        }
        thrash_armed = !thrash_hot;
    }
    alerts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::US;

    fn frame(i: u64) -> Frame {
        Frame { seq: i, at: (i + 1) * 50 * US, ..Default::default() }
    }

    #[test]
    fn healthy_stream_fires_nothing() {
        let frames: Vec<Frame> = (0..32)
            .map(|i| Frame {
                d_serve_arrivals: 100,
                d_serve_completed: 100,
                d_serve_in_slo: 100,
                d_load_count: 50,
                d_load_ps: 50.0 * 900_000.0,
                d_cache_hits: 90,
                d_cache_misses: 10,
                d_cache_writebacks: 5,
                ..frame(i)
            })
            .collect();
        assert!(scan(&frames, &HealthSpec::default()).is_empty());
    }

    #[test]
    fn sustained_misses_fire_fast_then_stay_latched() {
        // 1% budget; 50% miss rate = 50x burn >= 14x fast threshold.
        let frames: Vec<Frame> = (0..24)
            .map(|i| Frame {
                d_serve_arrivals: 100,
                d_serve_timed_out: if i >= 8 { 50 } else { 0 },
                ..frame(i)
            })
            .collect();
        let alerts = scan(&frames, &HealthSpec::default());
        let fast: Vec<_> =
            alerts.iter().filter(|a| a.kind == AlertKind::SloFastBurn).collect();
        assert_eq!(fast.len(), 1, "edge-triggered: one alert for a sustained burn");
        // The short window saturates first (50x by frame 11), but fast
        // burn needs the long window too: 16-frame burn crosses 14x at
        // frame 11 (200 misses / 1200 arrivals / 1% budget = 16.7x).
        assert_eq!(fast[0].frame, 11);
        assert_eq!(fast[0].at, 12 * 50 * US);
        // Slow burn (long window >= 6x) leads it: 10x at frame 9.
        let slow: Vec<_> =
            alerts.iter().filter(|a| a.kind == AlertKind::SloSlowBurn).collect();
        assert_eq!(slow[0].frame, 9);
    }

    #[test]
    fn burn_ignores_idle_windows() {
        // Misses with zero arrivals in-window must not divide by zero or
        // fire (window with no evidence).
        let frames: Vec<Frame> = (0..8).map(frame).collect();
        assert!(scan(&frames, &HealthSpec::default()).is_empty());
    }

    #[test]
    fn latency_inflation_fires_on_victim_spike() {
        // Baseline ~ 1000 ns; frames past 10 jump to 5000 ns (> 3x).
        let frames: Vec<Frame> = (0..16)
            .map(|i| Frame {
                d_load_count: 10,
                d_load_ps: if i >= 10 { 10.0 * 5_000_000.0 } else { 10.0 * 1_000_000.0 },
                ..frame(i)
            })
            .collect();
        let alerts = scan(&frames, &HealthSpec::default());
        let lat: Vec<_> =
            alerts.iter().filter(|a| a.kind == AlertKind::LatencyInflation).collect();
        assert_eq!(lat.len(), 1);
        assert_eq!(lat[0].frame, 10);
        assert_eq!(lat[0].value, 5000.0);
    }

    #[test]
    fn ras_latch_fires_on_the_transition_edge() {
        let frames: Vec<Frame> = (0..8)
            .map(|i| Frame { ras_degraded: if i >= 3 { 1 } else { 0 }, ..frame(i) })
            .collect();
        let alerts = scan(&frames, &HealthSpec::default());
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, AlertKind::RasDegraded);
        assert_eq!(alerts[0].frame, 3);
        assert_eq!(alerts[0].at, 4 * 50 * US);
    }

    #[test]
    fn cache_thrash_needs_traffic_and_writebacks() {
        let thrashing = Frame {
            d_cache_hits: 5,
            d_cache_misses: 95,
            d_cache_writebacks: 40,
            ..frame(0)
        };
        let quiet = Frame { d_cache_hits: 1, d_cache_misses: 9, ..frame(1) };
        let alerts = scan(&[thrashing, quiet], &HealthSpec::default());
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].kind, AlertKind::CacheThrash);
        assert!(alerts[0].value < 0.2);
    }

    #[test]
    fn describe_is_stable() {
        let a = Alert {
            at: 2 * 50 * US,
            frame: 1,
            kind: AlertKind::RasDegraded,
            value: 1.0,
            threshold: 0.0,
        };
        assert_eq!(a.describe(), "[    0.100 ms] ras-degraded      endpoints degraded (1.00 vs 0.00)");
    }
}
