//! Telemetry exporters (§19): JSONL time series and Prometheus text
//! exposition, both built on `util/json.rs` / plain text — no external
//! dependencies, deterministic byte output for the same report.
//!
//! The `--telemetry-out PATH` CLI flag writes the JSONL stream at `PATH`
//! and the Prometheus exposition at `PATH.prom`; CI parses both
//! (`examples/prom_check.rs` validates the exposition grammar).

use crate::util::json::{Json, JsonObj};

use super::{Frame, TelemetryReport};

/// Every counter-delta field exported, with its cumulative-total
/// Prometheus family name. One list drives both exporters so the two
/// outputs can never drift apart.
const COUNTERS: &[(&str, &str, fn(&Frame) -> u64)] = &[
    ("loads", "Expander loads routed", |f| f.d_loads),
    ("stores", "Expander writebacks routed", |f| f.d_stores),
    ("llc_hits", "GPU LLC hits", |f| f.d_llc_hits),
    ("llc_misses", "GPU LLC misses", |f| f.d_llc_misses),
    ("mshr_stalls", "Issue stalls on MSHR exhaustion", |f| f.d_mshr_stalls),
    ("ds_intercepts", "Loads served from the DS write stack", |f| f.d_ds_intercepts),
    ("ep_cache_hits", "Loads served by the expander cache", |f| f.d_ep_cache_hits),
    ("media_reads", "Loads that reached backend media", |f| f.d_media_reads),
    ("faults", "UVM/GDS fault-path transfers", |f| f.d_faults),
    ("gc_episodes", "SSD garbage-collection episodes", |f| f.d_gc_episodes),
    ("sr_issued", "Speculative reads issued", |f| f.d_sr_issued),
    ("sr_suppressed", "Speculative reads suppressed by the EP cache", |f| {
        f.d_sr_suppressed
    }),
    ("cache_hits", "Device-cache hits", |f| f.d_cache_hits),
    ("cache_misses", "Device-cache misses", |f| f.d_cache_misses),
    ("cache_writebacks", "Device-cache writebacks", |f| f.d_cache_writebacks),
    ("ras_retries", "RAS link retries", |f| f.d_ras_retries),
    ("ras_failovers", "RAS endpoint failovers", |f| f.d_ras_failovers),
    ("tier_promotions", "Tiering promotions", |f| f.d_tier_promotions),
    ("tier_demotions", "Tiering demotions", |f| f.d_tier_demotions),
    ("throttle_waits", "QoS token-bucket throttle waits", |f| f.d_throttle_waits),
    ("backpressure", "Switch ingress backpressure events", |f| f.d_backpressure),
    ("serve_arrivals", "Serve requests arrived", |f| f.d_serve_arrivals),
    ("serve_admitted", "Serve requests admitted", |f| f.d_serve_admitted),
    ("serve_completed", "Serve requests completed", |f| f.d_serve_completed),
    ("serve_in_slo", "Serve requests completed within SLO", |f| f.d_serve_in_slo),
    ("serve_timed_out", "Serve requests past deadline", |f| f.d_serve_timed_out),
    ("serve_shed", "Serve requests shed under overload", |f| f.d_serve_shed),
    ("serve_rejected", "Serve requests rejected at admission", |f| f.d_serve_rejected),
];

/// Instantaneous gauges exported from the most recent frame.
const GAUGES: &[(&str, &str, fn(&Frame) -> f64)] = &[
    ("mshr_occupancy", "LLC MSHR entries in flight", |f| f.mshr as f64),
    ("port_queue_depth", "Root-port queue occupancy", |f| f.port_queue as f64),
    ("devload_class", "Worst DevLoad class (0=Light..3=Severe)", |f| f.devload as f64),
    ("ds_buffered_bytes", "DS write-stack bytes buffered", |f| f.ds_buffered as f64),
    ("cache_lines", "Device-cache resident lines", |f| f.cache_lines as f64),
    ("cache_dirty_lines", "Device-cache dirty lines", |f| f.cache_dirty as f64),
    ("cache_wb_pending", "Device-cache writeback backlog", |f| f.cache_wb_pending as f64),
    ("ras_degraded", "Endpoints latched degraded", |f| f.ras_degraded as f64),
    ("qos_rate_bytes", "QoS token refill rate", |f| f.qos_rate as f64),
    ("ingress_occupancy", "Switch ingress occupancy", |f| f.ingress as f64),
    ("serve_queue_depth", "Front-door admission queue depth", |f| f.serve_queue as f64),
    ("serve_inflight", "Requests dispatched and not drained", |f| f.serve_inflight as f64),
    ("load_latency_ns", "Mean expander load latency, last epoch", Frame::load_mean_ns),
    ("store_latency_ns", "Mean expander store latency, last epoch", Frame::store_mean_ns),
];

fn frame_obj(f: &Frame) -> JsonObj {
    let mut o =
        JsonObj::new().set("type", "frame").set("seq", f.seq).set("at_us", f.at as f64 / 1e6);
    for (name, _, get) in COUNTERS {
        o = o.set(&format!("d_{name}"), get(f));
    }
    for (name, _, get) in GAUGES {
        o = o.set(name, get(f));
    }
    o
}

/// JSONL time series: one `meta` line, one `frame` line per epoch, one
/// `alert` line per fired monitor. Every line is a standalone JSON
/// object — `jq`/pandas friendly.
pub fn jsonl(name: &str, rep: &TelemetryReport) -> String {
    let mut out = String::new();
    let meta: Json = JsonObj::new()
        .set("type", "meta")
        .set("name", name)
        .set("epoch_us", rep.epoch as f64 / 1e6)
        .set("frames", rep.frames.len())
        .set("ticks", rep.ticks)
        .set("dropped", rep.dropped)
        .set("alerts", rep.alerts.len())
        .into();
    out.push_str(&meta.to_string());
    out.push('\n');
    for f in &rep.frames {
        out.push_str(&Json::from(frame_obj(f)).to_string());
        out.push('\n');
    }
    for a in &rep.alerts {
        let line: Json = JsonObj::new()
            .set("type", "alert")
            .set("at_us", a.at as f64 / 1e6)
            .set("frame", a.frame)
            .set("kind", a.kind.name())
            .set("value", a.value)
            .set("threshold", a.threshold)
            .into();
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Escape a Prometheus label value (`\`, `"`, newline).
fn label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Prometheus text exposition (format 0.0.4) over one or more named
/// runs. Counter families export run totals (summed frame deltas) as
/// `cxlgpu_<name>_total{run="..."}`; gauges export the last frame's
/// value; alerts export a per-kind count. `# HELP`/`# TYPE` are emitted
/// once per family, samples grouped under them, which is what the
/// exposition grammar requires.
pub fn prometheus(runs: &[(String, TelemetryReport)]) -> String {
    let mut out = String::new();
    for (fam, help, get) in COUNTERS {
        out.push_str(&format!("# HELP cxlgpu_{fam}_total {help}\n"));
        out.push_str(&format!("# TYPE cxlgpu_{fam}_total counter\n"));
        for (name, rep) in runs {
            let total: u64 = rep.frames.iter().map(|f| get(f)).sum();
            out.push_str(&format!(
                "cxlgpu_{fam}_total{{run=\"{}\"}} {total}\n",
                label(name)
            ));
        }
    }
    for (fam, help, get) in GAUGES {
        out.push_str(&format!("# HELP cxlgpu_{fam} {help}\n"));
        out.push_str(&format!("# TYPE cxlgpu_{fam} gauge\n"));
        for (name, rep) in runs {
            let v = rep.frames.last().map(|f| get(f)).unwrap_or(0.0);
            out.push_str(&format!("cxlgpu_{fam}{{run=\"{}\"}} {}\n", label(name), num(v)));
        }
    }
    out.push_str("# HELP cxlgpu_alerts_total Health-monitor alerts fired\n");
    out.push_str("# TYPE cxlgpu_alerts_total counter\n");
    for (name, rep) in runs {
        for kind in
            ["slo-fast-burn", "slo-slow-burn", "latency-inflation", "ras-degraded", "cache-thrash"]
        {
            let n = rep.alerts.iter().filter(|a| a.kind.name() == kind).count();
            out.push_str(&format!(
                "cxlgpu_alerts_total{{run=\"{}\",kind=\"{kind}\"}} {n}\n",
                label(name)
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::US;
    use crate::telemetry::{Alert, AlertKind};
    use crate::util::json::parse;

    fn report() -> TelemetryReport {
        let frames = vec![
            Frame {
                seq: 0,
                at: 50 * US,
                d_loads: 10,
                d_load_count: 10,
                d_load_ps: 10.0 * 2_000_000.0,
                ingress: 3,
                ..Default::default()
            },
            Frame { seq: 1, at: 100 * US, d_loads: 5, ras_degraded: 1, ..Default::default() },
        ];
        TelemetryReport {
            epoch: 50 * US,
            frames,
            ticks: 2,
            dropped: 0,
            alerts: vec![Alert {
                at: 100 * US,
                frame: 1,
                kind: AlertKind::RasDegraded,
                value: 1.0,
                threshold: 0.0,
            }],
        }
    }

    #[test]
    fn jsonl_lines_all_parse() {
        let text = jsonl("cxl-ras", &report());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "meta + 2 frames + 1 alert");
        let meta = parse(lines[0]).unwrap();
        assert_eq!(meta.get("type").unwrap().as_str(), Some("meta"));
        assert_eq!(meta.get("frames").unwrap().as_u64(), Some(2));
        let f0 = parse(lines[1]).unwrap();
        assert_eq!(f0.get("d_loads").unwrap().as_u64(), Some(10));
        assert_eq!(f0.get("load_latency_ns").unwrap().as_u64(), Some(2000));
        let alert = parse(lines[3]).unwrap();
        assert_eq!(alert.get("kind").unwrap().as_str(), Some("ras-degraded"));
    }

    #[test]
    fn prometheus_totals_and_last_gauges() {
        let text = prometheus(&[("run-a".to_string(), report())]);
        assert!(text.contains("# TYPE cxlgpu_loads_total counter\n"));
        assert!(text.contains("cxlgpu_loads_total{run=\"run-a\"} 15\n"));
        assert!(text.contains("cxlgpu_ras_degraded{run=\"run-a\"} 1\n"));
        assert!(text.contains("cxlgpu_alerts_total{run=\"run-a\",kind=\"ras-degraded\"} 1\n"));
        // HELP/TYPE precede their samples and appear exactly once.
        assert_eq!(text.matches("# TYPE cxlgpu_loads_total").count(), 1);
        let type_at = text.find("# TYPE cxlgpu_loads_total").unwrap();
        let sample_at = text.find("cxlgpu_loads_total{").unwrap();
        assert!(type_at < sample_at);
    }

    #[test]
    fn prometheus_escapes_hostile_run_names() {
        let text = prometheus(&[("we\"ird\\name".to_string(), report())]);
        assert!(text.contains("run=\"we\\\"ird\\\\name\""));
    }
}
