//! Flight recorder (DESIGN.md §19): deterministic epoch time-series
//! telemetry over one simulation run.
//!
//! A `TelemetryTick` calendar event samples, every [`TelemetrySpec::epoch`]
//! picoseconds, one fixed-width [`Frame`] of system-wide gauges and
//! counter *deltas*: port/ingress queue depth, DevLoad class, MSHR
//! occupancy, SR issue/suppression, DS buffer fill, expander-cache
//! occupancy and writeback backlog, tiering migrations, RAS retry and
//! degradation state, QoS token rate, and the serving front door's queue
//! depth, goodput and deadline misses. On top of the frame stream sit
//! the [`health`] SLO monitors (multi-window burn rate, latency
//! inflation, RAS degradation latch, cache-thrash) and the [`export`]
//! encoders (Prometheus text exposition, JSONL).
//!
//! # Determinism contract
//!
//! The same contract as the §18 span tracer, with one addition for the
//! tick events themselves:
//!
//! * **Structural inertness.** A disabled spec builds no
//!   [`TelemetryState`] (`new` returns `None`): nothing exists to
//!   consult, no tick is ever scheduled, and the disabled run is
//!   bit-identical to the pre-telemetry code path.
//! * **Read-only arming.** An armed recorder samples only values the
//!   simulation computes anyway and draws no RNG. Tick events do consume
//!   calendar sequence numbers, but relative order among all other
//!   events is preserved (sequence numbers are monotonic), and the
//!   coordinator subtracts [`TelemetryState::ticks`] from the popped
//!   count so the `events` fingerprint entry matches a disabled run
//!   exactly — armed runs are fingerprint-identical at every cadence
//!   (pinned in `tests/determinism.rs`).
//! * **Shard safety.** In a sharded pool run (§17) a tick that fires
//!   during a parallel phase may not read the shared switch — its state
//!   lags the serial schedule until the barrier. Capture is therefore
//!   split: the *local* half (LLC, MSHR, front door) is taken at the
//!   tick, where tenant-local evolution is already bit-identical, and
//!   the *fabric* half (expander counters, switch gauges, pool sums) is
//!   recorded as a deferred fabric op and completed during the serial
//!   replay phase, in exactly the global `(time, tenant, program-order)`
//!   slot the serial run's tick would have occupied. Sharded runs
//!   therefore record frame-for-frame identical telemetry to serial —
//!   the capability the Fig. 9e timeline (per-op sampling inside the
//!   load path) structurally cannot have.
//!
//! # Conservation contract
//!
//! Frames record counter deltas against the previous frame, and
//! [`TelemetryState::finalize`] captures one residual frame at harvest,
//! so for every recorded counter the sum of deltas across the frame
//! stream equals the run-final `RunMetrics` total exactly (integer
//! arithmetic, no sampling) — pinned by a property test over randomized
//! configs in `tests/props.rs`. The only exception is a stream truncated
//! by [`TelemetrySpec::max_frames`], which the `dropped` counter makes
//! visible.

pub mod export;
pub mod health;
pub mod series;

pub use export::{jsonl, prometheus};
pub use health::{scan, Alert, AlertKind, HealthSpec};
pub use series::{Series, MAX_BUCKETS};

use std::collections::VecDeque;

use crate::sim::{Time, US};

/// Flight-recorder configuration. `Default` is disabled and structurally
/// inert: a config carrying it schedules no ticks and records nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TelemetrySpec {
    /// Master switch; `false` (default) builds no recorder.
    pub enabled: bool,
    /// Sampling cadence in picoseconds. The default matches the Fig. 9e
    /// bucket width (50 µs), so frame indices line up with the
    /// historical timeline buckets.
    pub epoch: Time,
    /// Hard ceiling on retained frames; past it, new frames are dropped
    /// (counted in [`TelemetryReport::dropped`]) instead of growing the
    /// buffer unbounded on multi-second runs.
    pub max_frames: usize,
}

impl Default for TelemetrySpec {
    fn default() -> TelemetrySpec {
        TelemetrySpec { enabled: false, epoch: 50 * US, max_frames: MAX_BUCKETS }
    }
}

/// One telemetry epoch: gauges sampled at the tick plus counter deltas
/// since the previous frame. `d_`-prefixed fields are deltas; everything
/// else is an instantaneous gauge. Fixed width — every run records the
/// same schema, with fields a topology lacks held at zero.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Frame {
    /// Frame index (0-based).
    pub seq: u64,
    /// Capture timestamp (end of the epoch), picoseconds.
    pub at: Time,

    // --- tenant-local gauges (sampled at the tick) ---
    /// LLC MSHR entries in flight.
    pub mshr: u64,
    /// Admission-queue depth at the serving front door.
    pub serve_queue: u64,
    /// Requests dispatched to warps and not yet drained.
    pub serve_inflight: u64,

    // --- tenant-local counter deltas ---
    pub d_llc_hits: u64,
    pub d_llc_misses: u64,
    pub d_mshr_stalls: u64,
    pub d_serve_arrivals: u64,
    pub d_serve_admitted: u64,
    pub d_serve_completed: u64,
    pub d_serve_in_slo: u64,
    pub d_serve_timed_out: u64,
    pub d_serve_shed: u64,
    pub d_serve_rejected: u64,

    // --- expander/fabric gauges ---
    /// Direct attach: summed root-port queue occupancy. Pooled: this
    /// tenant's switch ingress occupancy.
    pub port_queue: u64,
    /// Worst DevLoad class across local ports (0=Light .. 3=Severe).
    pub devload: u8,
    /// DS write-stack bytes buffered (local and pooled endpoints).
    pub ds_buffered: u64,
    /// Expander device-cache resident lines.
    pub cache_lines: u64,
    /// ... of which dirty.
    pub cache_dirty: u64,
    /// Device-cache writeback queue backlog (lines).
    pub cache_wb_pending: u64,
    /// Endpoints currently latched degraded (RAS §15).
    pub ras_degraded: u64,
    /// QoS token-bucket refill rate, bytes/s (0 = no QoS shaping).
    pub qos_rate: u64,
    /// Switch ingress occupancy for this tenant (pooled runs).
    pub ingress: u64,

    // --- expander/fabric counter deltas ---
    pub d_loads: u64,
    pub d_stores: u64,
    pub d_ds_intercepts: u64,
    pub d_ep_cache_hits: u64,
    pub d_media_reads: u64,
    pub d_faults: u64,
    pub d_gc_episodes: u64,
    pub d_sr_issued: u64,
    /// SR candidates suppressed because the EP cache already covered them.
    pub d_sr_suppressed: u64,
    pub d_cache_hits: u64,
    pub d_cache_misses: u64,
    pub d_cache_writebacks: u64,
    pub d_ras_retries: u64,
    pub d_ras_failovers: u64,
    pub d_tier_promotions: u64,
    pub d_tier_demotions: u64,
    pub d_throttle_waits: u64,
    pub d_backpressure: u64,

    // --- expander-op latency accumulator deltas ---
    /// Expander loads completed-routed this epoch (the latency pair's
    /// denominator; equals `d_loads` on every current backend).
    pub d_load_count: u64,
    /// Summed expander load latency this epoch, picoseconds.
    pub d_load_ps: f64,
    pub d_store_count: u64,
    pub d_store_ps: f64,
}

impl Frame {
    /// Mean expander load latency this epoch, nanoseconds (0 when idle).
    pub fn load_mean_ns(&self) -> f64 {
        if self.d_load_count == 0 { 0.0 } else { self.d_load_ps / self.d_load_count as f64 / 1e3 }
    }

    /// Mean expander store latency this epoch, nanoseconds.
    pub fn store_mean_ns(&self) -> f64 {
        if self.d_store_count == 0 {
            0.0
        } else {
            self.d_store_ps / self.d_store_count as f64 / 1e3
        }
    }

    /// SR hit rate this epoch: loads served by the EP cache.
    pub fn sr_hit_rate(&self) -> f64 {
        if self.d_loads == 0 { 0.0 } else { self.d_ep_cache_hits as f64 / self.d_loads as f64 }
    }

    /// Device-cache hit rate this epoch.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.d_cache_hits + self.d_cache_misses;
        if total == 0 { 0.0 } else { self.d_cache_hits as f64 / total as f64 }
    }

    /// Serve deadline misses this epoch (timed out + shed).
    pub fn serve_missed(&self) -> u64 {
        self.d_serve_timed_out + self.d_serve_shed
    }
}

/// Cumulative tenant-local counters plus instantaneous local gauges,
/// sampled at the tick event. The recorder turns consecutive samples
/// into per-frame deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LocalSample {
    pub at: Time,
    pub mshr: u64,
    pub serve_queue: u64,
    pub serve_inflight: u64,
    pub llc_hits: u64,
    pub llc_misses: u64,
    pub mshr_stalls: u64,
    pub serve_arrivals: u64,
    pub serve_admitted: u64,
    pub serve_completed: u64,
    pub serve_in_slo: u64,
    pub serve_timed_out: u64,
    pub serve_shed: u64,
    pub serve_rejected: u64,
}

/// Cumulative expander/fabric counters plus switch-side gauges, sampled
/// either at the tick (direct attach, serial pool) or during the barrier
/// replay (sharded pool — see the module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FabricSample {
    pub port_queue: u64,
    pub devload: u8,
    pub ds_buffered: u64,
    pub cache_lines: u64,
    pub cache_dirty: u64,
    pub cache_wb_pending: u64,
    pub ras_degraded: u64,
    pub qos_rate: u64,
    pub ingress: u64,
    pub loads: u64,
    pub stores: u64,
    pub ds_intercepts: u64,
    pub ep_cache_hits: u64,
    pub media_reads: u64,
    pub faults: u64,
    pub gc_episodes: u64,
    pub sr_issued: u64,
    pub sr_suppressed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_writebacks: u64,
    pub ras_retries: u64,
    pub ras_failovers: u64,
    pub tier_promotions: u64,
    pub tier_demotions: u64,
    pub throttle_waits: u64,
    pub backpressure: u64,
    pub load_count: u64,
    pub load_ps: f64,
    pub store_count: u64,
    pub store_ps: f64,
}

/// The armed flight recorder owned by one `System`.
pub struct TelemetryState {
    spec: TelemetrySpec,
    frames: Vec<Frame>,
    dropped: u64,
    ticks: u64,
    /// Local halves awaiting their fabric halves, in tick order. Depth 1
    /// outside sharded parallel phases; bounded by pending deferred ops
    /// inside them.
    pending: VecDeque<LocalSample>,
    prev_local: LocalSample,
    prev_fabric: FabricSample,
    /// Cumulative expander-op latency accumulators, fed from the fabric
    /// side of the load/store paths so sharded replay reproduces them in
    /// serial order.
    load_count: u64,
    load_ps: f64,
    store_count: u64,
    store_ps: f64,
}

impl TelemetryState {
    /// Build the recorder, or `None` when the spec is inert (disabled or
    /// zero cadence) — the structural-inertness lever.
    pub fn new(spec: &TelemetrySpec) -> Option<TelemetryState> {
        if !spec.enabled || spec.epoch == 0 {
            return None;
        }
        Some(TelemetryState {
            spec: *spec,
            frames: Vec::new(),
            dropped: 0,
            ticks: 0,
            pending: VecDeque::new(),
            prev_local: LocalSample::default(),
            prev_fabric: FabricSample::default(),
            load_count: 0,
            load_ps: 0.0,
            store_count: 0,
            store_ps: 0.0,
        })
    }

    /// Sampling cadence (ps).
    pub fn epoch(&self) -> Time {
        self.spec.epoch
    }

    /// `TelemetryTick` calendar events executed so far. The coordinator
    /// subtracts this from the popped-event count so `events` stays
    /// fingerprint-identical to a disabled run.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Record one executed tick event.
    pub fn on_tick(&mut self) {
        self.ticks += 1;
    }

    /// Fabric-side latency feed: one expander load completed routing.
    pub fn note_load(&mut self, lat_ps: Time) {
        self.load_count += 1;
        self.load_ps += lat_ps as f64;
    }

    /// Fabric-side latency feed: one expander writeback acked.
    pub fn note_store(&mut self, lat_ps: Time) {
        self.store_count += 1;
        self.store_ps += lat_ps as f64;
    }

    /// Cumulative load-latency accumulator `(count, sum_ps)` — the
    /// coordinator copies it into each [`FabricSample`].
    pub fn load_acc(&self) -> (u64, f64) {
        (self.load_count, self.load_ps)
    }

    /// Cumulative store-latency accumulator `(count, sum_ps)`.
    pub fn store_acc(&self) -> (u64, f64) {
        (self.store_count, self.store_ps)
    }

    /// Stage 1 of a capture: the tenant-local half, taken at the tick.
    pub fn push_local(&mut self, s: LocalSample) {
        self.pending.push_back(s);
    }

    /// Stage 2 of a capture: the fabric half. Completes the oldest
    /// pending local half into a finished [`Frame`].
    pub fn complete_fabric(&mut self, f: FabricSample) {
        let Some(l) = self.pending.pop_front() else { return };
        let frame = Frame {
            seq: self.frames.len() as u64 + self.dropped,
            at: l.at,
            mshr: l.mshr,
            serve_queue: l.serve_queue,
            serve_inflight: l.serve_inflight,
            d_llc_hits: l.llc_hits - self.prev_local.llc_hits,
            d_llc_misses: l.llc_misses - self.prev_local.llc_misses,
            d_mshr_stalls: l.mshr_stalls - self.prev_local.mshr_stalls,
            d_serve_arrivals: l.serve_arrivals - self.prev_local.serve_arrivals,
            d_serve_admitted: l.serve_admitted - self.prev_local.serve_admitted,
            d_serve_completed: l.serve_completed - self.prev_local.serve_completed,
            d_serve_in_slo: l.serve_in_slo - self.prev_local.serve_in_slo,
            d_serve_timed_out: l.serve_timed_out - self.prev_local.serve_timed_out,
            d_serve_shed: l.serve_shed - self.prev_local.serve_shed,
            d_serve_rejected: l.serve_rejected - self.prev_local.serve_rejected,
            port_queue: f.port_queue,
            devload: f.devload,
            ds_buffered: f.ds_buffered,
            cache_lines: f.cache_lines,
            cache_dirty: f.cache_dirty,
            cache_wb_pending: f.cache_wb_pending,
            ras_degraded: f.ras_degraded,
            qos_rate: f.qos_rate,
            ingress: f.ingress,
            d_loads: f.loads - self.prev_fabric.loads,
            d_stores: f.stores - self.prev_fabric.stores,
            d_ds_intercepts: f.ds_intercepts - self.prev_fabric.ds_intercepts,
            d_ep_cache_hits: f.ep_cache_hits - self.prev_fabric.ep_cache_hits,
            d_media_reads: f.media_reads - self.prev_fabric.media_reads,
            d_faults: f.faults - self.prev_fabric.faults,
            d_gc_episodes: f.gc_episodes - self.prev_fabric.gc_episodes,
            d_sr_issued: f.sr_issued - self.prev_fabric.sr_issued,
            d_sr_suppressed: f.sr_suppressed - self.prev_fabric.sr_suppressed,
            d_cache_hits: f.cache_hits - self.prev_fabric.cache_hits,
            d_cache_misses: f.cache_misses - self.prev_fabric.cache_misses,
            d_cache_writebacks: f.cache_writebacks - self.prev_fabric.cache_writebacks,
            d_ras_retries: f.ras_retries - self.prev_fabric.ras_retries,
            d_ras_failovers: f.ras_failovers - self.prev_fabric.ras_failovers,
            d_tier_promotions: f.tier_promotions - self.prev_fabric.tier_promotions,
            d_tier_demotions: f.tier_demotions - self.prev_fabric.tier_demotions,
            d_throttle_waits: f.throttle_waits - self.prev_fabric.throttle_waits,
            d_backpressure: f.backpressure - self.prev_fabric.backpressure,
            d_load_count: f.load_count - self.prev_fabric.load_count,
            d_load_ps: f.load_ps - self.prev_fabric.load_ps,
            d_store_count: f.store_count - self.prev_fabric.store_count,
            d_store_ps: f.store_ps - self.prev_fabric.store_ps,
        };
        // Snapshots advance even when the frame is dropped, so later
        // frames stay correct deltas of their own windows.
        self.prev_local = l;
        self.prev_fabric = f;
        if self.frames.len() < self.spec.max_frames {
            self.frames.push(frame);
        } else {
            self.dropped += 1;
        }
    }

    /// True when a final residual frame would record nothing new — the
    /// coordinator skips the capture entirely then (a run whose last
    /// tick already drained everything).
    pub fn residual_needed(&self, l: &LocalSample, f: &FabricSample) -> bool {
        let mut probe = LocalSample { at: self.prev_local.at, ..*l };
        probe.mshr = self.prev_local.mshr;
        probe.serve_queue = self.prev_local.serve_queue;
        probe.serve_inflight = self.prev_local.serve_inflight;
        probe != self.prev_local || {
            let mut pf = *f;
            pf.port_queue = self.prev_fabric.port_queue;
            pf.devload = self.prev_fabric.devload;
            pf.ds_buffered = self.prev_fabric.ds_buffered;
            pf.cache_lines = self.prev_fabric.cache_lines;
            pf.cache_dirty = self.prev_fabric.cache_dirty;
            pf.cache_wb_pending = self.prev_fabric.cache_wb_pending;
            pf.ras_degraded = self.prev_fabric.ras_degraded;
            pf.qos_rate = self.prev_fabric.qos_rate;
            pf.ingress = self.prev_fabric.ingress;
            pf != self.prev_fabric
        }
    }

    /// Capture the run-final residual frame (conservation: deltas must
    /// sum to the final totals) and emit the report. Called from
    /// `System::harvest` with both halves sampled directly — deferral is
    /// always off by then.
    pub fn finalize(&mut self, l: LocalSample, f: FabricSample) -> TelemetryReport {
        // A straggling pending half would shift the local/fabric pairing;
        // complete it against the final fabric sample first (cannot
        // happen on a drained run — purely defensive).
        while !self.pending.is_empty() {
            self.complete_fabric(f);
        }
        if self.residual_needed(&l, &f) {
            self.push_local(l);
            self.complete_fabric(f);
        }
        let frames = std::mem::take(&mut self.frames);
        let alerts = health::scan(&frames, &HealthSpec::default());
        TelemetryReport {
            epoch: self.spec.epoch,
            frames,
            ticks: self.ticks,
            dropped: self.dropped,
            alerts,
        }
    }
}

/// The run-final telemetry payload carried (fingerprint-exempt) on
/// `RunMetrics::telemetry`.
#[derive(Debug, Clone, Default)]
pub struct TelemetryReport {
    /// Sampling cadence (ps).
    pub epoch: Time,
    /// The frame stream, oldest first; the final frame is the harvest
    /// residual.
    pub frames: Vec<Frame>,
    /// Tick events executed (subtracted from the `events` metric).
    pub ticks: u64,
    /// Frames discarded past `max_frames`.
    pub dropped: u64,
    /// Health-monitor alerts over the frame stream, in frame order.
    pub alerts: Vec<Alert>,
}

impl TelemetryReport {
    /// Sum a counter delta across the frame stream (= the run total for
    /// conserved counters).
    pub fn total(&self, field: impl Fn(&Frame) -> u64) -> u64 {
        self.frames.iter().map(field).sum()
    }

    /// Convert one frame metric into the shared [`Series`]
    /// representation (bucket = the frame epoch; frames that recorded no
    /// samples for the metric are skipped, matching `Series::series`'s
    /// empty-bucket behaviour). Known metrics: `load-latency-ns`,
    /// `store-latency-ns`, `ingress-occupancy`, `serve-queue`,
    /// `serve-miss-rate`, `ds-buffered`. Unknown names yield an empty
    /// series.
    pub fn series(&self, metric: &str) -> Series {
        let mut s = Series::new(metric, self.epoch.max(1));
        let mut start = 0;
        for fr in &self.frames {
            match metric {
                "load-latency-ns" if fr.d_load_count > 0 => s.record(start, fr.load_mean_ns()),
                "store-latency-ns" if fr.d_store_count > 0 => {
                    s.record(start, fr.store_mean_ns())
                }
                "ingress-occupancy" => s.record(start, fr.ingress as f64),
                "serve-queue" => s.record(start, fr.serve_queue as f64),
                "serve-miss-rate" if fr.d_serve_arrivals > 0 => {
                    s.record(start, fr.serve_missed() as f64 / fr.d_serve_arrivals as f64)
                }
                "ds-buffered" => s.record(start, fr.ds_buffered as f64),
                _ => {}
            }
            start = fr.at;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spec_builds_nothing() {
        assert!(TelemetryState::new(&TelemetrySpec::default()).is_none());
        let zero = TelemetrySpec { enabled: true, epoch: 0, ..Default::default() };
        assert!(TelemetryState::new(&zero).is_none());
        let armed = TelemetrySpec { enabled: true, ..Default::default() };
        assert!(TelemetryState::new(&armed).is_some());
    }

    fn armed() -> TelemetryState {
        TelemetryState::new(&TelemetrySpec { enabled: true, ..Default::default() }).unwrap()
    }

    #[test]
    fn deltas_partition_the_cumulative_counters() {
        let mut t = armed();
        t.note_load(1000);
        t.note_load(3000);
        let (lc, lp) = t.load_acc();
        t.push_local(LocalSample { at: 50 * US, llc_hits: 10, ..Default::default() });
        t.complete_fabric(FabricSample {
            loads: 2,
            load_count: lc,
            load_ps: lp,
            ..Default::default()
        });
        t.note_load(5000);
        let (lc, lp) = t.load_acc();
        t.push_local(LocalSample { at: 100 * US, llc_hits: 25, ..Default::default() });
        t.complete_fabric(FabricSample {
            loads: 3,
            load_count: lc,
            load_ps: lp,
            ..Default::default()
        });
        let rep = t.finalize(
            LocalSample { at: 120 * US, llc_hits: 25, ..Default::default() },
            FabricSample { loads: 3, load_count: 3, load_ps: 9000.0, ..Default::default() },
        );
        assert_eq!(rep.frames.len(), 2, "unchanged residual is skipped");
        assert_eq!(rep.frames[0].d_llc_hits, 10);
        assert_eq!(rep.frames[1].d_llc_hits, 15);
        assert_eq!(rep.frames[0].d_loads, 2);
        assert_eq!(rep.frames[1].d_loads, 1);
        assert_eq!(rep.total(|f| f.d_llc_hits), 25);
        assert_eq!(rep.total(|f| f.d_loads), 3);
        assert_eq!(rep.frames[0].load_mean_ns(), 2.0);
        assert_eq!(rep.frames[1].load_mean_ns(), 5.0);
    }

    #[test]
    fn finalize_appends_the_residual_frame() {
        let mut t = armed();
        t.push_local(LocalSample { at: 50 * US, llc_hits: 4, ..Default::default() });
        t.complete_fabric(FabricSample { loads: 1, ..Default::default() });
        let rep = t.finalize(
            LocalSample { at: 70 * US, llc_hits: 9, ..Default::default() },
            FabricSample { loads: 6, ..Default::default() },
        );
        assert_eq!(rep.frames.len(), 2);
        assert_eq!(rep.frames[1].at, 70 * US);
        assert_eq!(rep.frames[1].d_llc_hits, 5);
        assert_eq!(rep.total(|f| f.d_loads), 6);
    }

    #[test]
    fn max_frames_drops_but_keeps_snapshots_consistent() {
        let mut t = TelemetryState::new(&TelemetrySpec {
            enabled: true,
            max_frames: 1,
            ..Default::default()
        })
        .unwrap();
        for i in 1..=3u64 {
            t.push_local(LocalSample { at: i * 50 * US, llc_hits: i * 10, ..Default::default() });
            t.complete_fabric(FabricSample::default());
        }
        let rep = t.finalize(
            LocalSample { at: 200 * US, llc_hits: 30, ..Default::default() },
            FabricSample::default(),
        );
        assert_eq!(rep.frames.len(), 1);
        assert_eq!(rep.dropped, 2, "overflow frames are counted, not silently lost");
        assert_eq!(rep.frames[0].d_llc_hits, 10);
    }

    #[test]
    fn frame_rates_and_series_conversion() {
        let mut frames = Vec::new();
        frames.push(Frame {
            at: 50 * US,
            d_loads: 10,
            d_ep_cache_hits: 4,
            d_cache_hits: 3,
            d_cache_misses: 1,
            d_serve_arrivals: 8,
            d_serve_timed_out: 1,
            d_serve_shed: 1,
            d_load_count: 10,
            d_load_ps: 10_000.0,
            ingress: 7,
            ..Default::default()
        });
        let f = &frames[0];
        assert_eq!(f.sr_hit_rate(), 0.4);
        assert_eq!(f.cache_hit_rate(), 0.75);
        assert_eq!(f.serve_missed(), 2);
        let rep = TelemetryReport { epoch: 50 * US, frames, ..Default::default() };
        let lat = rep.series("load-latency-ns");
        assert_eq!(lat.series(), vec![(0, 1.0)]);
        assert_eq!(rep.series("ingress-occupancy").series(), vec![(0, 7.0)]);
        assert!(rep.series("no-such-metric").series().is_empty());
        assert_eq!(rep.series("serve-miss-rate").series(), vec![(0, 0.25)]);
    }
}
