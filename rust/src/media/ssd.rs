//! SSD endpoint media model: internal DRAM cache + flash backend with
//! ingress write buffering, garbage collection and wear-leveling.
//!
//! Models the three SSD classes of Table 1a. The paper's expectation
//! (Background §CXL with an SSD integration) is that CXL SSDs front their
//! slow media with an internal DRAM cache, that writes are slower than
//! reads, and that internal tasks (GC for flash, fine-grained
//! wear-leveling for PRAM) produce tail latencies. All three behaviours
//! are modeled here because SR and DS exist to hide exactly them.

use crate::sim::{transfer_time, Time, MS, NS, US};
use crate::util::hash::FxHashMap;
use crate::util::prng::Pcg32;

use super::{MediaKind, MediaStats};

/// Alias matching the Table 1a device rows.
pub type SsdKind = MediaKind;

/// Internal device-DRAM streaming bandwidth (GB/s) used to serialize
/// cache-hit service. One definition for both device-DRAM hit paths —
/// the SSD's own internal cache here and the expander-side device
/// cache (`crate::expander`, which re-exports this) — so they stay on
/// the same cost surface and can't drift apart.
pub const DEV_DRAM_GBPS: f64 = 44.8;

/// SSD model parameters (picosecond latencies).
#[derive(Debug, Clone, Copy)]
pub struct SsdParams {
    pub kind: MediaKind,
    /// Backend media read latency (one frame).
    pub read_lat: Time,
    /// Backend media program latency (one page of `page_bytes`).
    pub program_lat: Time,
    /// Parallel backend channels (dies).
    pub channels: usize,
    /// Internal DRAM cache capacity in bytes.
    pub cache_bytes: u64,
    /// Cache tracking granule. 64 B = one CXL.mem demand line: a demand
    /// miss installs only the line it fetched, while a MemSpecRd span
    /// installs its whole window with a single backend read — this
    /// asymmetry is exactly SR's bandwidth amplification.
    pub frame_bytes: u64,
    /// Internal DRAM access time (cache-hit service).
    pub dram_lat: Time,
    /// Write-buffer capacity in bytes (internal DRAM reserved for writes).
    pub write_buf_bytes: u64,
    /// Flash page size for programs.
    pub page_bytes: u64,
    /// Bytes written to flash between GC episodes (0 = GC-free media).
    pub gc_every_bytes: u64,
    /// GC episode duration.
    pub gc_duration: Time,
    /// Per-write probability of a wear-leveling pause (PRAM), and its cost.
    pub wear_level_p: f64,
    pub wear_level_pause: Time,
}

impl SsdParams {
    /// Intel Optane P5800X: PRAM — fast, byte-addressable-ish, no GC but
    /// fine-grained wear-leveling pauses.
    pub fn optane() -> SsdParams {
        SsdParams {
            kind: MediaKind::Optane,
            read_lat: 2 * US,
            program_lat: 4 * US,
            channels: 8,
            cache_bytes: 512 << 10,
            frame_bytes: 64,
            dram_lat: 120 * NS,
            write_buf_bytes: 256 << 10,
            page_bytes: 512,
            gc_every_bytes: 0,
            gc_duration: 0,
            wear_level_p: 0.002,
            wear_level_pause: 50 * US,
        }
    }

    /// Samsung 983 ZET (Z-NAND): ultra-low-latency flash; reads ~3 µs,
    /// programs ~100 µs, GC to reconcile write/erase mismatch.
    pub fn znand() -> SsdParams {
        SsdParams {
            kind: MediaKind::Znand,
            read_lat: 3 * US,
            program_lat: 100 * US,
            channels: 8,
            cache_bytes: 512 << 10,
            frame_bytes: 64,
            dram_lat: 120 * NS,
            write_buf_bytes: 256 << 10,
            page_bytes: 4096,
            gc_every_bytes: 3 << 20,
            gc_duration: 3 * MS,
            wear_level_p: 0.0,
            wear_level_pause: 0,
        }
    }

    /// Samsung 980 Pro (conventional TLC NAND): slowest reads/programs and
    /// the longest GC episodes.
    pub fn nand() -> SsdParams {
        SsdParams {
            kind: MediaKind::Nand,
            read_lat: 50 * US,
            program_lat: 500 * US,
            channels: 8,
            cache_bytes: 512 << 10,
            frame_bytes: 64,
            dram_lat: 120 * NS,
            write_buf_bytes: 256 << 10,
            page_bytes: 16384,
            gc_every_bytes: 4 << 20,
            gc_duration: 10 * MS,
            wear_level_p: 0.0,
            wear_level_pause: 0,
        }
    }

    pub fn for_kind(kind: MediaKind) -> SsdParams {
        match kind {
            MediaKind::Optane => SsdParams::optane(),
            MediaKind::Znand => SsdParams::znand(),
            MediaKind::Nand => SsdParams::nand(),
            MediaKind::Ddr5 => panic!("DDR5 is not an SSD medium"),
        }
    }
}

/// LRU set of cached frames (internal DRAM read cache).
///
/// O(1) operations via an intrusive doubly-linked list over an arena
/// (head = most recent, tail = LRU victim). Deterministic regardless of
/// HashMap iteration order — required for reproducible simulations.
#[derive(Debug, Clone)]
struct LruSet {
    cap: usize,
    map: FxHashMap<u64, usize>, // frame -> arena slot
    keys: Vec<u64>,
    prev: Vec<usize>,
    next: Vec<usize>,
    head: usize,
    tail: usize,
    free: Vec<usize>,
}

const LRU_NIL: usize = usize::MAX;

impl LruSet {
    fn new(cap: usize) -> LruSet {
        LruSet {
            cap: cap.max(1),
            map: FxHashMap::default(),
            keys: Vec::new(),
            prev: Vec::new(),
            next: Vec::new(),
            head: LRU_NIL,
            tail: LRU_NIL,
            free: Vec::new(),
        }
    }

    fn contains(&self, frame: u64) -> bool {
        self.map.contains_key(&frame)
    }

    fn unlink(&mut self, slot: usize) {
        let (p, n) = (self.prev[slot], self.next[slot]);
        if p != LRU_NIL {
            self.next[p] = n;
        } else {
            self.head = n;
        }
        if n != LRU_NIL {
            self.prev[n] = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, slot: usize) {
        self.prev[slot] = LRU_NIL;
        self.next[slot] = self.head;
        if self.head != LRU_NIL {
            self.prev[self.head] = slot;
        }
        self.head = slot;
        if self.tail == LRU_NIL {
            self.tail = slot;
        }
    }

    fn touch(&mut self, frame: u64) {
        if let Some(&slot) = self.map.get(&frame) {
            if self.head != slot {
                self.unlink(slot);
                self.push_front(slot);
            }
        }
    }

    /// Insert a frame, evicting the least-recently-used if full.
    fn insert(&mut self, frame: u64) {
        if let Some(&slot) = self.map.get(&frame) {
            if self.head != slot {
                self.unlink(slot);
                self.push_front(slot);
            }
            return;
        }
        if self.map.len() >= self.cap {
            let victim = self.tail;
            debug_assert_ne!(victim, LRU_NIL);
            self.unlink(victim);
            self.map.remove(&self.keys[victim]);
            self.free.push(victim);
        }
        let slot = if let Some(s) = self.free.pop() {
            self.keys[s] = frame;
            s
        } else {
            self.keys.push(frame);
            self.prev.push(LRU_NIL);
            self.next.push(LRU_NIL);
            self.keys.len() - 1
        };
        self.map.insert(frame, slot);
        self.push_front(slot);
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// The SSD endpoint media model.
#[derive(Debug, Clone)]
pub struct SsdModel {
    pub params: SsdParams,
    cache: LruSet,
    /// In-flight prefetches: frame -> completion time, plus a min-heap
    /// of (completion, frame) so settling is O(log n) per event instead
    /// of a full-map scan.
    inflight: FxHashMap<u64, Time>,
    inflight_by_time: std::collections::BinaryHeap<std::cmp::Reverse<(Time, u64)>>,
    /// Backend channel availability.
    chan_free: Vec<Time>,
    rr: usize,
    /// Write buffer occupancy in bytes and its last drain timestamp.
    buf_bytes: u64,
    buf_last_drain: Time,
    /// Garbage collection state.
    bytes_since_gc: u64,
    gc_until: Time,
    /// Wear-leveling pause end (Optane).
    wl_until: Time,
    /// End address of the last accepted write (sequentiality detector
    /// for write-amplification-aware GC accounting).
    last_write_end: u64,
    pub stats: MediaStats,
}

impl SsdModel {
    pub fn new(params: SsdParams) -> SsdModel {
        let frames = (params.cache_bytes / params.frame_bytes) as usize;
        SsdModel {
            params,
            cache: LruSet::new(frames),
            inflight: FxHashMap::default(),
            inflight_by_time: std::collections::BinaryHeap::new(),
            // Guard against a zero-channel param: `next_channel` indexes
            // `rr % chan_free.len()`, which would divide by zero.
            chan_free: vec![0; params.channels.max(1)],
            rr: 0,
            buf_bytes: 0,
            buf_last_drain: 0,
            bytes_since_gc: 0,
            gc_until: 0,
            wl_until: 0,
            last_write_end: u64::MAX,
            stats: MediaStats::default(),
        }
    }

    pub fn kind(&self) -> MediaKind {
        self.params.kind
    }

    /// Quiet-device media-read service time (one backend read plus the
    /// internal-DRAM hop) — the unloaded-latency baseline the fabric
    /// QoS controller compares observed completions against.
    pub fn nominal_read_ps(&self) -> Time {
        self.params.read_lat + self.params.dram_lat
    }

    fn frame_of(&self, addr: u64) -> u64 {
        addr / self.params.frame_bytes
    }

    /// True while an internal task (GC or wear-leveling) runs or is about
    /// to run — the signal folded into DevLoad. The "about to run" half
    /// models the paper's EP announcing the task *before* scheduling it:
    /// within 75 % of the GC budget the EP pre-announces.
    pub fn internal_task_active(&self, now: Time) -> bool {
        if now < self.gc_until || now < self.wl_until {
            return true;
        }
        self.params.gc_every_bytes > 0
            && self.bytes_since_gc * 4 >= self.params.gc_every_bytes * 3
    }

    /// Earliest time the backend is free of internal tasks.
    fn task_free(&self, now: Time) -> Time {
        now.max(self.gc_until).max(self.wl_until)
    }

    /// Begin a GC episode at `now` regardless of write volume — fault
    /// injection used by tests and the Fig. 9e bench.
    pub fn begin_gc(&mut self, now: Time) {
        self.gc_until = now + self.params.gc_duration;
        self.stats.gc_episodes += 1;
        self.stats.gc_time += self.params.gc_duration;
    }

    fn next_channel(&mut self, at: Time) -> (usize, Time) {
        // Round-robin with earliest-available preference. `chan_free` is
        // built non-empty (`new` clamps channels to >= 1) and never
        // shrinks, so the modulus below cannot divide by zero.
        debug_assert!(!self.chan_free.is_empty());
        let mut best = self.rr % self.chan_free.len();
        for i in 0..self.chan_free.len() {
            let c = (self.rr + i) % self.chan_free.len();
            if self.chan_free[c] <= at {
                best = c;
                break;
            }
            if self.chan_free[c] < self.chan_free[best] {
                best = c;
            }
        }
        self.rr = best + 1;
        (best, self.chan_free[best].max(at))
    }

    /// Advance the background write-buffer drain: flash programs retire
    /// buffered bytes at `page_bytes / program_lat` per channel while no
    /// GC runs.
    fn drain_buffer(&mut self, now: Time) {
        if now <= self.buf_last_drain {
            return;
        }
        let span = now - self.task_free(self.buf_last_drain).min(now) + 0;
        let elapsed = if self.gc_until > self.buf_last_drain {
            now.saturating_sub(self.gc_until.min(now))
        } else {
            span
        };
        if elapsed > 0 && self.buf_bytes > 0 {
            // The flush engine programs across every channel in parallel
            // (multi-plane writes); GC accounting happens at write-accept
            // time, with write-amplification.
            let per_chan =
                (elapsed as f64 / self.params.program_lat as f64) * self.params.page_bytes as f64;
            let drained = (per_chan * self.params.channels as f64) as u64;
            let actually = drained.min(self.buf_bytes);
            self.buf_bytes -= actually;
        }
        self.buf_last_drain = now;
    }

    fn account_flash_write(&mut self, bytes: u64, now: Time) {
        if self.params.gc_every_bytes == 0 || bytes == 0 {
            return;
        }
        self.bytes_since_gc += bytes;
        if self.bytes_since_gc >= self.params.gc_every_bytes && now >= self.gc_until {
            // GC starts now and blocks the backend for its duration.
            self.gc_until = now + self.params.gc_duration;
            self.bytes_since_gc = 0;
            self.stats.gc_episodes += 1;
            self.stats.gc_time += self.params.gc_duration;
        }
    }

    /// Demand read of `len` bytes. Returns (completion time, cache hit?).
    pub fn read(&mut self, now: Time, addr: u64, len: u64) -> (Time, bool) {
        self.drain_buffer(now);
        self.settle_prefetches(now);
        self.stats.reads += 1;
        self.stats.read_bytes += len;
        let first = self.frame_of(addr);
        let last = self.frame_of(addr + len.saturating_sub(1));

        // All frames cached (or arriving via in-flight prefetch)?
        let mut ready_at = now;
        let mut all_cached = true;
        for f in first..=last {
            if self.cache.contains(f) {
                self.cache.touch(f);
            } else if let Some(&t) = self.inflight.get(&f) {
                // Prefetch racing the demand read: wait for it.
                ready_at = ready_at.max(t);
            } else {
                all_cached = false;
            }
        }
        if all_cached {
            self.stats.cache_hits += 1;
            let done = ready_at + self.params.dram_lat
                + transfer_time(len.max(64), DEV_DRAM_GBPS);
            return (done, true);
        }

        // Miss: backend read of the covering frames through a channel.
        // Frames become visible when the media read completes (via the
        // in-flight set) — installing at issue time would let concurrent
        // same-frame reads skip the media latency entirely.
        self.stats.cache_misses += 1;
        let start = self.task_free(now);
        let (ch, avail) = self.next_channel(start);
        let done = avail.max(start) + self.params.read_lat;
        self.chan_free[ch] = done;
        for f in first..=last {
            if !self.inflight.contains_key(&f) {
                self.inflight.insert(f, done);
                self.inflight_by_time.push(std::cmp::Reverse((done, f)));
            }
        }
        (done + self.params.dram_lat, false)
    }

    /// MemSpecRd prefetch of `len` bytes at `addr` (256 B..1 KiB).
    /// Returns the install-completion time. Respects internal tasks and
    /// channel occupancy but does not block demand traffic (separate
    /// channel arbitration round).
    pub fn prefetch(&mut self, now: Time, addr: u64, len: u64) -> Time {
        self.drain_buffer(now);
        let first = self.frame_of(addr);
        let last = self.frame_of(addr + len.saturating_sub(1));
        // A frame needs fetching if it is neither cached nor in flight.
        // Two passes over the (≤16-frame) span instead of collecting a
        // `todo` Vec per call — this runs on every SR window issue, so
        // the allocation was steady-state hot-path churn. The passes see
        // the same cache/inflight state: nothing between them mutates
        // either map, and the span's frames are distinct.
        let needs = |s: &SsdModel, f: u64| !s.cache.contains(f) && !s.inflight.contains_key(&f);
        if !(first..=last).any(|f| needs(self, f)) {
            return now;
        }
        let start = self.task_free(now);
        let (ch, avail) = self.next_channel(start);
        // One media read covers the whole contiguous span.
        let done = avail.max(start) + self.params.read_lat;
        self.chan_free[ch] = done;
        for f in first..=last {
            if needs(self, f) {
                self.inflight.insert(f, done);
                self.inflight_by_time.push(std::cmp::Reverse((done, f)));
                self.stats.prefetches += 1;
            }
        }
        done
    }

    /// Promote completed in-flight prefetches into the cache: pop the
    /// completion heap up to `now` (lazy deletion for superseded entries).
    pub fn settle_prefetches(&mut self, now: Time) {
        while let Some(&std::cmp::Reverse((t, f))) = self.inflight_by_time.peek() {
            if t > now {
                break;
            }
            self.inflight_by_time.pop();
            // Only settle if this heap entry still matches the live one.
            if self.inflight.get(&f) == Some(&t) {
                self.inflight.remove(&f);
                self.cache.insert(f);
            }
        }
    }

    /// Write `len` bytes. Returns the *ack* time (when the ingress can
    /// consider the write accepted). Fast path: write buffer has room —
    /// ack at internal-DRAM speed. Slow path: buffer full — ack waits for
    /// drain (and for GC if one is running): the paper's tail case.
    pub fn write(&mut self, now: Time, addr: u64, len: u64, rng: &mut Pcg32) -> Time {
        self.drain_buffer(now);
        self.stats.writes += 1;
        self.stats.write_bytes += len;
        self.account_write_pressure(now, addr, len);

        // Wear-leveling pause (Optane): rare, but stalls the whole device.
        if self.params.wear_level_p > 0.0 && rng.chance(self.params.wear_level_p) {
            let start = self.task_free(now);
            self.wl_until = start + self.params.wear_level_pause;
        }

        self.buffer_or_stall(now, len)
    }

    /// Device-internal write (the expander cache's writeback drain): the
    /// same buffering/GC accounting as [`SsdModel::write`], but no
    /// wear-leveling coin — internal relocations are already folded into
    /// the GC model, and the drain path has no requester RNG to consume.
    pub fn write_internal(&mut self, now: Time, addr: u64, len: u64) -> Time {
        self.drain_buffer(now);
        self.stats.writes += 1;
        self.stats.write_bytes += len;
        self.account_write_pressure(now, addr, len);
        self.buffer_or_stall(now, len)
    }

    /// GC pressure with write amplification: sequential overwrites are
    /// FTL-friendly (erase-block-aligned streams, amp ~1); random
    /// writes fragment erase blocks and multiply relocation work.
    /// "Sequential" tolerates small forward gaps: LLC evictions of a
    /// coalesced store stream arrive in ascending order but not
    /// perfectly adjacent (warp interleave), and the FTL coalesces
    /// anything landing within an open erase block.
    fn account_write_pressure(&mut self, now: Time, addr: u64, len: u64) {
        let sequential =
            addr >= self.last_write_end && addr - self.last_write_end <= 4096;
        self.last_write_end = addr + len;
        let amp = if sequential { 1 } else { 4 };
        self.account_flash_write(len * amp, now);
    }

    /// Accept `len` bytes into the write buffer, or stall on the drain.
    fn buffer_or_stall(&mut self, now: Time, len: u64) -> Time {
        if self.buf_bytes + len <= self.params.write_buf_bytes {
            self.buf_bytes += len;
            return now + self.params.dram_lat;
        }

        // Buffer full: the write must wait for enough drain. Time to free
        // `len` bytes at one channel's program bandwidth, plus any GC.
        let start = self.task_free(now);
        let needed = self.buf_bytes + len - self.params.write_buf_bytes;
        let pages = needed.div_ceil(self.params.page_bytes * self.params.channels as u64);
        let drain_done = start + pages * self.params.program_lat;
        self.buf_bytes = self.params.write_buf_bytes;
        drain_done + self.params.dram_lat
    }

    /// Current write-buffer occupancy fraction (DevLoad input).
    pub fn buffer_fill(&self) -> f64 {
        self.buf_bytes as f64 / self.params.write_buf_bytes as f64
    }

    pub fn cached_frames(&self) -> usize {
        self.cache.len()
    }

    /// Time GC ends (0 if never ran).
    pub fn gc_until(&self) -> Time {
        self.gc_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn znand() -> SsdModel {
        SsdModel::new(SsdParams::znand())
    }

    #[test]
    fn cold_read_misses_then_hits() {
        let mut m = znand();
        let (t1, hit1) = m.read(0, 0x1000, 64);
        assert!(!hit1);
        assert!(t1 >= 3 * US);
        let (t2, hit2) = m.read(t1, 0x1000, 64);
        assert!(hit2);
        assert!(t2 - t1 < 1 * US, "hit took {}", t2 - t1);
    }

    #[test]
    fn prefetch_turns_miss_into_hit() {
        let mut m = znand();
        let done = m.prefetch(0, 0x4000, 1024);
        assert!(done >= 3 * US);
        m.settle_prefetches(done);
        let (_, hit) = m.read(done, 0x4000, 64);
        assert!(hit);
        let (_, hit2) = m.read(done, 0x4000 + 960, 64);
        assert!(hit2, "whole 1KiB window cached");
    }

    #[test]
    fn demand_read_waits_for_inflight_prefetch() {
        let mut m = znand();
        let done = m.prefetch(0, 0x8000, 256);
        // Demand read arrives mid-flight: hit, but not before `done`.
        let (t, hit) = m.read(done / 2, 0x8000, 64);
        assert!(hit);
        assert!(t >= done);
    }

    #[test]
    fn buffered_writes_ack_fast() {
        let mut m = znand();
        let mut rng = Pcg32::new(1, 1);
        let t = m.write(0, 0x0, 64, &mut rng);
        assert!(t < 1 * US, "buffered write ack {t}");
    }

    #[test]
    fn write_buffer_overflow_stalls() {
        let mut m = znand();
        let mut rng = Pcg32::new(1, 1);
        // Fill the buffer instantly (no drain time passes at t=0).
        let cap = m.params.write_buf_bytes;
        let mut acked_fast = 0u64;
        let mut last = 0;
        for i in 0..(cap / 4096 + 4) {
            let t = m.write(0, i * 4096, 4096, &mut rng);
            if t < 1 * US {
                acked_fast += 4096;
            }
            last = t;
        }
        assert!(acked_fast <= cap);
        assert!(last >= m.params.program_lat, "overflow write must stall: {last}");
    }

    #[test]
    fn gc_triggers_after_enough_flash_writes() {
        let mut p = SsdParams::znand();
        p.gc_every_bytes = 1 << 20; // 1 MiB for the test
        p.write_buf_bytes = 64 << 10;
        let mut m = SsdModel::new(p);
        let mut rng = Pcg32::new(2, 2);
        let mut now = 0;
        for i in 0..2048u64 {
            now = m.write(now, i * 4096, 4096, &mut rng).max(now);
        }
        assert!(m.stats.gc_episodes > 0, "no GC after 8 MiB of writes");
        assert!(m.stats.gc_time > 0);
    }

    #[test]
    fn internal_writes_share_accounting_but_skip_the_wear_coin() {
        let mut p = SsdParams::znand();
        p.gc_every_bytes = 1 << 20;
        p.write_buf_bytes = 64 << 10;
        let mut m = SsdModel::new(p);
        let mut now = 0;
        // The expander cache's writeback drain has no requester RNG;
        // internal writes must still build buffer/GC pressure.
        for i in 0..2048u64 {
            now = m.write_internal(now, i * 4096, 4096).max(now);
        }
        assert!(m.stats.gc_episodes > 0, "internal writes must feed GC accounting");
        assert_eq!(m.stats.writes, 2048);
    }

    #[test]
    fn reads_stall_during_gc() {
        let mut m = znand();
        m.gc_until = 5 * MS;
        m.stats.gc_episodes = 1;
        let (t, hit) = m.read(1 * MS, 0xff000, 64);
        assert!(!hit);
        assert!(t >= 5 * MS, "read during GC completed at {t}");
    }

    #[test]
    fn optane_wear_leveling_occasionally_pauses() {
        let mut m = SsdModel::new(SsdParams::optane());
        let mut rng = Pcg32::new(3, 3);
        let mut paused = false;
        let mut now = 0;
        for i in 0..5000u64 {
            let t = m.write(now, i * 64, 64, &mut rng);
            if t > now + 10 * US {
                paused = true;
            }
            now += 100 * NS;
            let _ = t;
        }
        // Either an ack stalled or the wl window was set at least once.
        assert!(paused || m.wl_until > 0, "wear-leveling never kicked in");
    }

    #[test]
    fn lru_evicts_under_pressure() {
        let mut p = SsdParams::znand();
        p.cache_bytes = 1024; // 16 frames of 64B
        let mut m = SsdModel::new(p);
        let mut now = 0;
        for i in 0..64u64 {
            let (t, _) = m.read(now, i * 64, 64);
            now = t;
        }
        assert!(m.cached_frames() <= 16);
        // The very first frame must have been evicted.
        let (_, hit) = m.read(now, 0, 64);
        assert!(!hit);
    }

    #[test]
    fn media_latency_order_matches_fig9c() {
        // Fig. 9c: SR gains grow O < Z < N because media slowness grows
        // in that order — Optane must be the fastest backend.
        let mut o = SsdModel::new(SsdParams::optane());
        let mut z = znand();
        let mut n = SsdModel::new(SsdParams::nand());
        let (to, _) = o.read(0, 0, 64);
        let (tz, _) = z.read(0, 0, 64);
        let (tn, _) = n.read(0, 0, 64);
        assert!(to < tz && tz < tn, "order O<{to}> Z<{tz}> N<{tn}> wrong");
    }
}
