//! Bank/row-level DDR5 timing model (DRAMSim3 stand-in).
//!
//! Captures the three timing regimes a row-buffer DRAM exposes: row hit
//! (tCAS), row miss (tRP + tRCD + tCAS), and bank-busy queueing, plus
//! data-bus serialization per channel. Defaults model the paper's
//! DDR5-5600 expander media (Table 1a).

use crate::sim::{transfer_time, Time, NS};

use super::MediaStats;

/// DDR timing parameters (picoseconds).
#[derive(Debug, Clone, Copy)]
pub struct DramTimings {
    /// Column access (CAS) latency — row-buffer hit cost.
    pub t_cas: Time,
    /// Row activate (RAS-to-CAS) delay.
    pub t_rcd: Time,
    /// Precharge time.
    pub t_rp: Time,
    /// Per-channel data bandwidth, GB/s.
    pub channel_gbps: f64,
    /// Channels and banks per channel.
    pub channels: usize,
    pub banks_per_channel: usize,
    /// Row (page) size in bytes — determines row-hit locality.
    pub row_bytes: u64,
    /// Fixed memory-subsystem traversal cost added to every access
    /// (controller front-end, PHY, board). Vortex-class systems see
    /// hundreds of ns to DDR — which is exactly why the paper's ~70 ns
    /// CXL protocol adder costs only 2-20% end to end (Fig. 9a).
    pub base_lat: Time,
}

impl DramTimings {
    /// DDR5-5600: tCAS ≈ tRCD ≈ tRP ≈ 16 ns (CL46 at 5600 MT/s),
    /// 44.8 GB/s per channel, 2 channels x 16 banks, 8 KiB rows.
    pub fn ddr5_5600() -> DramTimings {
        DramTimings {
            t_cas: 16 * NS,
            t_rcd: 16 * NS,
            t_rp: 16 * NS,
            channel_gbps: 44.8,
            channels: 2,
            banks_per_channel: 16,
            row_bytes: 8192,
            base_lat: 220 * NS,
        }
    }

    /// GDDR-like local GPU memory: same structure, higher bandwidth and
    /// slightly tighter timings (used for the GPU's on-board memory).
    pub fn gddr_local() -> DramTimings {
        DramTimings {
            t_cas: 14 * NS,
            t_rcd: 14 * NS,
            t_rp: 14 * NS,
            channel_gbps: 112.0,
            channels: 8,
            banks_per_channel: 16,
            row_bytes: 4096,
            base_lat: 220 * NS,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Bank {
    open_row: Option<u64>,
    busy_until: Time,
}

/// The DRAM device model: per-bank state + per-channel bus occupancy.
#[derive(Debug, Clone)]
pub struct DramModel {
    pub timings: DramTimings,
    banks: Vec<Bank>,
    bus_free: Vec<Time>,
    pub stats: MediaStats,
    row_hits: u64,
    row_misses: u64,
}

impl DramModel {
    pub fn new(timings: DramTimings) -> DramModel {
        let nbanks = timings.channels * timings.banks_per_channel;
        DramModel {
            timings,
            banks: vec![Bank { open_row: None, busy_until: 0 }; nbanks],
            bus_free: vec![0; timings.channels],
            stats: MediaStats::default(),
            row_hits: 0,
            row_misses: 0,
        }
    }

    fn map(&self, addr: u64) -> (usize, usize, u64) {
        // Interleave channels on 256 B chunks, banks on rows.
        let chunk = addr / 256;
        let channel = (chunk as usize) % self.timings.channels;
        let row = addr / self.timings.row_bytes;
        let bank_in_ch = (row as usize) % self.timings.banks_per_channel;
        let bank = channel * self.timings.banks_per_channel + bank_in_ch;
        (channel, bank, row)
    }

    /// Service one access of `len` bytes at `addr` starting no earlier
    /// than `now`; returns completion time and updates bank/bus state.
    pub fn access(&mut self, now: Time, addr: u64, len: u64, is_write: bool) -> Time {
        let (channel, bank_idx, row) = self.map(addr);
        let bank = &mut self.banks[bank_idx];
        let start = now.max(bank.busy_until);
        let t = &self.timings;
        let array_time = match bank.open_row {
            Some(open) if open == row => {
                self.row_hits += 1;
                t.t_cas
            }
            Some(_) => {
                self.row_misses += 1;
                t.t_rp + t.t_rcd + t.t_cas
            }
            None => {
                self.row_misses += 1;
                t.t_rcd + t.t_cas
            }
        };
        bank.open_row = Some(row);
        let array_done = start + array_time;
        bank.busy_until = array_done;

        // Data burst occupies the channel bus.
        let bus_start = array_done.max(self.bus_free[channel]);
        let burst = transfer_time(len.max(64), t.channel_gbps);
        let done = bus_start + burst + t.base_lat;
        self.bus_free[channel] = bus_start + burst;

        if is_write {
            self.stats.writes += 1;
            self.stats.write_bytes += len;
        } else {
            self.stats.reads += 1;
            self.stats.read_bytes += len;
        }
        done
    }

    /// Unloaded row-hit latency (for calibration assertions).
    pub fn hit_latency(&self) -> Time {
        self.timings.base_lat + self.timings.t_cas + transfer_time(64, self.timings.channel_gbps)
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DramModel {
        DramModel::new(DramTimings::ddr5_5600())
    }

    #[test]
    fn row_hit_is_cheaper_than_miss() {
        let mut m = model();
        let t0 = m.access(0, 0x0, 64, false); // cold: activate + cas
        let t1 = m.access(t0, 0x40, 64, false) - t0; // same row: hit
        let t2 = m.access(t0 + t1 + 1_000_000, 64 * 8192, 64, false)
            - (t0 + t1 + 1_000_000); // same bank different row region
        assert!(t1 < t2, "hit {t1} not cheaper than miss {t2}");
    }

    #[test]
    fn sequential_stream_mostly_row_hits() {
        let mut m = model();
        let mut now = 0;
        for i in 0..512u64 {
            now = m.access(now, i * 64, 64, false);
        }
        assert!(m.row_hit_rate() > 0.8, "hit rate {}", m.row_hit_rate());
    }

    #[test]
    fn random_stream_mostly_row_misses() {
        let mut m = model();
        let mut now = 0;
        let mut addr = 0x12345u64;
        for _ in 0..512 {
            addr = addr.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            now = m.access(now, addr % (1 << 30) & !63, 64, false);
        }
        assert!(m.row_hit_rate() < 0.3, "hit rate {}", m.row_hit_rate());
    }

    #[test]
    fn bank_conflicts_serialize() {
        let mut m = model();
        // Two accesses to the same bank, different rows, at the same time:
        // the second must wait for the first.
        let row_stride = m.timings.row_bytes * m.timings.banks_per_channel as u64;
        let a = m.access(0, 0, 64, false);
        let b = m.access(0, row_stride, 64, false);
        assert!(b > a);
    }

    #[test]
    fn unloaded_hit_latency_includes_subsystem_base() {
        let m = model();
        let ns = m.hit_latency() as f64 / NS as f64;
        assert!((220.0..260.0).contains(&ns), "{ns} ns");
    }

    #[test]
    fn stats_track_reads_and_writes() {
        let mut m = model();
        m.access(0, 0, 64, false);
        m.access(0, 4096, 128, true);
        assert_eq!(m.stats.reads, 1);
        assert_eq!(m.stats.writes, 1);
        assert_eq!(m.stats.write_bytes, 128);
    }
}
