//! Endpoint backend media models: DDR5 DRAM and three SSD classes
//! (Optane PRAM, Z-NAND ultra-low-latency flash, conventional NAND).
//!
//! The paper's simulator takes memory latencies from DRAMSim3 and device
//! datasheets (Table 1a); per the substitution rule we implement the
//! timing models directly — a bank/row-level DDR5 model ([`dram`]) and a
//! flash model with internal DRAM caching, ingress queueing, garbage
//! collection and wear-leveling ([`ssd`]) — which reproduce the latency
//! *distributions* the SR/DS mechanisms react to.

pub mod dram;
pub mod ssd;

pub use dram::{DramModel, DramTimings};
pub use ssd::{SsdKind, SsdModel, SsdParams};

use crate::sim::Time;

/// Media classes evaluated by the paper (Table 1a).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MediaKind {
    /// DDR5-5600 DRAM expander.
    Ddr5,
    /// Intel Optane P5800X (PRAM): no GC but fine-grained wear-leveling.
    Optane,
    /// Samsung 983 ZET (Z-NAND): ultra-low-latency flash with GC.
    Znand,
    /// Samsung 980 Pro (conventional NAND): slowest, longest GC.
    Nand,
}

impl MediaKind {
    pub fn name(self) -> &'static str {
        match self {
            MediaKind::Ddr5 => "DRAM",
            MediaKind::Optane => "Optane",
            MediaKind::Znand => "Z-NAND",
            MediaKind::Nand => "NAND",
        }
    }

    /// Short letter used by Fig. 9c's column labels (O / Z / N).
    pub fn letter(self) -> &'static str {
        match self {
            MediaKind::Ddr5 => "D",
            MediaKind::Optane => "O",
            MediaKind::Znand => "Z",
            MediaKind::Nand => "N",
        }
    }

    pub fn is_ssd(self) -> bool {
        !matches!(self, MediaKind::Ddr5)
    }
}

/// Counters every media model maintains (consumed by EXPERIMENTS.md rows).
#[derive(Debug, Clone, Default)]
pub struct MediaStats {
    pub reads: u64,
    pub writes: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    /// SSD-internal DRAM cache hits/misses (demand reads only).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Prefetches installed by MemSpecRd.
    pub prefetches: u64,
    /// Garbage-collection episodes and total stalled time.
    pub gc_episodes: u64,
    pub gc_time: Time,
}

impl MediaStats {
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}
