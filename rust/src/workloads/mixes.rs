//! Tenant mix specifications for the pooled-fabric experiments
//! (DESIGN.md §13): who shares the pool, and in what shape.
//!
//! Each mix pairs one *latency-sensitive victim* (small warp count,
//! shallow MLP — a tenant whose p99 matters) with `tenants - 1`
//! *bandwidth hogs* (wide, deep-MLP tenants that saturate the pooled
//! endpoints). The victim's op budget is a quarter of the hogs' so its
//! entire run executes under contention.
//!
//! Workload choices are deliberate: the hog is `sort` (98.7 % loads,
//! Around pattern — a relentless read stream that saturates the pooled
//! SSD channels with almost no writes, keeping GC out of the tail) and
//! the victim is `path` (92.7 % loads, Rand — pointer-chasing graph
//! lookups whose p99 is exactly what a co-tenant's queue buildup
//! destroys).

/// One hog/victim pool scenario.
#[derive(Debug, Clone, Copy)]
pub struct TenantMix {
    pub name: &'static str,
    /// Total tenants: 1 victim + (tenants - 1) hogs.
    pub tenants: usize,
    /// The latency-sensitive tenant's workload.
    pub victim: &'static str,
    /// The bandwidth-hog tenants' workload.
    pub hog: &'static str,
    /// Victim shape: few warps, shallow MLP (low demand).
    pub victim_warps: usize,
    pub victim_mlp: usize,
    /// Hog shape: wide and deep (demand far past its fair share).
    pub hog_warps: usize,
    pub hog_mlp: usize,
}

/// The multi-tenant sweep's scenarios: 2, 4 and 8 tenants sharing one
/// pool, one victim against a growing hog population.
pub static TENANT_MIXES: &[TenantMix] = &[
    TenantMix {
        name: "duo",
        tenants: 2,
        victim: "path",
        hog: "sort",
        victim_warps: 4,
        victim_mlp: 2,
        hog_warps: 32,
        hog_mlp: 8,
    },
    TenantMix {
        name: "quad",
        tenants: 4,
        victim: "path",
        hog: "sort",
        victim_warps: 4,
        victim_mlp: 2,
        hog_warps: 32,
        hog_mlp: 8,
    },
    TenantMix {
        name: "octet",
        tenants: 8,
        victim: "path",
        hog: "sort",
        victim_warps: 4,
        victim_mlp: 2,
        hog_warps: 16,
        hog_mlp: 4,
    },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::table1b::spec;

    #[test]
    fn mixes_reference_real_workloads_and_grow() {
        let mut last = 1;
        for m in TENANT_MIXES {
            // `spec` panics on unknown names: the mix must resolve.
            assert!(spec(m.victim).load_ratio > 0.9, "victim should be load-bound");
            assert!(spec(m.hog).load_ratio > 0.9, "hog should be load-bound");
            assert!(m.tenants > last, "mixes must grow the tenant count");
            last = m.tenants;
            assert!(m.hog_warps * m.hog_mlp > m.victim_warps * m.victim_mlp);
        }
    }
}
