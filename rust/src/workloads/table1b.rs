//! Table 1b: the workload roster with its measured instruction mixes.
//!
//! Compute ratio = compute instructions / all instructions; load ratio =
//! loads / (loads + stores). Categories and ratios are the paper's; the
//! pattern assignments follow the paper's own description of each
//! workload (Fig. 9d's Seq/Around/Rand taxonomy, §Performance Analysis).

use super::patterns::PatternKind;
use super::{Category, OpStream, TraceParams};

/// Static description of one workload.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub category: Category,
    /// Table 1b "Compute Ratio".
    pub compute_ratio: f64,
    /// Table 1b "Load Ratio" (fraction of memory ops that are loads).
    pub load_ratio: f64,
    pub pattern: PatternKind,
}

impl WorkloadSpec {
    /// Per-workload RNG salt so traces differ across workloads.
    pub fn seed_salt(&self) -> u64 {
        self.name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        })
    }

    /// Lazy op stream for one warp of this workload (see
    /// [`OpStream::new`]).
    pub fn stream(&self, p: &TraceParams, warp: usize) -> OpStream {
        OpStream::new(self, p, warp)
    }
}

// Sub-patterns for the composites (need 'static for the enum references).
static SEQ: PatternKind = PatternKind::Seq;
static RAND: PatternKind = PatternKind::Rand;
static AROUND: PatternKind = PatternKind::Around;
static GEMM_TILE: PatternKind = PatternKind::Tiled { tile_bytes: 16 << 10, reuse: 3 };
static CONV_TILE: PatternKind = PatternKind::Tiled { tile_bytes: 8 << 10, reuse: 2 };

/// The full Table 1b roster, in the paper's row order.
pub static ALL_WORKLOADS: &[WorkloadSpec] = &[
    // Compute-intensive.
    WorkloadSpec {
        name: "rsum",
        category: Category::ComputeIntensive,
        compute_ratio: 0.314,
        load_ratio: 0.533,
        pattern: PatternKind::Seq,
    },
    WorkloadSpec {
        name: "stencil",
        category: Category::ComputeIntensive,
        compute_ratio: 0.375,
        load_ratio: 0.725,
        pattern: PatternKind::Tiled { tile_bytes: 8 << 10, reuse: 2 },
    },
    WorkloadSpec {
        name: "sort",
        category: Category::ComputeIntensive,
        compute_ratio: 0.381,
        load_ratio: 0.987,
        pattern: PatternKind::Around,
    },
    // Load-intensive.
    WorkloadSpec {
        name: "gemm",
        category: Category::LoadIntensive,
        compute_ratio: 0.116,
        load_ratio: 0.999,
        pattern: PatternKind::Tiled { tile_bytes: 16 << 10, reuse: 3 },
    },
    WorkloadSpec {
        name: "vadd",
        category: Category::LoadIntensive,
        compute_ratio: 0.156,
        load_ratio: 0.691,
        pattern: PatternKind::Seq,
    },
    WorkloadSpec {
        name: "saxpy",
        category: Category::LoadIntensive,
        compute_ratio: 0.162,
        load_ratio: 0.692,
        pattern: PatternKind::Seq,
    },
    WorkloadSpec {
        name: "conv3",
        category: Category::LoadIntensive,
        compute_ratio: 0.218,
        load_ratio: 0.786,
        pattern: PatternKind::Tiled { tile_bytes: 8 << 10, reuse: 2 },
    },
    WorkloadSpec {
        name: "path",
        category: Category::LoadIntensive,
        compute_ratio: 0.270,
        load_ratio: 0.927,
        pattern: PatternKind::Rand,
    },
    // Store-intensive.
    WorkloadSpec {
        name: "cfd",
        category: Category::StoreIntensive,
        compute_ratio: 0.209,
        load_ratio: 0.426,
        pattern: PatternKind::Seq,
    },
    WorkloadSpec {
        name: "gauss",
        category: Category::StoreIntensive,
        compute_ratio: 0.235,
        load_ratio: 0.485,
        pattern: PatternKind::Around,
    },
    WorkloadSpec {
        name: "bfs",
        category: Category::StoreIntensive,
        compute_ratio: 0.293,
        load_ratio: 0.432,
        pattern: PatternKind::Rand,
    },
    // Real-world composites: gnn = bfs + vadd + gemm; mri = sort + conv3.
    WorkloadSpec {
        name: "gnn",
        category: Category::RealWorld,
        compute_ratio: 0.274,
        load_ratio: 0.738,
        pattern: PatternKind::Composite3 { a: &RAND, b: &SEQ, c: &GEMM_TILE, phase_len: 128 },
    },
    WorkloadSpec {
        name: "mri",
        category: Category::RealWorld,
        compute_ratio: 0.292,
        load_ratio: 0.533,
        pattern: PatternKind::Composite2 { a: &AROUND, b: &CONV_TILE, phase_len: 128 },
    },
];

/// Synthetic hot-fraction sweep for the tiering experiment (DESIGN.md
/// §12): `hotNN` directs NN% of loads at a 64-page (1 MiB) hot set
/// scattered evenly over the input region, the rest at a uniform cold
/// scatter. Not part of Table 1b — the figure suites never run these.
pub static HOT_SWEEP: &[WorkloadSpec] = &[
    WorkloadSpec {
        name: "hot50",
        category: Category::LoadIntensive,
        compute_ratio: 0.15,
        load_ratio: 0.85,
        pattern: PatternKind::HotCold { hot_permille: 500, hot_pages: 64 },
    },
    WorkloadSpec {
        name: "hot75",
        category: Category::LoadIntensive,
        compute_ratio: 0.15,
        load_ratio: 0.85,
        pattern: PatternKind::HotCold { hot_permille: 750, hot_pages: 64 },
    },
    WorkloadSpec {
        name: "hot90",
        category: Category::LoadIntensive,
        compute_ratio: 0.15,
        load_ratio: 0.85,
        pattern: PatternKind::HotCold { hot_permille: 900, hot_pages: 64 },
    },
    WorkloadSpec {
        name: "hot95",
        category: Category::LoadIntensive,
        compute_ratio: 0.15,
        load_ratio: 0.85,
        pattern: PatternKind::HotCold { hot_permille: 950, hot_pages: 64 },
    },
];

/// Look up a workload by name (panics on unknown: test/bench-time
/// input). Resolves the Table 1b roster first, then the [`HOT_SWEEP`]
/// synthetics.
pub fn spec(name: &str) -> &'static WorkloadSpec {
    ALL_WORKLOADS
        .iter()
        .chain(HOT_SWEEP)
        .find(|w| w.name == name)
        .unwrap_or_else(|| panic!("unknown workload `{name}`"))
}

/// Workloads in a category, in table order.
pub fn by_category(cat: Category) -> Vec<&'static WorkloadSpec> {
    ALL_WORKLOADS.iter().filter(|w| w.category == cat).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_workloads() {
        assert_eq!(ALL_WORKLOADS.len(), 13);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(spec("vadd").compute_ratio, 0.156);
        assert_eq!(spec("gemm").load_ratio, 0.999);
    }

    #[test]
    #[should_panic(expected = "unknown workload")]
    fn unknown_name_panics() {
        spec("nope");
    }

    #[test]
    fn categories_partition_roster() {
        let n: usize = [
            Category::ComputeIntensive,
            Category::LoadIntensive,
            Category::StoreIntensive,
            Category::RealWorld,
        ]
        .iter()
        .map(|&c| by_category(c).len())
        .sum();
        assert_eq!(n, 13);
        assert_eq!(by_category(Category::LoadIntensive).len(), 5);
        assert_eq!(by_category(Category::RealWorld).len(), 2);
    }

    #[test]
    fn salts_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        for w in ALL_WORKLOADS.iter().chain(HOT_SWEEP) {
            assert!(seen.insert(w.seed_salt()), "salt collision for {}", w.name);
        }
    }

    #[test]
    fn hot_sweep_resolves_by_name_but_stays_out_of_the_roster() {
        assert_eq!(
            spec("hot90").pattern,
            PatternKind::HotCold { hot_permille: 900, hot_pages: 64 }
        );
        assert_eq!(ALL_WORKLOADS.len(), 13, "Table 1b roster must not grow");
        assert!(ALL_WORKLOADS.iter().all(|w| !w.name.starts_with("hot")));
    }

    #[test]
    fn ratios_are_probabilities() {
        for w in ALL_WORKLOADS {
            assert!((0.0..=1.0).contains(&w.compute_ratio), "{}", w.name);
            assert!((0.0..=1.0).contains(&w.load_ratio), "{}", w.name);
        }
    }
}
