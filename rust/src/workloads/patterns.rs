//! Memory access pattern generators: the Seq / Around / Rand taxonomy of
//! Fig. 9d plus tiled 2D reuse and the real-world composites.
//!
//! Streaming (Seq/Tiled) kinds model *coalesced* GPU access: all warps
//! sweep one shared region together, each taking every W-th line (the
//! CUDA `base + tid` idiom after 64 B coalescing). This matters: it makes
//! the combined request stream at the root port dense and monotone —
//! exactly the stream SR's 256 B–1 KiB windows exploit — and keeps the
//! page-level working set small (what UVM's migration heuristics assume).
//!
//! Loads draw from the lower (input) portion of the footprint and stores
//! from the upper sixth (output), mirroring the Rodinia kernels' separate
//! input/output buffers.

use crate::gpu::LINE;
use crate::util::prng::Pcg32;

/// Page size of the [`PatternKind::HotCold`] hot set. Matches the
/// tiering subsystem's default migration unit (`TierConfig::page_bytes`)
/// so one hot page is exactly one migratable unit.
pub const HOT_PAGE_BYTES: u64 = 16 << 10;

/// Pattern taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatternKind {
    /// Monotonically ascending coalesced stream (vadd, saxpy, rsum, cfd).
    Seq,
    /// Descending stream (reverse traversal; exercises the address
    /// window's backwards extension).
    SeqReverse,
    /// Spatially local but direction-undecided (sort, gauss): a random
    /// walk with bounded step around a drifting cursor.
    Around,
    /// Irregular (path, bfs): uniform over the footprint.
    Rand,
    /// 2D tiled with intra-tile reuse (gemm, conv3, stencil): warps
    /// cooperate on a shared tile that is swept `reuse` times.
    Tiled { tile_bytes: u64, reuse: u32 },
    /// Skewed hot/cold mix for the tiering sweep (DESIGN.md §12):
    /// `hot_permille`/1000 of the loads land uniformly on a hot set of
    /// `hot_pages` pages ([`HOT_PAGE_BYTES`] each) spread evenly across
    /// the input region; the rest scatter uniformly. The scatter keeps
    /// any static placement honest — hot pages land on both tiers of a
    /// hybrid topology, so only migration can concentrate them on DRAM.
    HotCold { hot_permille: u32, hot_pages: u32 },
    /// Phase composite (gnn = bfs+vadd+gemm, mri = sort+conv3): cycles
    /// through sub-patterns every `phase_len` accesses.
    Composite2 { a: &'static PatternKind, b: &'static PatternKind, phase_len: u32 },
    Composite3 {
        a: &'static PatternKind,
        b: &'static PatternKind,
        c: &'static PatternKind,
        phase_len: u32,
    },
}

/// A warp's stateful address generator.
#[derive(Debug)]
pub struct Pattern {
    kind: PatternKind,
    /// Shared input region [lo, hi) and this warp's interleave step.
    lo: u64,
    hi: u64,
    step: u64,
    /// Store region (shared, interleaved).
    st_lo: u64,
    st_hi: u64,
    cursor: u64,
    st_cursor: u64,
    /// Tiled state.
    tile_off: u64,
    tile_pos: u64,
    visits: u32,
    /// Around state (per-warp local region).
    around_lo: u64,
    around_hi: u64,
    /// HotCold state: hot pages sit at page indices `0, stride, 2*stride,
    /// ...` of the input region ([`HOT_PAGE_BYTES`] pages).
    hot_stride: u64,
    hot_n: u64,
    /// Composite state.
    phase: u32,
    count: u32,
    sub: Vec<Pattern>,
}

impl Pattern {
    pub fn new(
        kind: PatternKind,
        footprint: u64,
        warp: usize,
        warps: usize,
        rng: &mut Pcg32,
    ) -> Pattern {
        // Output region = top 1/6 of the footprint; inputs below it.
        let store_base = (footprint - footprint / 6) & !(LINE - 1);
        let w = warp as u64;
        let nw = warps as u64;
        let step = nw * LINE;

        // Around: per-warp local window (binary-tree subtrees differ per
        // thread), sized 1/warps of the input space.
        let around_span = ((store_base / nw) & !(LINE - 1)).max(LINE);
        let around_lo = w * around_span;
        let around_hi = around_lo + around_span;

        let sub = match kind {
            PatternKind::Composite2 { a, b, .. } => vec![
                Pattern::new(*a, footprint, warp, warps, rng),
                Pattern::new(*b, footprint, warp, warps, rng),
            ],
            PatternKind::Composite3 { a, b, c, .. } => vec![
                Pattern::new(*a, footprint, warp, warps, rng),
                Pattern::new(*b, footprint, warp, warps, rng),
                Pattern::new(*c, footprint, warp, warps, rng),
            ],
            _ => Vec::new(),
        };

        let cursor = match kind {
            PatternKind::SeqReverse => store_base - (w + 1) * LINE,
            PatternKind::Around => (around_lo + around_span / 2) & !(LINE - 1),
            _ => w * LINE,
        };
        let (hot_stride, hot_n) = match kind {
            PatternKind::HotCold { hot_pages, .. } => {
                let input_pages = (store_base / HOT_PAGE_BYTES).max(1);
                let n = (hot_pages as u64).clamp(1, input_pages);
                ((input_pages / n).max(1), n)
            }
            _ => (0, 0),
        };
        Pattern {
            kind,
            lo: 0,
            hi: store_base,
            step,
            st_lo: store_base,
            st_hi: footprint,
            cursor,
            st_cursor: store_base + w * LINE,
            tile_off: 0,
            tile_pos: w * LINE,
            visits: 0,
            around_lo,
            around_hi,
            hot_stride,
            hot_n,
            phase: 0,
            count: 0,
            sub,
        }
    }

    /// Resident size of this pattern's state in bytes (inline struct plus
    /// the composite sub-pattern heap). This — times the warp count — is
    /// the entire address-generation memory of a streamed scenario, so
    /// the `trace_stream` bench reports it as the O(warps) side of the
    /// memory model (DESIGN.md §11).
    pub fn state_bytes(&self) -> usize {
        std::mem::size_of::<Pattern>()
            + self.sub.iter().map(|s| s.state_bytes()).sum::<usize>()
    }

    fn wrap_input(&self, a: u64) -> u64 {
        let span = self.hi - self.lo;
        self.lo + (a - self.lo) % span
    }

    /// Next load address.
    pub fn next_load(&mut self, rng: &mut Pcg32) -> u64 {
        match self.kind {
            PatternKind::Seq => {
                let a = self.cursor;
                self.cursor = self.wrap_input(self.cursor + self.step);
                a
            }
            PatternKind::SeqReverse => {
                let a = self.cursor;
                self.cursor = if self.cursor < self.lo + self.step {
                    self.hi - (self.lo + self.step - self.cursor)
                } else {
                    self.cursor - self.step
                };
                a
            }
            PatternKind::Around => {
                // Bounded random walk with slow forward drift inside the
                // warp's subtree window.
                let step = (rng.below(4) + 1) * LINE;
                let span = self.around_hi - self.around_lo;
                let fwd = rng.chance(0.52);
                let mut c = self.cursor;
                if fwd {
                    c += step;
                    if c >= self.around_hi {
                        c = self.around_lo + (c - self.around_hi) % span;
                    }
                } else {
                    c = if c < self.around_lo + step {
                        self.around_hi - (self.around_lo + step - c) % span
                    } else {
                        c - step
                    };
                }
                self.cursor = c & !(LINE - 1);
                self.cursor
            }
            PatternKind::Rand => {
                // Frontier-style irregularity (Rodinia bfs/path): most
                // accesses land in a slowly-drifting hot window (the
                // current frontier), the rest scatter globally. Pure
                // uniform access would be far harsher than the real
                // graph workloads the paper measured.
                let span_lines = (self.hi - self.lo) / LINE;
                let hot_lines = (span_lines / 16).max(1);
                let a = if rng.chance(0.95) {
                    let base = (self.cursor / LINE) % span_lines;
                    self.lo + ((base + rng.below(hot_lines)) % span_lines) * LINE
                } else {
                    self.lo + rng.below(span_lines.max(1)) * LINE
                };
                // The frontier drifts forward slowly.
                self.cursor += LINE / 4 + 16;
                a
            }
            PatternKind::HotCold { hot_permille, .. } => {
                // Draw order is fixed (hot-Bernoulli, then one address
                // draw) so streams stay bit-reproducible.
                if rng.chance(hot_permille as f64 / 1000.0) {
                    let page = rng.below(self.hot_n) * self.hot_stride;
                    let line = rng.below(HOT_PAGE_BYTES / LINE);
                    self.lo + page * HOT_PAGE_BYTES + line * LINE
                } else {
                    let span_lines = (self.hi - self.lo) / LINE;
                    self.lo + rng.below(span_lines.max(1)) * LINE
                }
            }
            PatternKind::Tiled { tile_bytes, reuse } => {
                // All warps sweep the shared tile cooperatively; each tile
                // is swept `reuse` times before advancing (CUDA-block
                // shared-memory reuse).
                let a = self.tile_off + self.tile_pos;
                self.tile_pos += self.step;
                if self.tile_pos >= tile_bytes {
                    self.tile_pos -= tile_bytes; // next sweep of this tile
                    self.visits += 1;
                    if self.visits >= reuse {
                        self.visits = 0;
                        self.tile_off += tile_bytes;
                        if self.tile_off + tile_bytes > self.hi {
                            self.tile_off = self.lo;
                        }
                    }
                }
                self.wrap_input(a)
            }
            PatternKind::Composite2 { phase_len, .. } => {
                self.advance_phase(phase_len, 2);
                let p = self.phase as usize;
                self.sub[p].next_load(rng)
            }
            PatternKind::Composite3 { phase_len, .. } => {
                self.advance_phase(phase_len, 3);
                let p = self.phase as usize;
                self.sub[p].next_load(rng)
            }
        }
    }

    fn advance_phase(&mut self, phase_len: u32, phases: u32) {
        self.count += 1;
        if self.count >= phase_len {
            self.count = 0;
            self.phase = (self.phase + 1) % phases;
        }
    }

    /// Next store address (shared output region, coalesced interleave;
    /// Rand kinds scatter).
    pub fn next_store(&mut self, rng: &mut Pcg32) -> u64 {
        match self.kind {
            PatternKind::Rand => {
                let span = (self.st_hi - self.st_lo) / LINE;
                self.st_lo + rng.below(span.max(1)) * LINE
            }
            PatternKind::Composite2 { .. } | PatternKind::Composite3 { .. } => {
                let p = self.phase as usize;
                self.sub[p].next_store(rng)
            }
            _ => {
                let a = self.st_cursor;
                self.st_cursor += self.step;
                if self.st_cursor >= self.st_hi {
                    let span = self.st_hi - self.st_lo;
                    self.st_cursor = self.st_lo + (self.st_cursor - self.st_lo) % span;
                }
                a
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FOOT: u64 = 4 << 20;
    const WARPS: usize = 4;

    fn pat(kind: PatternKind, warp: usize) -> (Pattern, Pcg32) {
        let mut rng = Pcg32::new(7, warp as u64);
        let p = Pattern::new(kind, FOOT, warp, WARPS, &mut rng);
        (p, rng)
    }

    #[test]
    fn seq_interleaves_across_warps() {
        // Warp w starts at w*LINE and strides by warps*LINE: the union of
        // all warps' first accesses is a dense run of lines.
        let mut firsts = Vec::new();
        for w in 0..WARPS {
            let (mut p, mut rng) = pat(PatternKind::Seq, w);
            firsts.push(p.next_load(&mut rng));
        }
        firsts.sort_unstable();
        for (i, a) in firsts.iter().enumerate() {
            assert_eq!(*a, i as u64 * LINE);
        }
    }

    #[test]
    fn seq_strides_by_warp_count() {
        let (mut p, mut rng) = pat(PatternKind::Seq, 1);
        let a = p.next_load(&mut rng);
        let b = p.next_load(&mut rng);
        assert_eq!(b - a, WARPS as u64 * LINE);
    }

    #[test]
    fn seq_reverse_descends() {
        let (mut p, mut rng) = pat(PatternKind::SeqReverse, 0);
        let a = p.next_load(&mut rng);
        let b = p.next_load(&mut rng);
        assert_eq!(a - b, WARPS as u64 * LINE);
    }

    #[test]
    fn around_stays_in_warp_window() {
        let (mut p, mut rng) = pat(PatternKind::Around, 2);
        let store_base = FOOT - FOOT / 6;
        let span = store_base / WARPS as u64 & !(LINE - 1);
        for _ in 0..500 {
            let a = p.next_load(&mut rng);
            assert!(a >= 2 * span && a < 3 * span, "{a:#x} outside warp-2 window");
        }
    }

    #[test]
    fn around_moves_both_directions() {
        let (mut p, mut rng) = pat(PatternKind::Around, 0);
        let mut up = 0;
        let mut down = 0;
        let mut prev = p.next_load(&mut rng);
        for _ in 0..300 {
            let a = p.next_load(&mut rng);
            if a > prev {
                up += 1;
            } else if a < prev {
                down += 1;
            }
            prev = a;
        }
        assert!(up > 50 && down > 50, "walk must go both ways: up {up} down {down}");
    }

    #[test]
    fn rand_covers_widely() {
        let (mut p, mut rng) = pat(PatternKind::Rand, 0);
        let mut set = std::collections::HashSet::new();
        for _ in 0..1000 {
            set.insert(p.next_load(&mut rng));
        }
        assert!(set.len() > 800, "only {} distinct", set.len());
    }

    #[test]
    fn tiled_stays_within_tile_until_advancing() {
        let tile = 16 * LINE;
        let (mut p, mut rng) = pat(PatternKind::Tiled { tile_bytes: tile, reuse: 2 }, 0);
        // With 4 warps and reuse 2, warp 0 makes 2*16/4 = 8 accesses in
        // tile 0 before moving on.
        let mut addrs = Vec::new();
        for _ in 0..8 {
            addrs.push(p.next_load(&mut rng));
        }
        assert!(addrs.iter().all(|&a| a < tile), "left tile early: {addrs:?}");
        let next = p.next_load(&mut rng);
        assert!(next >= tile, "should advance to next tile, got {next:#x}");
    }

    #[test]
    fn hotcold_respects_the_hot_fraction() {
        let kind = PatternKind::HotCold { hot_permille: 900, hot_pages: 16 };
        let (mut p, mut rng) = pat(kind, 0);
        // Reconstruct the hot set the same way Pattern::new does.
        let store_base = FOOT - FOOT / 6;
        let input_pages = store_base / HOT_PAGE_BYTES;
        let stride = input_pages / 16;
        let is_hot = |a: u64| (a / HOT_PAGE_BYTES) % stride == 0;
        let mut hot = 0;
        let n = 4000;
        for _ in 0..n {
            let a = p.next_load(&mut rng);
            assert!(a < store_base, "{a:#x} outside the input region");
            if is_hot(a) {
                hot += 1;
            }
        }
        // 90% targeted + the sliver of uniform scatter that happens to
        // land on hot pages; 2σ of a 0.9 Bernoulli over 4000 draws ≈ 1%.
        let frac = hot as f64 / n as f64;
        assert!((0.87..=0.97).contains(&frac), "hot fraction {frac}");
    }

    #[test]
    fn hotcold_hot_set_spans_few_distinct_pages() {
        let kind = PatternKind::HotCold { hot_permille: 1000, hot_pages: 16 };
        let (mut p, mut rng) = pat(kind, 1);
        let mut pages = std::collections::HashSet::new();
        for _ in 0..2000 {
            pages.insert(p.next_load(&mut rng) / HOT_PAGE_BYTES);
        }
        assert!(pages.len() <= 16, "hot set leaked: {} pages", pages.len());
        assert!(pages.len() >= 12, "hot set barely sampled: {} pages", pages.len());
    }

    #[test]
    fn stores_land_in_output_region() {
        let (mut p, mut rng) = pat(PatternKind::Seq, 1);
        let store_base = FOOT - FOOT / 6 & !(LINE - 1);
        for _ in 0..100 {
            let a = p.next_store(&mut rng);
            assert!(a >= store_base, "{a:#x} below store region");
            assert!(a < FOOT);
        }
    }

    #[test]
    fn state_bytes_counts_composite_subpatterns() {
        static SEQ: PatternKind = PatternKind::Seq;
        static RAND: PatternKind = PatternKind::Rand;
        let (seq, _) = pat(PatternKind::Seq, 0);
        let (comp, _) = pat(PatternKind::Composite2 { a: &SEQ, b: &RAND, phase_len: 8 }, 0);
        assert_eq!(seq.state_bytes(), std::mem::size_of::<Pattern>());
        assert_eq!(comp.state_bytes(), 3 * std::mem::size_of::<Pattern>());
    }

    #[test]
    fn composite_cycles_phases() {
        static SEQ: PatternKind = PatternKind::Seq;
        static RAND: PatternKind = PatternKind::Rand;
        let (mut p, mut rng) =
            pat(PatternKind::Composite2 { a: &SEQ, b: &RAND, phase_len: 8 }, 0);
        let mut addrs = Vec::new();
        for _ in 0..64 {
            addrs.push(p.next_load(&mut rng));
        }
        let jumps = addrs.windows(2).filter(|w| w[1].abs_diff(w[0]) > 64 * LINE).count();
        assert!(jumps > 0, "composite never switched phase");
    }
}
