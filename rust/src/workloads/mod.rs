//! The evaluation workload suite (Table 1b): 11 Rodinia-style programs
//! plus the two real-world composites (gnn, mri).
//!
//! Each workload is characterized by its instruction mix (compute ratio,
//! load ratio — Table 1b's two columns) and its memory access pattern
//! (the Seq / Around / Rand taxonomy of Fig. 9d, plus tiled reuse for the
//! 2D kernels). Per-warp instruction streams are generated *lazily*: an
//! [`OpStream`] owns the warp's RNG and pattern state and yields one `Op`
//! at a time, so simulation memory is O(warps) — independent of the op
//! budget — and trace generation overlaps execution instead of preceding
//! it. [`collect_trace`] keeps the original eager materialization as the
//! executable reference the streaming path is property-tested against
//! (DESIGN.md §11).
//!
//! The *compute results* of these workloads come from the real JAX/Pallas
//! kernels executed through PJRT (`runtime/`); the *timing* comes from
//! these streams. Both describe the same programs.

pub mod mixes;
pub mod patterns;
pub mod table1b;

pub use mixes::{TenantMix, TENANT_MIXES};
pub use patterns::{Pattern, PatternKind};
pub use table1b::{WorkloadSpec, ALL_WORKLOADS};

use crate::gpu::{Op, OpSource, LINE};
use crate::sim::{Time, NS};
use crate::util::prng::Pcg32;

/// Category labels used by the figure benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    ComputeIntensive,
    LoadIntensive,
    StoreIntensive,
    RealWorld,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::ComputeIntensive => "compute-intensive",
            Category::LoadIntensive => "load-intensive",
            Category::StoreIntensive => "store-intensive",
            Category::RealWorld => "real-world",
        }
    }
}

/// Parameters controlling trace generation.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    /// Total data footprint in bytes (paper: 10x the GPU local memory).
    pub footprint: u64,
    /// Number of warps (Table 1a: 8 cores x 8 threads).
    pub warps: usize,
    /// Total dynamic instructions across all warps.
    pub total_ops: usize,
    /// RNG seed.
    pub seed: u64,
    /// Base duration of one compute burst.
    pub compute_ns: Time,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            footprint: 40 << 20,
            warps: 64,
            total_ops: 300_000,
            seed: 0xC11A,
            compute_ns: 8 * NS,
        }
    }
}

/// One warp's lazy op stream: the RNG + pattern state that the old
/// materialized trace row was generated from, now owned by the stream and
/// advanced one op per `OpStream::next` call.
///
/// Equivalence contract: for identical `(spec, params, warp)`, the yielded
/// sequence is bit-identical to the corresponding [`collect_trace`] row —
/// same RNG construction, same per-op draw order. Enforced by
/// `tests/props.rs::prop_stream_matches_materialized_trace`.
#[derive(Debug)]
pub struct OpStream {
    rng: Pcg32,
    pat: Pattern,
    compute_ratio: f64,
    load_ratio: f64,
    compute_ns: Time,
    remaining: usize,
}

impl OpStream {
    /// Stream for warp `warp` of `spec` under `p`.
    pub fn new(spec: &WorkloadSpec, p: &TraceParams, warp: usize) -> OpStream {
        let mut rng = Pcg32::new(p.seed ^ spec.seed_salt(), warp as u64);
        let pat = Pattern::new(spec.pattern, p.footprint, warp, p.warps, &mut rng);
        OpStream {
            rng,
            pat,
            compute_ratio: spec.compute_ratio,
            load_ratio: spec.load_ratio,
            compute_ns: p.compute_ns,
            remaining: p.total_ops / p.warps,
        }
    }

    /// Ops not yet yielded.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// Resident state in bytes (inline struct + pattern heap): the whole
    /// per-warp memory cost of a streamed scenario, independent of
    /// `total_ops`. Reported by the `trace_stream` bench.
    pub fn state_bytes(&self) -> usize {
        std::mem::size_of::<OpStream>() - std::mem::size_of::<Pattern>()
            + self.pat.state_bytes()
    }
}

impl Iterator for OpStream {
    type Item = Op;

    fn next(&mut self) -> Option<Op> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        Some(if self.rng.chance(self.compute_ratio) {
            // Compute burst: base +/- 50% jitter.
            let jitter = (self.rng.f64() - 0.5) * self.compute_ns as f64;
            let dur = (self.compute_ns as f64 + jitter).max(500.0) as Time;
            Op::Compute { dur }
        } else if self.rng.chance(self.load_ratio) {
            Op::Load { addr: self.pat.next_load(&mut self.rng) }
        } else {
            Op::Store { addr: self.pat.next_store(&mut self.rng) }
        })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl OpSource for OpStream {
    fn next_op(&mut self) -> Option<Op> {
        self.next()
    }

    fn remaining_hint(&self) -> usize {
        self.remaining
    }
}

/// Materialize the full per-warp traces eagerly.
///
/// This keeps the *original* generator loop verbatim as the executable
/// specification the streaming path is checked against; it is also the
/// convenient form for tests and trace analyses. The simulator itself
/// never calls this — `System` builds one [`OpStream`] per warp.
pub fn collect_trace(spec: &WorkloadSpec, p: &TraceParams) -> Vec<Vec<Op>> {
    let per_warp = p.total_ops / p.warps;
    let mut out = Vec::with_capacity(p.warps);
    for w in 0..p.warps {
        let mut rng = Pcg32::new(p.seed ^ spec.seed_salt(), w as u64);
        let mut pat = Pattern::new(spec.pattern, p.footprint, w, p.warps, &mut rng);
        let mut ops = Vec::with_capacity(per_warp);
        for _ in 0..per_warp {
            if rng.chance(spec.compute_ratio) {
                let jitter = (rng.f64() - 0.5) * p.compute_ns as f64;
                let dur = (p.compute_ns as f64 + jitter).max(500.0) as Time;
                ops.push(Op::Compute { dur });
            } else if rng.chance(spec.load_ratio) {
                ops.push(Op::Load { addr: pat.next_load(&mut rng) });
            } else {
                ops.push(Op::Store { addr: pat.next_store(&mut rng) });
            }
        }
        out.push(ops);
    }
    out
}

/// Measured instruction mix of a trace (for the Table 1b bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceMix {
    pub computes: u64,
    pub loads: u64,
    pub stores: u64,
}

impl TraceMix {
    pub fn of(trace: &[Vec<Op>]) -> TraceMix {
        let mut m = TraceMix::default();
        for ops in trace {
            for op in ops {
                m.count(op);
            }
        }
        m
    }

    /// Mix of a workload's full streamed trace, without materializing it:
    /// every warp's stream is consumed and tallied on the fly, so the
    /// accounting runs in O(warps) memory at any op budget.
    pub fn of_stream(spec: &WorkloadSpec, p: &TraceParams) -> TraceMix {
        let mut m = TraceMix::default();
        for w in 0..p.warps {
            for op in spec.stream(p, w) {
                m.count(&op);
            }
        }
        m
    }

    fn count(&mut self, op: &Op) {
        match op {
            Op::Compute { .. } => self.computes += 1,
            Op::Load { .. } => self.loads += 1,
            Op::Store { .. } => self.stores += 1,
        }
    }

    pub fn total(&self) -> u64 {
        self.computes + self.loads + self.stores
    }

    pub fn compute_ratio(&self) -> f64 {
        self.computes as f64 / self.total().max(1) as f64
    }

    /// Loads as a fraction of memory operations (Table 1b's load ratio).
    pub fn load_ratio(&self) -> f64 {
        let mem = self.loads + self.stores;
        if mem == 0 {
            0.0
        } else {
            self.loads as f64 / mem as f64
        }
    }
}

/// Unique 64 B lines touched by a trace (footprint check).
pub fn distinct_lines(trace: &[Vec<Op>]) -> usize {
    let mut set = std::collections::HashSet::new();
    for ops in trace {
        for op in ops {
            match op {
                Op::Load { addr } | Op::Store { addr } => {
                    set.insert(addr / LINE);
                }
                _ => {}
            }
        }
    }
    set.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::table1b::spec;

    #[test]
    fn mix_matches_table1b_within_tolerance() {
        let p = TraceParams { total_ops: 64_000, ..Default::default() };
        for spec in ALL_WORKLOADS {
            let mix = TraceMix::of_stream(spec, &p);
            assert!(
                (mix.compute_ratio() - spec.compute_ratio).abs() < 0.03,
                "{}: compute ratio {} vs spec {}",
                spec.name,
                mix.compute_ratio(),
                spec.compute_ratio
            );
            assert!(
                (mix.load_ratio() - spec.load_ratio).abs() < 0.04,
                "{}: load ratio {} vs spec {}",
                spec.name,
                mix.load_ratio(),
                spec.load_ratio
            );
        }
    }

    #[test]
    fn streamed_mix_equals_materialized_mix() {
        let p = TraceParams { total_ops: 20_000, ..Default::default() };
        for spec in ALL_WORKLOADS {
            let eager = TraceMix::of(&collect_trace(spec, &p));
            let lazy = TraceMix::of_stream(spec, &p);
            assert_eq!(eager.computes, lazy.computes, "{}", spec.name);
            assert_eq!(eager.loads, lazy.loads, "{}", spec.name);
            assert_eq!(eager.stores, lazy.stores, "{}", spec.name);
        }
    }

    #[test]
    fn stream_matches_trace_row_for_row() {
        let p = TraceParams { total_ops: 12_000, ..Default::default() };
        for name in ["vadd", "bfs", "gnn"] {
            let trace = collect_trace(spec(name), &p);
            for (w, row) in trace.iter().enumerate() {
                let streamed: Vec<Op> = OpStream::new(spec(name), &p, w).collect();
                assert_eq!(&streamed, row, "{name} warp {w}");
            }
        }
    }

    #[test]
    fn traces_are_deterministic() {
        let p = TraceParams { total_ops: 10_000, ..Default::default() };
        let a = collect_trace(spec("vadd"), &p);
        let b = collect_trace(spec("vadd"), &p);
        assert_eq!(a, b);
    }

    #[test]
    fn different_workloads_differ() {
        let p = TraceParams { total_ops: 10_000, ..Default::default() };
        let a = collect_trace(spec("vadd"), &p);
        let b = collect_trace(spec("bfs"), &p);
        assert_ne!(a, b);
    }

    #[test]
    fn addresses_stay_in_footprint() {
        let p = TraceParams { total_ops: 50_000, footprint: 8 << 20, ..Default::default() };
        for name in ["vadd", "sort", "bfs", "gemm", "gnn", "mri"] {
            for w in 0..p.warps {
                for op in OpStream::new(spec(name), &p, w) {
                    if let Op::Load { addr } | Op::Store { addr } = op {
                        assert!(addr < p.footprint, "{name}: {addr:#x} out of range");
                    }
                }
            }
        }
    }

    #[test]
    fn seq_workloads_touch_many_distinct_lines() {
        let p = TraceParams { total_ops: 100_000, ..Default::default() };
        let vadd_lines = distinct_lines(&collect_trace(spec("vadd"), &p));
        let gemm_lines = distinct_lines(&collect_trace(spec("gemm"), &p));
        // Streaming vadd covers far more distinct lines than tiled gemm
        // (which re-reads its tiles).
        assert!(vadd_lines > gemm_lines, "vadd {vadd_lines} <= gemm {gemm_lines}");
    }

    #[test]
    fn stream_state_is_small_and_op_budget_free() {
        // The whole point: per-warp state must not scale with total_ops.
        let small = TraceParams { total_ops: 1_000, ..Default::default() };
        let huge = TraceParams { total_ops: 10_000_000, ..Default::default() };
        for spec in ALL_WORKLOADS {
            let a = OpStream::new(spec, &small, 0).state_bytes();
            let b = OpStream::new(spec, &huge, 0).state_bytes();
            assert_eq!(a, b, "{}: state must be op-budget independent", spec.name);
            assert!(a < 4096, "{}: {a} B per warp is not O(1)", spec.name);
        }
    }

    #[test]
    fn stream_remaining_counts_down() {
        let p = TraceParams { total_ops: 6_400, ..Default::default() };
        let mut s = OpStream::new(spec("vadd"), &p, 3);
        let per_warp = p.total_ops / p.warps;
        assert_eq!(s.remaining(), per_warp);
        assert_eq!(s.size_hint(), (per_warp, Some(per_warp)));
        s.next().unwrap();
        assert_eq!(s.remaining(), per_warp - 1);
        assert_eq!(s.by_ref().count(), per_warp - 1);
        assert_eq!(s.next(), None, "exhausted stream stays exhausted");
    }
}
