//! The evaluation workload suite (Table 1b): 11 Rodinia-style programs
//! plus the two real-world composites (gnn, mri).
//!
//! Each workload is characterized by its instruction mix (compute ratio,
//! load ratio — Table 1b's two columns) and its memory access pattern
//! (the Seq / Around / Rand taxonomy of Fig. 9d, plus tiled reuse for the
//! 2D kernels). Generators materialize per-warp instruction streams that
//! the coordinator's `System` executes against any memory configuration.
//!
//! The *compute results* of these workloads come from the real JAX/Pallas
//! kernels executed through PJRT (`runtime/`); the *timing* comes from
//! these streams. Both describe the same programs.

pub mod patterns;
pub mod table1b;

pub use patterns::{Pattern, PatternKind};
pub use table1b::{WorkloadSpec, ALL_WORKLOADS};

use crate::gpu::{Op, LINE};
use crate::sim::{Time, NS};
use crate::util::prng::Pcg32;

/// Category labels used by the figure benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    ComputeIntensive,
    LoadIntensive,
    StoreIntensive,
    RealWorld,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::ComputeIntensive => "compute-intensive",
            Category::LoadIntensive => "load-intensive",
            Category::StoreIntensive => "store-intensive",
            Category::RealWorld => "real-world",
        }
    }
}

/// Parameters controlling trace materialization.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    /// Total data footprint in bytes (paper: 10x the GPU local memory).
    pub footprint: u64,
    /// Number of warps (Table 1a: 8 cores x 8 threads).
    pub warps: usize,
    /// Total dynamic instructions across all warps.
    pub total_ops: usize,
    /// RNG seed.
    pub seed: u64,
    /// Base duration of one compute burst.
    pub compute_ns: Time,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            footprint: 40 << 20,
            warps: 64,
            total_ops: 300_000,
            seed: 0xC11A,
            compute_ns: 8 * NS,
        }
    }
}

/// Materialize per-warp op streams for a workload.
pub fn generate(spec: &WorkloadSpec, p: &TraceParams) -> Vec<Vec<Op>> {
    let per_warp = p.total_ops / p.warps;
    let mut out = Vec::with_capacity(p.warps);
    for w in 0..p.warps {
        let mut rng = Pcg32::new(p.seed ^ spec.seed_salt(), w as u64);
        let mut pat = Pattern::new(spec.pattern, p.footprint, w, p.warps, &mut rng);
        let mut ops = Vec::with_capacity(per_warp);
        for _ in 0..per_warp {
            if rng.chance(spec.compute_ratio) {
                // Compute burst: base +/- 50% jitter.
                let jitter = (rng.f64() - 0.5) * p.compute_ns as f64;
                let dur = (p.compute_ns as f64 + jitter).max(500.0) as Time;
                ops.push(Op::Compute { dur });
            } else if rng.chance(spec.load_ratio) {
                ops.push(Op::Load { addr: pat.next_load(&mut rng) });
            } else {
                ops.push(Op::Store { addr: pat.next_store(&mut rng) });
            }
        }
        out.push(ops);
    }
    out
}

/// Measured instruction mix of a generated trace (for the Table 1b bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct TraceMix {
    pub computes: u64,
    pub loads: u64,
    pub stores: u64,
}

impl TraceMix {
    pub fn of(trace: &[Vec<Op>]) -> TraceMix {
        let mut m = TraceMix::default();
        for ops in trace {
            for op in ops {
                match op {
                    Op::Compute { .. } => m.computes += 1,
                    Op::Load { .. } => m.loads += 1,
                    Op::Store { .. } => m.stores += 1,
                }
            }
        }
        m
    }

    pub fn total(&self) -> u64 {
        self.computes + self.loads + self.stores
    }

    pub fn compute_ratio(&self) -> f64 {
        self.computes as f64 / self.total().max(1) as f64
    }

    /// Loads as a fraction of memory operations (Table 1b's load ratio).
    pub fn load_ratio(&self) -> f64 {
        let mem = self.loads + self.stores;
        if mem == 0 {
            0.0
        } else {
            self.loads as f64 / mem as f64
        }
    }
}

/// Unique 64 B lines touched by a trace (footprint check).
pub fn distinct_lines(trace: &[Vec<Op>]) -> usize {
    let mut set = std::collections::HashSet::new();
    for ops in trace {
        for op in ops {
            match op {
                Op::Load { addr } | Op::Store { addr } => {
                    set.insert(addr / LINE);
                }
                _ => {}
            }
        }
    }
    set.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::table1b::spec;

    #[test]
    fn mix_matches_table1b_within_tolerance() {
        let p = TraceParams { total_ops: 64_000, ..Default::default() };
        for spec in ALL_WORKLOADS {
            let trace = generate(spec, &p);
            let mix = TraceMix::of(&trace);
            assert!(
                (mix.compute_ratio() - spec.compute_ratio).abs() < 0.03,
                "{}: compute ratio {} vs spec {}",
                spec.name,
                mix.compute_ratio(),
                spec.compute_ratio
            );
            assert!(
                (mix.load_ratio() - spec.load_ratio).abs() < 0.04,
                "{}: load ratio {} vs spec {}",
                spec.name,
                mix.load_ratio(),
                spec.load_ratio
            );
        }
    }

    #[test]
    fn traces_are_deterministic() {
        let p = TraceParams { total_ops: 10_000, ..Default::default() };
        let a = generate(spec("vadd"), &p);
        let b = generate(spec("vadd"), &p);
        assert_eq!(a, b);
    }

    #[test]
    fn different_workloads_differ() {
        let p = TraceParams { total_ops: 10_000, ..Default::default() };
        let a = generate(spec("vadd"), &p);
        let b = generate(spec("bfs"), &p);
        assert_ne!(a, b);
    }

    #[test]
    fn addresses_stay_in_footprint() {
        let p = TraceParams { total_ops: 50_000, footprint: 8 << 20, ..Default::default() };
        for name in ["vadd", "sort", "bfs", "gemm", "gnn", "mri"] {
            let trace = generate(spec(name), &p);
            for ops in &trace {
                for op in ops {
                    if let Op::Load { addr } | Op::Store { addr } = op {
                        assert!(*addr < p.footprint, "{name}: {addr:#x} out of range");
                    }
                }
            }
        }
    }

    #[test]
    fn seq_workloads_touch_many_distinct_lines() {
        let p = TraceParams { total_ops: 100_000, ..Default::default() };
        let vadd_lines = distinct_lines(&generate(spec("vadd"), &p));
        let gemm_lines = distinct_lines(&generate(spec("gemm"), &p));
        // Streaming vadd covers far more distinct lines than tiled gemm
        // (which re-reads its tiles).
        assert!(vadd_lines > gemm_lines, "vadd {vadd_lines} <= gemm {gemm_lines}");
    }
}
