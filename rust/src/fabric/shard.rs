//! Sharded pool coordinator: the parallel twin of [`super::pool`],
//! bit-identical to it by construction.
//!
//! [`run_pool_sharded`] partitions a pool's tenants into contiguous
//! shards and drives them through the conservative-lookahead engine
//! ([`crate::sim::run_conservative`]): worker threads advance each
//! shard's tenants independently with every fabric interaction
//! *deferred*, then a serial barrier phase replays the deferred
//! interactions against the shared switch in exactly the global
//! `(time, tenant, program order)` the serial [`run_pool`] coordinator
//! would have produced. Same switch-call sequence, same per-tenant RNG
//! draw order, same floating-point accumulation order — so every
//! `RunMetrics::fingerprint()` and the pool sums match the serial run
//! bit-for-bit, for any shard count and any worker count. DESIGN.md §17
//! gives the full argument; `tests/props.rs` and
//! `benches/pool_scale.rs` enforce it.
//!
//! The lookahead window is the switch's round-trip hop cost
//! (`2 * hop_lat`): with two or more tenants the switch is never in
//! passthrough mode, so every deferred load's fill is at least that far
//! in the deferring tenant's future, and deferred stores/flushes feed
//! nothing back into its calendar at all.

use crate::coordinator::runner::thread_count;
use crate::sim::{interleave, run_conservative, Time};

use super::pool::{build_pool, harvest_pool, validate, PoolError, PoolResult, Tenant};

/// Run `tenants` against one shared pool to completion on `shards`
/// shards and up to `threads` worker threads (`None` = the
/// `CXL_GPU_THREADS` override, else every available core — the same
/// rule as the sweep runner). Results are bit-identical to
/// [`run_pool`]`(tenants)` regardless of both knobs.
///
/// Single-tenant pools and `shards == 1` take the serial coordinator
/// directly: there is nothing to overlap, and the single-tenant switch
/// is in passthrough mode (no hop charged), which would void the
/// lookahead bound.
///
/// [`run_pool`]: super::pool::run_pool
pub fn run_pool_sharded(
    tenants: &[Tenant],
    shards: usize,
    threads: Option<usize>,
) -> Result<PoolResult, PoolError> {
    if shards == 0 {
        return Err(PoolError::BadShardCount { shards });
    }
    let base = validate(tenants)?;
    let lookahead: Time = 2 * base.fabric.hop_lat;
    for t in tenants {
        if t.cfg.timeline {
            // Timeline capture samples shared switch occupancy inside a
            // tenant's (parallel-phase) load path — unreproducible here.
            return Err(PoolError::TimelineUnsupported { name: t.cfg.name.clone() });
        }
    }
    if tenants.len() > 1 && lookahead == 0 {
        return Err(PoolError::NoLookahead { name: base.name.clone() });
    }

    let (mut systems, link) = build_pool(tenants)?;
    if shards == 1 || systems.len() == 1 {
        interleave(&mut systems);
        return Ok(harvest_pool(systems, tenants, &link));
    }

    for s in &mut systems {
        s.set_defer_fabric(true);
    }
    let (mut systems, _steps) =
        run_conservative(systems, shards, threads.unwrap_or_else(thread_count), lookahead);
    for s in &mut systems {
        s.set_defer_fabric(false);
    }
    Ok(harvest_pool(systems, tenants, &link))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SystemConfig;
    use crate::fabric::run_pool;
    use crate::media::MediaKind;
    use crate::workloads::table1b::spec;

    fn tenant(wl: &str, warps: usize, mlp: usize, seed: u64) -> Tenant {
        let mut cfg = SystemConfig::named("cxl-pool-qos", MediaKind::Ddr5);
        cfg.total_ops = 5_000;
        cfg.warps = warps;
        cfg.mlp = mlp;
        cfg.seed = seed;
        cfg.footprint = 4 << 20;
        cfg.local_bytes = 64 << 10;
        Tenant { workload: spec(wl), cfg }
    }

    fn mixed_pool() -> Vec<Tenant> {
        vec![
            tenant("bfs", 8, 4, 1),
            tenant("vadd", 16, 2, 2),
            tenant("sort", 8, 8, 3),
        ]
    }

    /// Full PoolResult equality: per-tenant fingerprints, pool sums and
    /// the merged event count.
    fn assert_same(a: &PoolResult, b: &PoolResult, what: &str) {
        assert_eq!(a.events, b.events, "{what}: merged event count diverged");
        assert_eq!(
            format!("{:?}", a.pool),
            format!("{:?}", b.pool),
            "{what}: pool sums diverged"
        );
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                ta.metrics.fingerprint(),
                tb.metrics.fingerprint(),
                "{what}: tenant {} diverged",
                ta.workload
            );
        }
    }

    #[test]
    fn sharded_run_is_bit_identical_to_serial_for_every_shape() {
        let serial = run_pool(&mixed_pool()).unwrap();
        assert!(
            serial.tenants.iter().all(|t| t.metrics.expander_loads > 0),
            "pool must actually exercise the fabric for the identity to mean anything"
        );
        // Shard counts beyond the tenant count clamp; 2 does not divide
        // 3, so one shard is wider than the other.
        for shards in [1, 2, 3, 8] {
            for threads in [1, 2, 4] {
                let sharded =
                    run_pool_sharded(&mixed_pool(), shards, Some(threads)).unwrap();
                assert_same(&serial, &sharded, &format!("shards={shards} threads={threads}"));
            }
        }
    }

    #[test]
    fn single_tenant_pool_takes_the_passthrough_fallback() {
        let one = || vec![tenant("vadd", 8, 4, 7)];
        let serial = run_pool(&one()).unwrap();
        let sharded = run_pool_sharded(&one(), 4, Some(4)).unwrap();
        assert_same(&serial, &sharded, "single tenant");
    }

    #[test]
    fn zero_shards_is_rejected() {
        let err = run_pool_sharded(&mixed_pool(), 0, None).unwrap_err();
        assert_eq!(err, PoolError::BadShardCount { shards: 0 });
    }

    #[test]
    fn timeline_capture_is_rejected() {
        let mut tenants = mixed_pool();
        tenants[1].cfg.timeline = true;
        let err = run_pool_sharded(&tenants, 2, None).unwrap_err();
        assert!(matches!(err, PoolError::TimelineUnsupported { .. }), "{err:?}");
    }

    #[test]
    fn zero_hop_multi_tenant_pool_has_no_lookahead() {
        let mut tenants = mixed_pool();
        for t in &mut tenants {
            t.cfg.fabric.hop_lat = 0;
        }
        let err = run_pool_sharded(&tenants, 2, None).unwrap_err();
        assert!(matches!(err, PoolError::NoLookahead { .. }), "{err:?}");
        // ...but a single zero-hop tenant is fine: it takes the serial
        // passthrough fallback and never needs the window.
        let solo = vec![{
            let mut t = tenant("vadd", 8, 4, 9);
            t.cfg.fabric.hop_lat = 0;
            t
        }];
        assert!(run_pool_sharded(&solo, 4, None).is_ok());
    }

    #[test]
    fn validation_errors_match_the_serial_coordinator() {
        let err = run_pool_sharded(&[], 2, None).unwrap_err();
        assert_eq!(err, PoolError::EmptyPool);
        let mut tenants = mixed_pool();
        tenants[2].cfg.ports = 2;
        let err = run_pool_sharded(&tenants, 2, None).unwrap_err();
        assert!(matches!(err, PoolError::TopologyMismatch { .. }), "{err:?}");
    }
}
