//! The virtual CXL switch: N upstream ports (one per tenant GPU) fanned
//! into M shared downstream endpoints.
//!
//! Request path (non-passthrough): per-tenant **token bucket** (QoS
//! policing, [`TokenBucket`]) → per-upstream **ingress queue** (busy-until
//! slots, high-water mark tracked) → **WRR arbitration** for the
//! downstream endpoint (each tenant holds at most its weighted share of
//! the endpoint's memory-queue slots concurrently) → switch **hop
//! latency** → the shared [`RootPort`] (which charges its own queue,
//! controller legs and media exactly as in the direct topology) → hop
//! back.
//!
//! **DevLoad backpressure propagates to the originating tenant only**:
//! the endpoint's DevLoad observed when a tenant's request arrives is
//! recorded against that tenant and — when QoS is on — fed to *its*
//! token bucket, re-classified against the tenant's own share occupancy
//! so one tenant's congestion never throttles another.
//!
//! **Passthrough invariant**: a switch with exactly one upstream port
//! and QoS off is bit-transparent — no hop, no ingress bookkeeping, no
//! arbitration. A single-tenant `cxl-pool` therefore reproduces the
//! direct `cxl` configuration bit-identically (guarded in
//! `tests/determinism.rs`).

use crate::cxl::DevLoad;
use crate::media::MediaKind;
use crate::obs::{Stage, StageTrace};
use crate::rootcomplex::rootport::{EpBackend, LoadOutcome, RootPort, StoreOutcome};
use crate::rootcomplex::spec_read::MEM_QUEUE_CAP;
use crate::sim::Time;
use crate::util::prng::Pcg32;

use super::FabricSpec;

/// Picoseconds per second (token-bucket fixed-point scale: one token
/// unit is one byte·picosecond-per-second, so refill per picosecond is
/// exactly `rate` in bytes/s).
const PS_PER_S: u128 = 1_000_000_000_000;

/// Completions per AIMD adjustment window.
const AIMD_WINDOW: u32 = 32;

/// Ingress token bucket with AIMD rate adaptation.
///
/// The rate starts at `max_rate` (unthrottled) and only walks down when
/// the tenant's own completions show *real* congestion — its WRR share
/// saturated, the endpoint overloaded, and latency inflated past 1.5x
/// the unloaded reference. That gate keeps the bucket a shaper at the
/// congestion knee: sustained throughput is preserved (capacity-limited
/// tenants keep the endpoint busy; demand-limited tenants are never
/// throttled) while queue buildup — what the victim's tail sees — is
/// bounded. Integer fixed-point throughout, so pacing is deterministic.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    /// Current rate, bytes per second (AIMD-adapted).
    rate: u64,
    min_rate: u64,
    max_rate: u64,
    /// Bucket depth in bytes.
    burst: u64,
    /// Tokens, in byte·ps/s units (`bytes * PS_PER_S`).
    tokens: u128,
    last: Time,
    window: u32,
    window_congested: bool,
}

impl TokenBucket {
    pub fn new(rate: u64, min_rate: u64, max_rate: u64, burst: u64) -> TokenBucket {
        assert!(rate > 0 && min_rate > 0 && max_rate >= min_rate, "bad token-bucket rates");
        TokenBucket {
            rate: rate.clamp(min_rate, max_rate),
            min_rate,
            max_rate,
            burst: burst.max(64),
            tokens: burst.max(64) as u128 * PS_PER_S,
            last: 0,
            window: 0,
            window_congested: false,
        }
    }

    /// Earliest time a `len`-byte request may enter the switch, given
    /// arrival at `now`. Consumes the tokens (waiting accrues exactly
    /// the deficit, then spends it).
    pub fn ready_at(&mut self, now: Time, len: u64) -> Time {
        let now = now.max(self.last);
        let dt = (now - self.last) as u128;
        self.tokens =
            (self.tokens + dt * self.rate as u128).min(self.burst as u128 * PS_PER_S);
        self.last = now;
        let need = len as u128 * PS_PER_S;
        if self.tokens >= need {
            self.tokens -= need;
            now
        } else {
            let deficit = need - self.tokens;
            self.tokens = 0;
            let wait = (deficit + self.rate as u128 - 1) / self.rate as u128;
            self.last = now + wait as Time;
            self.last
        }
    }

    /// AIMD feedback from one of this tenant's demand-load completions.
    pub fn on_load_feedback(&mut self, congested: bool) {
        self.window_congested |= congested;
        self.window += 1;
        if self.window >= AIMD_WINDOW {
            self.rate = if self.window_congested {
                // Multiplicative decrease (x0.8): gentle, so the
                // equilibrium hovers just below the congestion knee.
                (self.rate - self.rate / 5).max(self.min_rate)
            } else {
                // Fast recovery (x1.25) back toward unthrottled.
                (self.rate + self.rate / 4).min(self.max_rate)
            };
            self.window = 0;
            self.window_congested = false;
        }
    }

    /// Current rate in bytes/s.
    pub fn rate(&self) -> u64 {
        self.rate
    }
}

/// Per-tenant switch counters, harvested into that tenant's
/// `RunMetrics` (per-tenant breakdowns).
#[derive(Debug, Clone, Default)]
pub struct TenantFabricStats {
    /// Demand loads forwarded for this tenant.
    pub loads: u64,
    /// Stores forwarded for this tenant.
    pub stores: u64,
    /// Ingress-queue high-water mark (occupancy including the admitted
    /// request; 0 in passthrough mode, which tracks nothing).
    pub ingress_hwm: u64,
    /// Requests that waited for an ingress slot.
    pub ingress_waits: u64,
    /// Requests that waited for a WRR share slot on their endpoint.
    pub wrr_waits: u64,
    /// Requests delayed by the QoS token bucket.
    pub throttle_waits: u64,
    /// Total picoseconds of token-bucket delay.
    pub throttle_ps: u64,
    /// Endpoint DevLoad observations of Moderate or worse, returned to
    /// this tenant (the originating-tenant-only backpressure channel).
    pub backpressure: u64,
    /// The Severe subset of `backpressure`.
    pub backpressure_severe: u64,
}

/// Pool-level sums over the shared downstream ports.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolSums {
    pub loads: u64,
    pub stores: u64,
    pub sr_issued: u64,
    pub ds_intercepts: u64,
    pub gc_episodes: u64,
    /// Max memory-queue high-water mark across the pooled endpoints.
    pub queue_hwm: u64,
    /// Expander device-cache sums across the pooled endpoints
    /// (DESIGN.md §14; zero when no endpoint carries a cache).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_writebacks: u64,
    pub cache_bypasses: u64,
    /// Max writeback-drain-queue high-water mark across the endpoints.
    pub cache_wb_hwm: u64,
    /// RAS sums across the pooled endpoints (DESIGN.md §15; zero when
    /// no endpoint carries a fault schedule).
    pub ras_retries: u64,
    pub ras_replays: u64,
    pub ras_poisons: u64,
    pub ras_timeouts: u64,
    pub ras_failovers: u64,
    pub ras_dirty_rescued: u64,
}

/// One tenant's side of the switch.
#[derive(Debug)]
struct UpstreamPort {
    /// Ingress-queue slots (busy-until), held from admission to response.
    slots: Vec<Time>,
    /// Per-downstream in-flight slots bounded to this tenant's WRR share
    /// of the endpoint's memory queue: weighted round-robin arbitration
    /// in deficit-share form — under contention no tenant holds more
    /// than `weight/total` of an endpoint's slots.
    share: Vec<Vec<Time>>,
    qos: TokenBucket,
    stats: TenantFabricStats,
}

/// The virtual CXL switch shared by every tenant of a pool.
#[derive(Debug)]
pub struct CxlSwitch {
    spec: FabricSpec,
    /// True iff one upstream port and QoS off: the switch is
    /// bit-transparent (see module docs).
    passthrough: bool,
    /// The shared pooled endpoints (same `RootPort` machinery as the
    /// direct topology: memory queue, controller legs, SR/DS, media).
    pub downstream: Vec<RootPort>,
    up: Vec<UpstreamPort>,
    /// Per-endpoint unloaded 64 B read latency (AIMD congestion
    /// baseline).
    unloaded: Vec<Time>,
    /// Last pooled DS flush sweep (cadence dedup across tenants' ticks;
    /// 0 = never flushed).
    last_flush: Time,
    /// Per-downstream latch: WRR shares already demoted after the
    /// endpoint degraded (DESIGN.md §15).
    demoted: Vec<bool>,
}

/// Minimum spacing between pooled DS flush sweeps — the same 10 µs
/// cadence a single `System` schedules its own `FlushTick` at.
const FLUSH_GAP: Time = 10 * crate::sim::US;

/// Acquire the earliest-free busy-until slot at or after `now`.
fn acquire(slots: &mut [Time], now: Time) -> (usize, Time) {
    let (idx, &free) = slots
        .iter()
        .enumerate()
        .min_by_key(|(_, &t)| t)
        .expect("switch slot vectors are non-empty by construction");
    (idx, free.max(now))
}

impl CxlSwitch {
    /// Build a switch over `downstream` pooled endpoints with one
    /// upstream port per entry of `weights` (the tenants' WRR weights).
    pub fn new(downstream: Vec<RootPort>, spec: FabricSpec, weights: &[u32]) -> CxlSwitch {
        assert!(!downstream.is_empty(), "fabric needs at least one downstream endpoint");
        assert!(!weights.is_empty(), "fabric needs at least one upstream port");
        let total: u64 = weights.iter().map(|&w| w.max(1) as u64).sum();
        let unloaded: Vec<Time> = downstream.iter().map(|p| p.unloaded_read_ps()).collect();
        // Weighted shares of the endpoint queue, floored at one slot so
        // every tenant can always make progress. The floor can push the
        // sum past the queue capacity (extreme weight skew, or more
        // tenants than slots), so trim the largest shares back until the
        // sum fits — deterministically, largest share first, ties to the
        // lowest index. Only when every share is already 1 (more tenants
        // than slots) does the sum stay oversubscribed; the endpoint's
        // own memory queue then provides the final backpressure.
        let mut shares: Vec<usize> = weights
            .iter()
            .map(|&w| ((MEM_QUEUE_CAP as u64 * w.max(1) as u64) / total).max(1) as usize)
            .collect();
        while shares.iter().sum::<usize>() > MEM_QUEUE_CAP {
            let (imax, &smax) = shares
                .iter()
                .enumerate()
                .max_by(|(ia, a), (ib, b)| a.cmp(b).then(ib.cmp(ia)))
                .expect("weights non-empty");
            if smax <= 1 {
                break;
            }
            shares[imax] -= 1;
        }
        let up = shares
            .iter()
            .map(|&share| {
                UpstreamPort {
                    slots: vec![0; spec.ingress_cap.max(1)],
                    share: (0..downstream.len()).map(|_| vec![0; share]).collect(),
                    qos: TokenBucket::new(
                        spec.max_rate,
                        spec.min_rate,
                        spec.max_rate,
                        spec.burst_bytes,
                    ),
                    stats: TenantFabricStats::default(),
                }
            })
            .collect();
        let demoted = vec![false; downstream.len()];
        CxlSwitch {
            passthrough: weights.len() == 1 && !spec.qos,
            spec,
            downstream,
            up,
            unloaded,
            last_flush: 0,
            demoted,
        }
    }

    /// Graceful degradation (DESIGN.md §15): the first time a pooled
    /// endpoint is observed degraded, demote every tenant's WRR share
    /// on it to a single slot — in-flight depth to the failing device
    /// is capped so pooled traffic keeps draining through the healthy
    /// endpoints instead of stacking up behind the degraded one.
    /// Latched once per endpoint; runs *before* admission so no
    /// already-acquired share slot index is invalidated mid-request.
    fn demote_if_degraded(&mut self, down: usize) {
        if self.demoted[down] || !self.downstream[down].is_degraded() {
            return;
        }
        self.demoted[down] = true;
        for u in &mut self.up {
            u.share[down].truncate(1);
        }
        if let Some(r) = &mut self.downstream[down].ras {
            r.stats.failovers += 1;
        }
    }

    /// Number of upstream (tenant) ports.
    pub fn upstreams(&self) -> usize {
        self.up.len()
    }

    /// Media class of each downstream endpoint, in port order (the
    /// fabric enumeration's config-space walk input).
    pub fn downstream_kinds(&self) -> Vec<MediaKind> {
        self.downstream.iter().map(|p| p.backend.kind()).collect()
    }

    /// One tenant's switch counters.
    pub fn upstream_stats(&self, up: usize) -> &TenantFabricStats {
        &self.up[up].stats
    }

    /// Pool-level sums over the shared endpoints.
    pub fn pool_sums(&self) -> PoolSums {
        let mut s = PoolSums::default();
        for p in &self.downstream {
            s.loads += p.stats.loads;
            s.stores += p.stats.stores;
            s.sr_issued += p.sr.stats.sr_issued;
            s.ds_intercepts += p.ds.stats.read_intercepts;
            s.queue_hwm = s.queue_hwm.max(p.stats.queue_hwm);
            if let EpBackend::Ssd(m) = &p.backend {
                s.gc_episodes += m.stats.gc_episodes;
            }
            if let Some(c) = &p.cache {
                s.cache_hits += c.stats.hits;
                s.cache_misses += c.stats.misses;
                s.cache_writebacks += c.stats.writebacks;
                s.cache_bypasses += c.stats.bypasses;
                s.cache_wb_hwm = s.cache_wb_hwm.max(c.stats.wb_hwm);
            }
            if let Some(r) = &p.ras {
                s.ras_retries += r.stats.retries;
                s.ras_replays += r.stats.replays;
                s.ras_poisons += r.stats.poisons;
                s.ras_timeouts += r.stats.timeouts;
                s.ras_failovers += r.stats.failovers;
                s.ras_dirty_rescued += r.stats.dirty_rescued_bytes;
            }
        }
        s
    }

    /// Ingress-queue occupancy of one upstream port at `at` (downstream
    /// port 0's memory queue in passthrough mode, where the ingress
    /// tracks nothing).
    pub fn ingress_occupancy(&self, up: usize, at: Time) -> usize {
        if self.passthrough {
            return self.downstream.first().map_or(0, |p| p.occupancy(at));
        }
        self.up[up].slots.iter().filter(|&&t| t > at).count()
    }

    /// Total DS-buffered bytes across the pooled endpoints.
    pub fn ds_backlog(&self) -> u64 {
        self.downstream.iter().map(|p| p.ds.buffered_bytes()).sum()
    }

    /// One tenant's QoS token-bucket refill rate, bytes/s (0 when the
    /// pool runs without QoS shaping) — the telemetry `qos_rate` gauge,
    /// which moves as AIMD feedback throttles or recovers the tenant.
    pub fn qos_rate(&self, up: usize) -> u64 {
        if self.spec.qos {
            self.up[up].qos.rate()
        } else {
            0
        }
    }

    /// Downstream endpoints currently latched degraded (RAS §15) — the
    /// telemetry `ras_degraded` gauge in pooled runs.
    pub fn degraded_endpoints(&self) -> u64 {
        self.downstream.iter().filter(|p| p.is_degraded()).count() as u64
    }

    /// Worst DevLoad class across the pooled endpoints at `at`
    /// (0=Light .. 3=Severe).
    pub fn worst_devload(&self, at: Time) -> u8 {
        self.downstream.iter().map(|p| p.devload(at).encode()).max().unwrap_or(0)
    }

    /// Background DS flush across the pooled endpoints. *Every* tenant's
    /// `FlushTick` forwards here — gating on one fixed tenant would
    /// stall the pool's flush once that tenant retires — and the switch
    /// dedupes to one sweep per [`FLUSH_GAP`] so co-tenants don't
    /// multiply the cadence. Deterministic: in the pool's global event
    /// order the first tick at or past the gap wins.
    pub fn flush_tick(&mut self, now: Time, rng: &mut Pcg32) {
        if now < self.last_flush + FLUSH_GAP && self.last_flush != 0 {
            return;
        }
        self.last_flush = now;
        for p in &mut self.downstream {
            p.flush_step(now, 8, rng);
        }
    }

    /// Admission pipeline shared by loads and stores: token bucket →
    /// ingress slot → WRR share slot. Returns (ingress slot, share
    /// slot, start time at the switch egress) — the caller charges the
    /// hop, runs the endpoint, then marks both slots busy until the
    /// response time.
    fn admit(
        up: &mut UpstreamPort,
        qos: bool,
        down: usize,
        now: Time,
        len: u64,
    ) -> (usize, usize, Time) {
        let mut start = now;
        if qos {
            let ready = up.qos.ready_at(start, len);
            if ready > start {
                up.stats.throttle_waits += 1;
                up.stats.throttle_ps += ready - start;
                start = ready;
            }
        }
        let (islot, istart) = acquire(&mut up.slots, start);
        if istart > start {
            up.stats.ingress_waits += 1;
        }
        start = istart;
        let occ = up.slots.iter().filter(|&&t| t > start).count() as u64 + 1;
        up.stats.ingress_hwm = up.stats.ingress_hwm.max(occ);
        let (wslot, wstart) = acquire(&mut up.share[down], start);
        if wstart > start {
            up.stats.wrr_waits += 1;
        }
        (islot, wslot, wstart)
    }

    /// Route a demand load from upstream `up` to downstream endpoint
    /// `down` at device address `addr`.
    pub fn load(&mut self, up: usize, down: usize, now: Time, addr: u64, len: u64) -> LoadOutcome {
        self.load_traced(up, down, now, addr, len, None)
    }

    /// [`load`](CxlSwitch::load) with an optional span ledger: the
    /// admission wait (token bucket + ingress + WRR) is attributed to
    /// `SwitchArb`, both hops to `SwitchHop`, and the ledger is threaded
    /// on to the endpoint. Passthrough mode forwards the ledger
    /// untouched — bit-transparency includes attributing nothing.
    pub fn load_traced(
        &mut self,
        up: usize,
        down: usize,
        now: Time,
        addr: u64,
        len: u64,
        mut trace: Option<&mut StageTrace>,
    ) -> LoadOutcome {
        if self.passthrough {
            return self.downstream[down].load_traced(now, addr, len, trace);
        }
        self.demote_if_degraded(down);
        let CxlSwitch { spec, downstream, up: ups, unloaded, .. } = self;
        let u = &mut ups[up];
        u.stats.loads += 1;
        let (islot, wslot, start) = Self::admit(u, spec.qos, down, now, len);
        let at_port = start + spec.hop_lat;
        if let Some(t) = trace.as_deref_mut() {
            t.add(Stage::SwitchArb, start - now);
            t.add(Stage::SwitchHop, 2 * spec.hop_lat);
        }
        // The endpoint's DevLoad as this tenant's request arrives: the
        // backpressure channel, attributed to the originating tenant
        // only.
        let dl = downstream[down].devload(at_port);
        if dl.overloaded() {
            u.stats.backpressure += 1;
            if dl == DevLoad::Severe {
                u.stats.backpressure_severe += 1;
            }
        }
        let out = downstream[down].load_traced(at_port, addr, len, trace);
        let done = out.done + spec.hop_lat;
        u.slots[islot] = done;
        u.share[down][wslot] = done;
        if spec.qos {
            // Congestion for AIMD = this tenant's own share saturated
            // (it is the cause) AND the endpoint overloaded AND the
            // observed latency inflated past 1.5x the unloaded baseline
            // (it is real queueing, not just occupancy). The own-share
            // gate is what re-classifies the endpoint's DevLoad per
            // tenant: a light tenant sharing a congested endpoint is
            // never throttled for someone else's queue. The 1.5x knee
            // keeps the equilibrium tight — co-tenants see at most
            // ~half an unloaded service time of queue buildup.
            let share = &u.share[down];
            let own_busy = share.iter().filter(|&&t| t > at_port).count();
            let own_dl = DevLoad::classify(own_busy, share.len(), false);
            let lat = out.done.saturating_sub(at_port);
            let infl = unloaded[down] + unloaded[down] / 2;
            let congested = own_dl == DevLoad::Severe && dl.overloaded() && lat > infl;
            u.qos.on_load_feedback(congested);
        }
        LoadOutcome { done, path: out.path }
    }

    /// Route a store (writeback) from upstream `up` to endpoint `down`.
    pub fn store(
        &mut self,
        up: usize,
        down: usize,
        now: Time,
        addr: u64,
        len: u64,
        rng: &mut Pcg32,
    ) -> StoreOutcome {
        self.store_traced(up, down, now, addr, len, rng, None)
    }

    /// [`store`](CxlSwitch::store) with an optional span ledger (same
    /// attribution as [`load_traced`](CxlSwitch::load_traced)).
    pub fn store_traced(
        &mut self,
        up: usize,
        down: usize,
        now: Time,
        addr: u64,
        len: u64,
        rng: &mut Pcg32,
        mut trace: Option<&mut StageTrace>,
    ) -> StoreOutcome {
        if self.passthrough {
            return self.downstream[down].store_traced(now, addr, len, rng, trace);
        }
        self.demote_if_degraded(down);
        let CxlSwitch { spec, downstream, up: ups, .. } = self;
        let u = &mut ups[up];
        u.stats.stores += 1;
        let (islot, wslot, start) = Self::admit(u, spec.qos, down, now, len);
        let at_port = start + spec.hop_lat;
        if let Some(t) = trace.as_deref_mut() {
            t.add(Stage::SwitchArb, start - now);
            t.add(Stage::SwitchHop, 2 * spec.hop_lat);
        }
        let dl = downstream[down].devload(at_port);
        if dl.overloaded() {
            u.stats.backpressure += 1;
            if dl == DevLoad::Severe {
                u.stats.backpressure_severe += 1;
            }
        }
        let out = downstream[down].store_traced(at_port, addr, len, rng, trace);
        let ack = out.ack + spec.hop_lat;
        u.slots[islot] = ack;
        u.share[down][wslot] = ack;
        StoreOutcome { ack, buffered: out.buffered }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::ControllerKind;
    use crate::media::{DramModel, DramTimings, SsdModel, SsdParams};
    use crate::rootcomplex::SrPolicy;
    use crate::sim::{NS, US};

    fn dram_port(id: usize) -> RootPort {
        RootPort::new(
            id,
            ControllerKind::Panmnesia,
            EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
            SrPolicy::Off,
            false,
            0,
        )
    }

    fn ssd_port(id: usize) -> RootPort {
        RootPort::new(
            id,
            ControllerKind::Panmnesia,
            EpBackend::Ssd(SsdModel::new(SsdParams::znand())),
            SrPolicy::Off,
            false,
            0,
        )
    }

    fn spec(qos: bool) -> FabricSpec {
        FabricSpec { enabled: true, qos, ..FabricSpec::default() }
    }

    #[test]
    fn single_upstream_no_qos_is_passthrough() {
        let mut sw = CxlSwitch::new(vec![dram_port(0)], spec(false), &[1]);
        let mut direct = dram_port(0);
        let a = sw.load(0, 0, 0, 0x1000, 64);
        let b = direct.load(0, 0x1000, 64);
        assert_eq!(a.done, b.done, "passthrough must not add latency");
        assert_eq!(sw.upstream_stats(0).loads, 0, "passthrough tracks nothing");
        assert_eq!(sw.upstream_stats(0).ingress_hwm, 0);
    }

    #[test]
    fn multi_upstream_charges_the_hop_both_ways() {
        let mut sw = CxlSwitch::new(vec![dram_port(0)], spec(false), &[1, 1]);
        let mut direct = dram_port(0);
        let a = sw.load(0, 0, 0, 0x1000, 64);
        let b = direct.load(0, 0x1000, 64);
        assert_eq!(a.done, b.done + 2 * FabricSpec::default().hop_lat);
        assert_eq!(sw.upstream_stats(0).loads, 1);
        assert!(sw.upstream_stats(0).ingress_hwm >= 1);
        assert_eq!(sw.upstream_stats(1).loads, 0, "tenant 1 never issued");
    }

    #[test]
    fn wrr_share_caps_one_tenants_inflight() {
        // Two equal-weight tenants: each may hold at most half the
        // endpoint's 32 slots. The 17th concurrent request from one
        // tenant must wait even though the endpoint has free slots.
        let mut sw = CxlSwitch::new(vec![ssd_port(0)], spec(false), &[1, 1]);
        let share = MEM_QUEUE_CAP / 2;
        for i in 0..share as u64 {
            sw.load(0, 0, 0, i * 4096 * 64, 64);
        }
        assert_eq!(sw.upstream_stats(0).wrr_waits, 0, "within the share: no wait");
        sw.load(0, 0, 0, 0x400_0000, 64);
        assert!(
            sw.upstream_stats(0).wrr_waits >= 1,
            "request past the share must queue behind own in-flight"
        );
        // The other tenant still gets served promptly off its own share.
        let victim = sw.load(1, 0, 0, 0x10_0000, 64);
        assert!(
            victim.done < 100 * US,
            "victim must not wait behind the hog's share: {}",
            victim.done
        );
        assert_eq!(sw.upstream_stats(1).wrr_waits, 0);
    }

    #[test]
    fn token_bucket_paces_and_adapts() {
        let mut tb = TokenBucket::new(1 << 30, 1 << 26, 1 << 30, 128);
        // Burst admits immediately, then pacing kicks in.
        assert_eq!(tb.ready_at(0, 64), 0);
        assert_eq!(tb.ready_at(0, 64), 0);
        let t = tb.ready_at(0, 64);
        assert!(t > 0, "empty bucket must delay");
        // 64 bytes at 2^30 B/s is ~59.6 ns.
        assert!((50 * NS..80 * NS).contains(&t), "pace delay {t} ps");
        // AIMD: a congested window lowers the rate, clean windows raise it.
        let r0 = tb.rate();
        for _ in 0..AIMD_WINDOW {
            tb.on_load_feedback(true);
        }
        assert!(tb.rate() < r0, "congested window must cut the rate");
        let r1 = tb.rate();
        for _ in 0..AIMD_WINDOW * 8 {
            tb.on_load_feedback(false);
        }
        assert!(tb.rate() > r1, "clean windows must recover the rate");
        assert!(tb.rate() <= 1 << 30, "rate stays clamped to max");
    }

    #[test]
    fn qos_throttles_only_the_congested_tenant() {
        // Hog weight 3: its WRR share (24 of 32 slots) is deep enough
        // that its own in-flight pushes the endpoint solidly past the
        // Moderate occupancy threshold.
        let mut sw = CxlSwitch::new(vec![ssd_port(0)], spec(true), &[3, 1]);
        // Hog: hammer far past the share and the burst from time 0.
        for i in 0..400u64 {
            sw.load(0, 0, 0, i * 4096 * 64, 64);
        }
        // Victim issues sporadically at quiet times.
        for i in 0..8u64 {
            sw.load(1, 0, i * 50 * US, 0x800_0000 + i * 4096 * 64, 64);
        }
        let hog = sw.upstream_stats(0);
        let victim = sw.upstream_stats(1);
        assert!(hog.backpressure > 0, "hog must observe endpoint backpressure");
        assert_eq!(
            victim.throttle_waits, 0,
            "a light tenant must never be token-throttled"
        );
        assert!(victim.ingress_hwm <= 2, "victim ingress stays shallow");
    }

    #[test]
    fn wrr_shares_fit_the_endpoint_queue_under_weight_skew() {
        // Extreme skew: the max(1) floor would oversubscribe (31+1+1+1 =
        // 34 > 32) without the largest-first trim.
        let sw = CxlSwitch::new(vec![dram_port(0)], spec(false), &[1000, 1, 1, 1]);
        let total: usize = (0..4).map(|u| sw.up[u].share[0].len()).sum();
        assert!(total <= MEM_QUEUE_CAP, "shares oversubscribe: {total}");
        assert!(sw.up.iter().all(|u| !u.share[0].is_empty()), "every tenant keeps a slot");
        assert!(sw.up[0].share[0].len() > sw.up[1].share[0].len(), "weight still dominates");
    }

    #[test]
    fn flush_dedupes_to_one_sweep_per_cadence_from_any_tenant() {
        let mut rng = Pcg32::new(8, 8);
        let mut sw = CxlSwitch::new(vec![ssd_port(0)], spec(false), &[1, 1]);
        sw.flush_tick(10 * US, &mut rng);
        let first = sw.last_flush;
        assert_eq!(first, 10 * US);
        // A co-tenant's tick inside the gap is a no-op...
        sw.flush_tick(10 * US + 5, &mut rng);
        assert_eq!(sw.last_flush, first, "in-gap tick must not re-flush");
        // ...and the next tick at the cadence runs, whoever sends it.
        sw.flush_tick(20 * US, &mut rng);
        assert_eq!(sw.last_flush, 20 * US);
    }

    #[test]
    fn degraded_endpoint_gets_its_wrr_share_demoted() {
        use crate::ras::FaultSpec;
        let ras = FaultSpec {
            enabled: true,
            degrade_at: 1,
            degrade_port: 0,
            degrade_penalty: 5 * US,
            ..FaultSpec::default()
        };
        let ports = vec![ssd_port(0).with_ras(ras, 42), ssd_port(1).with_ras(ras, 42)];
        let mut sw = CxlSwitch::new(ports, spec(false), &[1, 1]);
        let full = sw.up[0].share[0].len();
        assert!(full > 1, "premise: shares start multi-slot");
        // The first access past the deadline latches the degradation
        // inside the port; the switch observes it on the next admission.
        sw.load(0, 0, 10, 0x1000, 64);
        assert!(sw.downstream[0].is_degraded());
        assert_eq!(sw.up[0].share[0].len(), full, "demotion waits for the next admission");
        sw.load(0, 0, 20 * US, 0x2000, 64);
        assert_eq!(sw.up[0].share[0].len(), 1, "tenant 0 share demoted");
        assert_eq!(sw.up[1].share[0].len(), 1, "tenant 1 share demoted");
        assert_eq!(sw.up[0].share[1].len(), full, "healthy endpoint untouched");
        let sums = sw.pool_sums();
        assert!(
            sums.ras_failovers >= 2,
            "degrade latch + switch demotion both count: {}",
            sums.ras_failovers
        );
        // Latched: further traffic doesn't re-demote or re-count.
        sw.load(0, 0, 40 * US, 0x3000, 64);
        assert_eq!(sw.pool_sums().ras_failovers, sums.ras_failovers);
    }

    #[test]
    fn pool_sums_aggregate_downstream_ports() {
        let mut sw = CxlSwitch::new(vec![dram_port(0), dram_port(1)], spec(false), &[1, 1]);
        sw.load(0, 0, 0, 0x0, 64);
        sw.load(1, 1, 0, 0x0, 64);
        let mut rng = Pcg32::new(1, 1);
        sw.store(0, 1, 0, 0x40, 64, &mut rng);
        let sums = sw.pool_sums();
        assert_eq!(sums.loads, 2);
        assert_eq!(sums.stores, 1);
        assert!(sums.queue_hwm >= 1);
    }
}
