//! Pooled CXL fabric: a virtual switch between N tenant GPUs and M
//! shared memory expanders, plus the multi-tenant pool coordinator.
//!
//! The paper's topology stops at one GPU with direct-attached root
//! ports; this subsystem models the next system tier the CXL roadmap
//! (and the LMB line of work) describes — *switch-attached pooling*,
//! where several GPUs reach one set of DRAM/SSD expanders through a
//! shared virtual CXL switch:
//!
//! * [`switch`] — the switch itself: per-upstream ingress queues,
//!   weighted-round-robin arbitration of downstream memory-queue slots,
//!   switch-hop latency, originating-tenant-only DevLoad backpressure,
//!   and the per-tenant QoS token bucket ([`switch::TokenBucket`]).
//! * [`pool`] — the multi-tenant coordinator: N independent GPU
//!   [`System`](crate::coordinator::system::System)s stepped against the
//!   shared pool in one deterministic global event order
//!   ([`crate::sim::interleave()`]).
//! * [`shard`] — the parallel twin: tenants partitioned across worker
//!   threads under the conservative-lookahead engine
//!   ([`crate::sim::pdes`]), bit-identical to [`pool`] by construction
//!   (DESIGN.md §17).
//!
//! Tenants address disjoint device-address slices of the pooled
//! endpoints (per-tenant `dpa_base` in the HDM walk), so pooling is a
//! *capacity partition with shared bandwidth* — contention is modeled,
//! aliasing is not. Design notes: DESIGN.md §13.

pub mod pool;
pub mod shard;
pub mod switch;

pub use pool::{run_pool, PoolError, PoolResult, Tenant, TenantResult};
pub use shard::run_pool_sharded;
pub use switch::{CxlSwitch, PoolSums, TenantFabricStats, TokenBucket};

use std::sync::{Arc, Mutex};

use crate::sim::{Time, NS};

/// Shared handle to the pool's switch. `Arc<Mutex<..>>` rather than
/// `Rc<RefCell<..>>` so a fabric-backed `RootComplex` stays `Send`
/// (examples serve one over a socket); within a pool run the lock is
/// uncontended — the coordinator steps tenants one event at a time.
pub type FabricLink = Arc<Mutex<CxlSwitch>>;

/// Fabric knobs carried by `SystemConfig` (one copy per tenant; the
/// pool builds the switch from the first tenant's spec and each
/// tenant's `weight`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricSpec {
    /// Route this configuration's expander through a fabric switch
    /// instead of direct-attached root ports.
    pub enabled: bool,
    /// Enable the per-tenant QoS token bucket on switch ingress.
    pub qos: bool,
    /// Switch traversal cost per direction (charged only when the
    /// switch is not in passthrough mode).
    pub hop_lat: Time,
    /// Ingress-queue slots per upstream port.
    pub ingress_cap: usize,
    /// This tenant's WRR weight (share of each endpoint's memory-queue
    /// slots under contention).
    pub weight: u32,
    /// QoS token-bucket rate floor, bytes/s (AIMD never cuts below).
    pub min_rate: u64,
    /// Rate ceiling, bytes/s. The bucket starts here, so QoS is inert
    /// until congestion feedback walks the rate down.
    pub max_rate: u64,
    /// Bucket depth in bytes (burst tolerance before pacing).
    pub burst_bytes: u64,
}

impl Default for FabricSpec {
    fn default() -> FabricSpec {
        FabricSpec {
            enabled: false,
            qos: false,
            hop_lat: 12 * NS,
            ingress_cap: 64,
            weight: 1,
            min_rate: 1 << 26,  // 64 MiB/s floor
            max_rate: 1 << 42,  // ~4.4 TB/s: effectively unthrottled
            burst_bytes: 2048,  // 32 cache lines
        }
    }
}
