//! The multi-tenant pool coordinator: N independent GPU [`System`]s
//! stepped against one shared switch on a single global event order.
//!
//! Each tenant keeps its own calendar queue, RNG, warps and metrics —
//! everything the single-GPU simulator owns — while the switch and its
//! pooled endpoints are shared through the [`FabricLink`]. The
//! coordinator merges the tenants' calendars with
//! [`crate::sim::interleave()`]: always step the tenant whose next event
//! is earliest (ties to the lowest tenant index), which is exactly the
//! order one global queue would produce — so pool runs are
//! bit-reproducible (guarded in `tests/determinism.rs`).
//!
//! Tenants receive disjoint device-address slices of the pool (stacked
//! `dpa_base` offsets in each tenant's HDM walk): pooling shares
//! *bandwidth and queues*, never aliases *data*.

use std::sync::{Arc, Mutex};

use crate::coordinator::config::{MemStrategy, SystemConfig};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::system::System;
use crate::sim::interleave;
use crate::workloads::WorkloadSpec;

use super::switch::{CxlSwitch, PoolSums};
use super::FabricLink;

/// One tenant of a pool run: a workload bound to a fabric-enabled
/// configuration (the config's `fabric.weight` is the tenant's WRR
/// weight on the shared switch).
pub struct Tenant {
    pub workload: &'static WorkloadSpec,
    pub cfg: SystemConfig,
}

/// One tenant's outcome.
#[derive(Debug, Clone)]
pub struct TenantResult {
    pub workload: &'static str,
    pub config: String,
    pub metrics: RunMetrics,
}

/// A pool run's outcome: per-tenant metrics plus the shared endpoints'
/// pool-level sums (which no single tenant may claim — see
/// `System::harvest`).
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub tenants: Vec<TenantResult>,
    pub pool: PoolSums,
    /// Total simulation events across every tenant.
    pub events: u64,
}

/// Run `tenants` against one shared pool to completion.
///
/// Validation: every tenant must be a fabric-enabled CXL configuration
/// with an expander footprint, and all tenants must agree on the pool
/// topology (port count and media) and the switch spec (QoS on/off,
/// hop, ingress depth) — the switch is built once from tenant 0's
/// config plus every tenant's weight.
pub fn run_pool(tenants: &[Tenant]) -> Result<PoolResult, String> {
    let base = &tenants
        .first()
        .ok_or_else(|| "pool needs at least one tenant".to_string())?
        .cfg;
    for t in tenants {
        let c = &t.cfg;
        let name = &c.name;
        if c.strategy != MemStrategy::Cxl || !c.fabric.enabled {
            return Err(format!(
                "tenant config `{name}` is not a pooled-fabric configuration"
            ));
        }
        if c.footprint <= c.local_bytes {
            return Err(format!("tenant config `{name}` has no expander footprint"));
        }
        if c.ports != base.ports || c.media != base.media || c.media_per_port != base.media_per_port
        {
            return Err(format!(
                "tenant config `{name}` disagrees with the pool topology of `{}`",
                base.name
            ));
        }
        // The switch is built once from tenant 0's spec: every field
        // except the per-tenant WRR weight must agree, or a tenant's
        // QoS/topology knobs would be silently discarded.
        let mut normalized = c.fabric;
        normalized.weight = base.fabric.weight;
        if normalized != base.fabric {
            return Err(format!(
                "tenant config `{name}` disagrees with the switch spec of `{}`",
                base.name
            ));
        }
    }

    let weights: Vec<u32> = tenants.iter().map(|t| t.cfg.fabric.weight).collect();
    let link: FabricLink =
        Arc::new(Mutex::new(CxlSwitch::new(base.build_ports(), base.fabric, &weights)));

    // Stack each tenant's device-address slice per endpoint so pooled
    // capacity partitions cleanly.
    let mut systems: Vec<System> = Vec::with_capacity(tenants.len());
    let mut dpa_base = 0u64;
    for (i, t) in tenants.iter().enumerate() {
        let expander = t.cfg.footprint - t.cfg.local_bytes;
        systems.push(System::new_tenant(t.workload, &t.cfg, Arc::clone(&link), i, dpa_base)?);
        dpa_base += expander / t.cfg.ports as u64;
    }

    for s in &mut systems {
        s.prime();
    }
    interleave(&mut systems);

    let pool = link.lock().expect("fabric mutex poisoned").pool_sums();
    let tenants_out: Vec<TenantResult> = systems
        .into_iter()
        .zip(tenants)
        .map(|(s, t)| TenantResult {
            workload: t.workload.name,
            config: t.cfg.name.clone(),
            metrics: s.harvest(),
        })
        .collect();
    let events = tenants_out.iter().map(|t| t.metrics.events).sum();
    Ok(PoolResult { tenants: tenants_out, pool, events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::media::MediaKind;
    use crate::workloads::table1b::spec;

    fn tenant(config: &str, wl: &str, ops: usize) -> Tenant {
        let mut cfg = SystemConfig::named(config, MediaKind::Ddr5);
        cfg.total_ops = ops;
        cfg.warps = 8;
        cfg.footprint = 4 << 20;
        cfg.local_bytes = 64 << 10;
        Tenant { workload: spec(wl), cfg }
    }

    #[test]
    fn two_tenant_pool_completes_and_shares_endpoints() {
        let res = run_pool(&[
            tenant("cxl-pool", "bfs", 6_000),
            tenant("cxl-pool", "vadd", 6_000),
        ])
        .unwrap();
        assert_eq!(res.tenants.len(), 2);
        for t in &res.tenants {
            assert!(t.metrics.exec_time > 0, "{} never ran", t.workload);
            assert!(t.metrics.expander_loads > 0, "{} never hit the pool", t.workload);
            assert!(t.metrics.ingress_hwm >= 1, "{} bypassed the switch", t.workload);
        }
        assert_eq!(
            res.pool.loads,
            res.tenants.iter().map(|t| t.metrics.expander_loads).sum::<u64>(),
            "pooled endpoints must see exactly the tenants' expander loads"
        );
        assert!(res.events > 0);
    }

    #[test]
    fn pool_rejects_mismatched_tenants() {
        let a = tenant("cxl-pool", "bfs", 1_000);
        let mut b = tenant("cxl-pool", "vadd", 1_000);
        b.cfg.ports = 2;
        assert!(run_pool(&[a, b]).unwrap_err().contains("pool topology"));

        let a = tenant("cxl-pool", "bfs", 1_000);
        let b = tenant("cxl-pool-qos", "vadd", 1_000);
        assert!(run_pool(&[a, b]).unwrap_err().contains("switch spec"));

        let direct = {
            let mut t = tenant("cxl-pool", "bfs", 1_000);
            t.cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
            t
        };
        assert!(run_pool(&[direct]).unwrap_err().contains("not a pooled-fabric"));
        assert!(run_pool(&[]).unwrap_err().contains("at least one tenant"));
    }

    #[test]
    fn tenants_get_disjoint_dpa_slices() {
        // Two tenants, tiny footprints: completion implies no decode
        // misses; the pool sums prove both reached the endpoints.
        let res = run_pool(&[
            tenant("cxl-pool", "vadd", 4_000),
            tenant("cxl-pool", "saxpy", 4_000),
        ])
        .unwrap();
        assert!(res.pool.loads > 0 && res.pool.queue_hwm >= 1);
    }
}
