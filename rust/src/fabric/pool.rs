//! The multi-tenant pool coordinator: N independent GPU [`System`]s
//! stepped against one shared switch on a single global event order.
//!
//! Each tenant keeps its own calendar queue, RNG, warps and metrics —
//! everything the single-GPU simulator owns — while the switch and its
//! pooled endpoints are shared through the [`FabricLink`]. The
//! coordinator merges the tenants' calendars with
//! [`crate::sim::interleave()`]: always step the tenant whose next event
//! is earliest (ties to the lowest tenant index), which is exactly the
//! order one global queue would produce — so pool runs are
//! bit-reproducible (guarded in `tests/determinism.rs`).
//!
//! [`super::shard::run_pool_sharded`] is the parallel twin: same
//! validation, same systems, same results bit-for-bit, via the
//! conservative-lookahead engine in [`crate::sim::pdes`].
//!
//! Tenants receive disjoint device-address slices of the pool (stacked
//! `dpa_base` offsets in each tenant's HDM walk): pooling shares
//! *bandwidth and queues*, never aliases *data*.

use std::fmt;
use std::sync::{Arc, Mutex};

use crate::coordinator::config::{MemStrategy, SystemConfig};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::system::System;
use crate::sim::interleave;
use crate::workloads::WorkloadSpec;

use super::switch::{CxlSwitch, PoolSums};
use super::FabricLink;

/// One tenant of a pool run: a workload bound to a fabric-enabled
/// configuration (the config's `fabric.weight` is the tenant's WRR
/// weight on the shared switch).
pub struct Tenant {
    pub workload: &'static WorkloadSpec,
    pub cfg: SystemConfig,
}

/// One tenant's outcome.
#[derive(Debug, Clone)]
pub struct TenantResult {
    pub workload: &'static str,
    pub config: String,
    pub metrics: RunMetrics,
}

/// A pool run's outcome: per-tenant metrics plus the shared endpoints'
/// pool-level sums (which no single tenant may claim — see
/// `System::harvest`).
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub tenants: Vec<TenantResult>,
    pub pool: PoolSums,
    /// Total simulation events across every tenant.
    pub events: u64,
}

/// Why a pool run could not be built or started.
///
/// Every variant carries the context needed to point at the offending
/// tenant configuration; `Display` renders the operator-facing message
/// (and keeps the historical wording that callers and tests match on).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// The tenant list was empty.
    EmptyPool,
    /// A tenant's config is not a fabric-enabled CXL configuration.
    NotPooledConfig { name: String },
    /// A tenant's footprint fits entirely in local HBM — it would never
    /// touch the pool it claims to share.
    NoExpander { name: String },
    /// A tenant disagrees with tenant 0 on port count / media / fanout.
    TopologyMismatch { name: String, base: String },
    /// A tenant disagrees with tenant 0's switch spec (QoS, hop,
    /// ingress depth, rate bounds) — only the WRR weight may differ.
    SwitchSpecMismatch { name: String, base: String },
    /// A sharded run was asked for zero shards.
    BadShardCount { shards: usize },
    /// A sharded run needs a nonzero switch hop to build its
    /// conservative-lookahead window from.
    NoLookahead { name: String },
    /// Timeline capture samples shared switch state mid-epoch, which a
    /// sharded run cannot reproduce bit-identically.
    TimelineUnsupported { name: String },
    /// A tenant `System` failed to build (bad warps/mlp/footprint...).
    Tenant(String),
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PoolError::EmptyPool => write!(f, "pool needs at least one tenant"),
            PoolError::NotPooledConfig { name } => {
                write!(f, "tenant config `{name}` is not a pooled-fabric configuration")
            }
            PoolError::NoExpander { name } => {
                write!(f, "tenant config `{name}` has no expander footprint")
            }
            PoolError::TopologyMismatch { name, base } => {
                write!(f, "tenant config `{name}` disagrees with the pool topology of `{base}`")
            }
            PoolError::SwitchSpecMismatch { name, base } => {
                write!(f, "tenant config `{name}` disagrees with the switch spec of `{base}`")
            }
            PoolError::BadShardCount { shards } => {
                write!(f, "sharded pool needs at least one shard (got {shards})")
            }
            PoolError::NoLookahead { name } => write!(
                f,
                "tenant config `{name}` has a zero switch hop latency: \
                 a sharded run has no conservative-lookahead window"
            ),
            PoolError::TimelineUnsupported { name } => write!(
                f,
                "tenant config `{name}` requests timeline capture, \
                 which sharded pool runs do not support"
            ),
            PoolError::Tenant(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for PoolError {}

/// `System::new_tenant` / `System::try_new` report `String` errors;
/// wrap them so `?` composes inside the pool builders.
impl From<String> for PoolError {
    fn from(msg: String) -> Self {
        PoolError::Tenant(msg)
    }
}

/// Check the tenant list is a coherent pool; returns tenant 0's config
/// (the pool's base: the switch is built from it plus every tenant's
/// weight).
pub(crate) fn validate(tenants: &[Tenant]) -> Result<&SystemConfig, PoolError> {
    let base = &tenants.first().ok_or(PoolError::EmptyPool)?.cfg;
    for t in tenants {
        let c = &t.cfg;
        let name = || c.name.clone();
        if c.strategy != MemStrategy::Cxl || !c.fabric.enabled {
            return Err(PoolError::NotPooledConfig { name: name() });
        }
        if c.footprint <= c.local_bytes {
            return Err(PoolError::NoExpander { name: name() });
        }
        if c.ports != base.ports || c.media != base.media || c.media_per_port != base.media_per_port
        {
            return Err(PoolError::TopologyMismatch { name: name(), base: base.name.clone() });
        }
        // Every switch-spec field except the per-tenant WRR weight must
        // agree, or a tenant's QoS/topology knobs would be silently
        // discarded.
        let mut normalized = c.fabric;
        normalized.weight = base.fabric.weight;
        if normalized != base.fabric {
            return Err(PoolError::SwitchSpecMismatch { name: name(), base: base.name.clone() });
        }
    }
    Ok(base)
}

/// Build the shared switch and one primed `System` per tenant, each on
/// its own disjoint device-address slice. Shared by the serial and
/// sharded coordinators so both run literally the same systems.
pub(crate) fn build_pool(tenants: &[Tenant]) -> Result<(Vec<System>, FabricLink), PoolError> {
    let base = validate(tenants)?;
    let weights: Vec<u32> = tenants.iter().map(|t| t.cfg.fabric.weight).collect();
    let link: FabricLink =
        Arc::new(Mutex::new(CxlSwitch::new(base.build_ports(), base.fabric, &weights)));

    // Stack each tenant's device-address slice per endpoint so pooled
    // capacity partitions cleanly.
    let mut systems: Vec<System> = Vec::with_capacity(tenants.len());
    let mut dpa_base = 0u64;
    for (i, t) in tenants.iter().enumerate() {
        let expander = t.cfg.footprint - t.cfg.local_bytes;
        systems.push(System::new_tenant(t.workload, &t.cfg, Arc::clone(&link), i, dpa_base)?);
        dpa_base += expander / t.cfg.ports as u64;
    }
    for s in &mut systems {
        s.prime();
    }
    Ok((systems, link))
}

/// Collect per-tenant metrics and the pool-level sums after a run.
pub(crate) fn harvest_pool(systems: Vec<System>, tenants: &[Tenant], link: &FabricLink) -> PoolResult {
    let pool = link.lock().expect("fabric mutex poisoned").pool_sums();
    let tenants_out: Vec<TenantResult> = systems
        .into_iter()
        .zip(tenants)
        .map(|(s, t)| TenantResult {
            workload: t.workload.name,
            config: t.cfg.name.clone(),
            metrics: s.harvest(),
        })
        .collect();
    let events = tenants_out.iter().map(|t| t.metrics.events).sum();
    PoolResult { tenants: tenants_out, pool, events }
}

/// Run `tenants` against one shared pool to completion, serially.
///
/// Validation: every tenant must be a fabric-enabled CXL configuration
/// with an expander footprint, and all tenants must agree on the pool
/// topology (port count and media) and the switch spec (QoS on/off,
/// hop, ingress depth) — the switch is built once from tenant 0's
/// config plus every tenant's weight.
pub fn run_pool(tenants: &[Tenant]) -> Result<PoolResult, PoolError> {
    let (mut systems, link) = build_pool(tenants)?;
    interleave(&mut systems);
    Ok(harvest_pool(systems, tenants, &link))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::media::MediaKind;
    use crate::workloads::table1b::spec;

    fn tenant(config: &str, wl: &str, ops: usize) -> Tenant {
        let mut cfg = SystemConfig::named(config, MediaKind::Ddr5);
        cfg.total_ops = ops;
        cfg.warps = 8;
        cfg.footprint = 4 << 20;
        cfg.local_bytes = 64 << 10;
        Tenant { workload: spec(wl), cfg }
    }

    #[test]
    fn two_tenant_pool_completes_and_shares_endpoints() {
        let res = run_pool(&[
            tenant("cxl-pool", "bfs", 6_000),
            tenant("cxl-pool", "vadd", 6_000),
        ])
        .unwrap();
        assert_eq!(res.tenants.len(), 2);
        for t in &res.tenants {
            assert!(t.metrics.exec_time > 0, "{} never ran", t.workload);
            assert!(t.metrics.expander_loads > 0, "{} never hit the pool", t.workload);
            assert!(t.metrics.ingress_hwm >= 1, "{} bypassed the switch", t.workload);
        }
        assert_eq!(
            res.pool.loads,
            res.tenants.iter().map(|t| t.metrics.expander_loads).sum::<u64>(),
            "pooled endpoints must see exactly the tenants' expander loads"
        );
        assert!(res.events > 0);
    }

    #[test]
    fn pool_rejects_mismatched_tenants() {
        let a = tenant("cxl-pool", "bfs", 1_000);
        let mut b = tenant("cxl-pool", "vadd", 1_000);
        b.cfg.ports = 2;
        let err = run_pool(&[a, b]).unwrap_err();
        assert!(matches!(err, PoolError::TopologyMismatch { .. }), "{err:?}");
        assert!(err.to_string().contains("pool topology"));

        let a = tenant("cxl-pool", "bfs", 1_000);
        let b = tenant("cxl-pool-qos", "vadd", 1_000);
        let err = run_pool(&[a, b]).unwrap_err();
        assert!(matches!(err, PoolError::SwitchSpecMismatch { .. }), "{err:?}");
        assert!(err.to_string().contains("switch spec"));

        let direct = {
            let mut t = tenant("cxl-pool", "bfs", 1_000);
            t.cfg = SystemConfig::named("cxl", MediaKind::Ddr5);
            t
        };
        let err = run_pool(&[direct]).unwrap_err();
        assert!(matches!(err, PoolError::NotPooledConfig { .. }), "{err:?}");
        assert!(err.to_string().contains("not a pooled-fabric"));

        let err = run_pool(&[]).unwrap_err();
        assert_eq!(err, PoolError::EmptyPool);
        assert!(err.to_string().contains("at least one tenant"));
    }

    #[test]
    fn pool_rejects_a_tenant_with_no_expander_share() {
        let mut local_only = tenant("cxl-pool", "bfs", 1_000);
        local_only.cfg.local_bytes = local_only.cfg.footprint;
        let err = run_pool(&[local_only]).unwrap_err();
        assert!(matches!(err, PoolError::NoExpander { .. }), "{err:?}");
        assert!(err.to_string().contains("has no expander footprint"));
    }

    #[test]
    fn pool_error_display_names_the_offender() {
        // Each contextful variant must surface the tenant config name,
        // so a 64-tenant pool failure points at the one bad config.
        let errs = [
            PoolError::NotPooledConfig { name: "t7".into() },
            PoolError::NoExpander { name: "t7".into() },
            PoolError::TopologyMismatch { name: "t7".into(), base: "t0".into() },
            PoolError::SwitchSpecMismatch { name: "t7".into(), base: "t0".into() },
            PoolError::NoLookahead { name: "t7".into() },
            PoolError::TimelineUnsupported { name: "t7".into() },
        ];
        for e in &errs {
            assert!(e.to_string().contains("t7"), "{e:?} lost the tenant name");
        }
        assert!(PoolError::BadShardCount { shards: 0 }.to_string().contains("got 0"));
        // And the std::error::Error plumbing works end to end.
        let boxed: Box<dyn std::error::Error> = Box::new(PoolError::EmptyPool);
        assert_eq!(boxed.to_string(), "pool needs at least one tenant");
    }

    #[test]
    fn tenants_get_disjoint_dpa_slices() {
        // Two tenants, tiny footprints: completion implies no decode
        // misses; the pool sums prove both reached the endpoints.
        let res = run_pool(&[
            tenant("cxl-pool", "vadd", 4_000),
            tenant("cxl-pool", "saxpy", 4_000),
        ])
        .unwrap();
        assert!(res.pool.loads > 0 && res.pool.queue_hwm >= 1);
    }
}
