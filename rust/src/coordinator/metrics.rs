//! Run metrics: everything the figure benches and EXPERIMENTS.md consume.

use crate::gpu::cache::LlcStats;
use crate::sim::{ps_to_ns, Time, US};
use crate::sim::Timeline;
use crate::util::stats::{Percentiles, Summary};

/// Fig. 9e's three time series.
#[derive(Debug, Clone)]
pub struct Fig9eSeries {
    pub load_latency: Timeline,
    pub store_latency: Timeline,
    pub ingress_occupancy: Timeline,
}

impl Fig9eSeries {
    pub fn new() -> Fig9eSeries {
        // 50 µs buckets resolve the multi-ms GC episodes cleanly.
        Fig9eSeries {
            load_latency: Timeline::new("load-latency-ns", 50 * US),
            store_latency: Timeline::new("store-latency-ns", 50 * US),
            ingress_occupancy: Timeline::new("ingress-occupancy", 50 * US),
        }
    }
}

impl Default for Fig9eSeries {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregated metrics for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Simulated execution time (max warp finish).
    pub exec_time: Time,
    /// End-to-end load latency (issue -> data), expander + local.
    pub load_latency: Summary,
    /// Store ack latency on the expander path.
    pub store_latency: Summary,
    pub llc: LlcStats,
    /// Loads that crossed the system bus to the expander.
    pub expander_loads: u64,
    pub expander_stores: u64,
    /// Loads served from the DS buffer in GPU memory.
    pub ds_intercepts: u64,
    /// Loads served by the SSD's internal DRAM (incl. SR prefetches).
    pub ep_cache_hits: u64,
    /// Loads that paid full backend-media latency.
    pub media_reads: u64,
    /// Page faults (UVM/GDS).
    pub faults: u64,
    /// GC episodes observed at the SSD EP.
    pub gc_episodes: u64,
    /// Speculative reads issued.
    pub sr_issued: u64,
    /// Tiering: pages promoted slow→fast (DESIGN.md §12).
    pub tier_promotions: u64,
    /// Tiering: pages demoted fast→slow.
    pub tier_demotions: u64,
    /// Tiering: bytes moved by the migration engine (both directions).
    pub tier_migrated_bytes: u64,
    /// Tiering: expander accesses decoded to a fast-tier (DRAM) frame.
    pub tier_fast_accesses: u64,
    /// Tiering: expander accesses decoded to a slow-tier (SSD) frame.
    pub tier_slow_accesses: u64,
    /// Tiering: epoch scans performed.
    pub tier_epochs: u64,
    /// Expander device-cache (DESIGN.md §14) demand hits, summed across
    /// SSD endpoints (0 for uncached configs).
    pub cache_hits: u64,
    /// Device-cache demand misses.
    pub cache_misses: u64,
    /// Dirty-eviction writebacks queued for media drain.
    pub cache_writebacks: u64,
    /// Read misses the admission predictor refused to install
    /// (streaming bypass).
    pub cache_bypasses: u64,
    /// Writeback drain-queue high-water mark, maxed across endpoints.
    pub cache_wb_hwm: u64,
    /// Expander-load latency reservoir (issue → data, queueing
    /// included) for percentile queries — the multi-tenant experiments'
    /// p99 victim-slowdown metric. Deterministic (index-hashed
    /// reservoir), but not fingerprinted: the summary above already
    /// pins the distribution bit-for-bit.
    pub load_pctl: Percentiles,
    /// Root-port memory-queue occupancy high-water mark, maxed across
    /// this system's ports (pooled endpoints when this tenant is a
    /// pool's sole upstream).
    pub port_queue_hwm: u64,
    /// Fabric: this tenant's switch-ingress-queue high-water mark
    /// (0 for direct topologies and passthrough pools).
    pub ingress_hwm: u64,
    /// Fabric QoS: requests delayed by this tenant's token bucket.
    pub qos_throttle_waits: u64,
    /// Fabric QoS: total token-bucket delay, picoseconds.
    pub qos_throttle_ps: u64,
    /// Fabric: endpoint DevLoad observations of Moderate or worse
    /// returned to this tenant (originating-tenant-only backpressure).
    pub fabric_backpressure: u64,
    /// RAS (DESIGN.md §15): link-layer retransmissions triggered by
    /// injected CRC errors, summed across this system's ports (pooled
    /// endpoints when this tenant is a pool's sole upstream).
    pub ras_retries: u64,
    /// RAS: flits re-sent by the go-back replay buffer.
    pub ras_replays: u64,
    /// RAS: transfers poisoned after exhausting the retry budget.
    pub ras_poisons: u64,
    /// RAS: controller timeout expiries (backoff waits charged).
    pub ras_timeouts: u64,
    /// RAS: failover actions — endpoint degradation latches, switch
    /// WRR demotions, and tier-swap vetoes onto a degraded port.
    pub ras_failovers: u64,
    /// RAS: dirty device-cache bytes flushed to media ahead of a
    /// scheduled endpoint degradation (zero lost bytes).
    pub ras_dirty_rescued_bytes: u64,
    /// Simulation events processed (perf metric).
    pub events: u64,
    /// Host wall-clock for the run, nanoseconds (perf metric).
    pub wall_ns: u128,
    /// Optional Fig. 9e series.
    pub series: Option<Fig9eSeries>,
}

impl RunMetrics {
    /// SSD internal-DRAM hit rate over expander loads that reached the EP.
    pub fn ep_hit_rate(&self) -> f64 {
        let reached = self.ep_cache_hits + self.media_reads;
        if reached == 0 {
            0.0
        } else {
            self.ep_cache_hits as f64 / reached as f64
        }
    }

    /// Simulated exec time in milliseconds.
    pub fn exec_ms(&self) -> f64 {
        ps_to_ns(self.exec_time) / 1e6
    }

    /// Expander device-cache hit rate over its demand lookups (0 when
    /// no endpoint carried a cache).
    pub fn dev_cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of tier-tracked expander accesses served by the fast
    /// (DRAM) tier; 0 when the run had no tiering subsystem.
    pub fn tier_fast_ratio(&self) -> f64 {
        let total = self.tier_fast_accesses + self.tier_slow_accesses;
        if total == 0 {
            0.0
        } else {
            self.tier_fast_accesses as f64 / total as f64
        }
    }

    /// p99 expander-load latency in microseconds (0 when the run had no
    /// expander loads).
    pub fn load_p99_us(&self) -> f64 {
        self.load_pctl.percentile(99.0) / 1e6
    }

    /// Events per wall second (simulator throughput).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.events as f64 / (self.wall_ns as f64 / 1e9)
        }
    }

    /// One-line human summary.
    pub fn summary_line(&self) -> String {
        format!(
            "exec {:.3} ms | load avg {:.0} ns p-mean | llc hit {:.1}% | ep hit {:.1}% | faults {} | gc {} | {:.1} M events/s",
            self.exec_ms(),
            self.load_latency.mean() / 1000.0,
            self.llc.hit_rate() * 100.0,
            self.ep_hit_rate() * 100.0,
            self.faults,
            self.gc_episodes,
            self.events_per_sec() / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ep_hit_rate_handles_zero() {
        let m = RunMetrics::default();
        assert_eq!(m.ep_hit_rate(), 0.0);
    }

    #[test]
    fn ep_hit_rate_computes() {
        let m = RunMetrics { ep_cache_hits: 3, media_reads: 1, ..Default::default() };
        assert!((m.ep_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn exec_ms_converts() {
        let m = RunMetrics { exec_time: 2_000_000_000, ..Default::default() }; // 2 ms in ps
        assert!((m.exec_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_line_formats() {
        let m = RunMetrics::default();
        assert!(m.summary_line().contains("exec"));
    }

    #[test]
    fn dev_cache_hit_rate_handles_zero_and_computes() {
        assert_eq!(RunMetrics::default().dev_cache_hit_rate(), 0.0);
        let m = RunMetrics { cache_hits: 3, cache_misses: 1, ..Default::default() };
        assert!((m.dev_cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn tier_fast_ratio_handles_zero_and_computes() {
        assert_eq!(RunMetrics::default().tier_fast_ratio(), 0.0);
        let m = RunMetrics {
            tier_fast_accesses: 9,
            tier_slow_accesses: 1,
            ..Default::default()
        };
        assert!((m.tier_fast_ratio() - 0.9).abs() < 1e-12);
    }
}
