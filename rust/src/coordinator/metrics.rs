//! Run metrics: everything the figure benches and EXPERIMENTS.md consume.

use crate::gpu::cache::LlcStats;
use crate::obs::{ObsReport, Stage};
use crate::sim::{ps_to_ns, Time, US};
use crate::sim::Timeline;
use crate::telemetry::TelemetryReport;
use crate::util::stats::{Percentiles, Summary};

/// Fig. 9e's three time series, carried on the shared
/// `telemetry::Series` type (`Timeline` is its historical re-export) —
/// per-*op* samples recorded inline on the load/store path, as opposed
/// to the flight recorder's per-*epoch* frames.
#[derive(Debug, Clone)]
pub struct Fig9eSeries {
    pub load_latency: Timeline,
    pub store_latency: Timeline,
    pub ingress_occupancy: Timeline,
}

impl Fig9eSeries {
    pub fn new() -> Fig9eSeries {
        // 50 µs buckets resolve the multi-ms GC episodes cleanly.
        Fig9eSeries {
            load_latency: Timeline::new("load-latency-ns", 50 * US),
            store_latency: Timeline::new("store-latency-ns", 50 * US),
            ingress_occupancy: Timeline::new("ingress-occupancy", 50 * US),
        }
    }
}

impl Default for Fig9eSeries {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregated metrics for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Simulated execution time (max warp finish).
    pub exec_time: Time,
    /// End-to-end load latency (issue -> data), expander + local.
    pub load_latency: Summary,
    /// Store ack latency on the expander path.
    pub store_latency: Summary,
    pub llc: LlcStats,
    /// Loads that crossed the system bus to the expander.
    pub expander_loads: u64,
    pub expander_stores: u64,
    /// Loads served from the DS buffer in GPU memory.
    pub ds_intercepts: u64,
    /// Loads served by the SSD's internal DRAM (incl. SR prefetches).
    pub ep_cache_hits: u64,
    /// Loads that paid full backend-media latency.
    pub media_reads: u64,
    /// Page faults (UVM/GDS).
    pub faults: u64,
    /// GC episodes observed at the SSD EP.
    pub gc_episodes: u64,
    /// Speculative reads issued.
    pub sr_issued: u64,
    /// Tiering: pages promoted slow→fast (DESIGN.md §12).
    pub tier_promotions: u64,
    /// Tiering: pages demoted fast→slow.
    pub tier_demotions: u64,
    /// Tiering: bytes moved by the migration engine (both directions).
    pub tier_migrated_bytes: u64,
    /// Tiering: expander accesses decoded to a fast-tier (DRAM) frame.
    pub tier_fast_accesses: u64,
    /// Tiering: expander accesses decoded to a slow-tier (SSD) frame.
    pub tier_slow_accesses: u64,
    /// Tiering: epoch scans performed.
    pub tier_epochs: u64,
    /// Expander device-cache (DESIGN.md §14) demand hits, summed across
    /// SSD endpoints (0 for uncached configs).
    pub cache_hits: u64,
    /// Device-cache demand misses.
    pub cache_misses: u64,
    /// Dirty-eviction writebacks queued for media drain.
    pub cache_writebacks: u64,
    /// Read misses the admission predictor refused to install
    /// (streaming bypass).
    pub cache_bypasses: u64,
    /// Writeback drain-queue high-water mark, maxed across endpoints.
    pub cache_wb_hwm: u64,
    /// Expander-load latency reservoir (issue → data, queueing
    /// included) for percentile queries — the multi-tenant experiments'
    /// p99 victim-slowdown metric. Deterministic (index-hashed
    /// reservoir), but not fingerprinted: the summary above already
    /// pins the distribution bit-for-bit.
    pub load_pctl: Percentiles,
    /// Root-port memory-queue occupancy high-water mark, maxed across
    /// this system's ports (pooled endpoints when this tenant is a
    /// pool's sole upstream).
    pub port_queue_hwm: u64,
    /// Fabric: this tenant's switch-ingress-queue high-water mark
    /// (0 for direct topologies and passthrough pools).
    pub ingress_hwm: u64,
    /// Fabric QoS: requests delayed by this tenant's token bucket.
    pub qos_throttle_waits: u64,
    /// Fabric QoS: total token-bucket delay, picoseconds.
    pub qos_throttle_ps: u64,
    /// Fabric: endpoint DevLoad observations of Moderate or worse
    /// returned to this tenant (originating-tenant-only backpressure).
    pub fabric_backpressure: u64,
    /// RAS (DESIGN.md §15): link-layer retransmissions triggered by
    /// injected CRC errors, summed across this system's ports (pooled
    /// endpoints when this tenant is a pool's sole upstream).
    pub ras_retries: u64,
    /// RAS: flits re-sent by the go-back replay buffer.
    pub ras_replays: u64,
    /// RAS: transfers poisoned after exhausting the retry budget.
    pub ras_poisons: u64,
    /// RAS: controller timeout expiries (backoff waits charged).
    pub ras_timeouts: u64,
    /// RAS: failover actions — endpoint degradation latches, switch
    /// WRR demotions, and tier-swap vetoes onto a degraded port.
    pub ras_failovers: u64,
    /// RAS: dirty device-cache bytes flushed to media ahead of a
    /// scheduled endpoint degradation (zero lost bytes).
    pub ras_dirty_rescued_bytes: u64,
    /// Serve (DESIGN.md §16): open-loop arrivals generated by the front
    /// door (0 for closed-loop runs — all `serve_*` counters are).
    pub serve_arrivals: u64,
    /// Serve: arrivals that passed token-bucket admission.
    pub serve_admitted: u64,
    /// Serve: arrivals the token bucket refused.
    pub serve_rejected: u64,
    /// Serve: queued requests dropped by the load shedder.
    pub serve_shed: u64,
    /// Serve: requests abandoned after exhausting their retry budget.
    pub serve_timed_out: u64,
    /// Serve: deadline extensions granted (timeout-and-retry backoff).
    pub serve_retried: u64,
    /// Serve: requests whose warp work ran to completion.
    pub serve_completed: u64,
    /// Serve: completions that beat their deadline (the goodput
    /// numerator).
    pub serve_completed_in_slo: u64,
    /// Serve: admission-queue high-water mark (bounded by the spec's
    /// `queue_cap` — the no-collapse guarantee).
    pub serve_queue_hwm: u64,
    /// Serve: end-to-end request latency (arrival → last op retired).
    pub req_latency: Summary,
    /// Serve: request-latency reservoir for p50/p99/p999 queries.
    /// Deterministic (index-hashed reservoir), but not fingerprinted:
    /// the summary above already pins the distribution bit-for-bit.
    pub req_pctl: Percentiles,
    /// Simulation events processed (perf metric).
    pub events: u64,
    /// Host wall-clock for the run, nanoseconds (perf metric).
    pub wall_ns: u128,
    /// Optional Fig. 9e series.
    pub series: Option<Fig9eSeries>,
    /// Span-ledger breakdown (§18); `None` unless the run armed
    /// `cfg.obs`. Deterministic for a fixed config (counter-clocked
    /// sampling, no RNG), but not fingerprinted: the breakdown explains
    /// the fingerprinted latencies, it is not one of them — and its
    /// conservation invariant ties it to them bit-exactly anyway.
    pub obs: Option<ObsReport>,
    /// Flight-recorder report (§19); `None` unless the run armed
    /// `cfg.telemetry`. Deterministic for a fixed config (calendar-tick
    /// sampling of values the run computes anyway, no RNG), but not
    /// fingerprinted: frames *explain* the fingerprinted totals — their
    /// conservation invariant (deltas sum to the totals exactly) ties
    /// them to the fingerprint bit-exactly anyway.
    pub telemetry: Option<TelemetryReport>,
}

impl RunMetrics {
    /// Everything deterministic about a run, flattened to `u64`s for
    /// exact comparison (wall-clock excluded, of course; f64
    /// accumulators compared through their raw bits). This is THE
    /// bit-identity surface: `tests/determinism.rs` pins repeated runs
    /// to it, and the sharded pool coordinator (`fabric::shard`,
    /// `benches/pool_scale.rs`) pins every parallel schedule to the
    /// serial run's fingerprint. The percentile reservoirs are
    /// deliberately absent — deterministic, but fully implied by the
    /// summaries already listed.
    pub fn fingerprint(&self) -> Vec<u64> {
        vec![
            self.exec_time,
            self.events,
            self.expander_loads,
            self.expander_stores,
            self.ds_intercepts,
            self.ep_cache_hits,
            self.media_reads,
            self.faults,
            self.gc_episodes,
            self.sr_issued,
            self.llc.hits,
            self.llc.misses,
            self.llc.merged,
            self.llc.writebacks,
            self.load_latency.count(),
            self.load_latency.mean().to_bits(),
            self.load_latency.max().to_bits(),
            self.store_latency.count(),
            self.store_latency.mean().to_bits(),
            self.tier_promotions,
            self.tier_demotions,
            self.tier_migrated_bytes,
            self.tier_fast_accesses,
            self.tier_slow_accesses,
            self.tier_epochs,
            self.port_queue_hwm,
            self.ingress_hwm,
            self.qos_throttle_waits,
            self.qos_throttle_ps,
            self.fabric_backpressure,
            self.cache_hits,
            self.cache_misses,
            self.cache_writebacks,
            self.cache_bypasses,
            self.cache_wb_hwm,
            self.ras_retries,
            self.ras_replays,
            self.ras_poisons,
            self.ras_timeouts,
            self.ras_failovers,
            self.ras_dirty_rescued_bytes,
            self.serve_arrivals,
            self.serve_admitted,
            self.serve_rejected,
            self.serve_shed,
            self.serve_timed_out,
            self.serve_retried,
            self.serve_completed,
            self.serve_completed_in_slo,
            self.serve_queue_hwm,
            self.req_latency.count(),
            self.req_latency.mean().to_bits(),
            self.req_latency.max().to_bits(),
        ]
    }

    /// SSD internal-DRAM hit rate over expander loads that reached the EP.
    pub fn ep_hit_rate(&self) -> f64 {
        let reached = self.ep_cache_hits + self.media_reads;
        if reached == 0 {
            0.0
        } else {
            self.ep_cache_hits as f64 / reached as f64
        }
    }

    /// Simulated exec time in milliseconds.
    pub fn exec_ms(&self) -> f64 {
        ps_to_ns(self.exec_time) / 1e6
    }

    /// Expander device-cache hit rate over its demand lookups (0 when
    /// no endpoint carried a cache).
    pub fn dev_cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of tier-tracked expander accesses served by the fast
    /// (DRAM) tier; 0 when the run had no tiering subsystem.
    pub fn tier_fast_ratio(&self) -> f64 {
        let total = self.tier_fast_accesses + self.tier_slow_accesses;
        if total == 0 {
            0.0
        } else {
            self.tier_fast_accesses as f64 / total as f64
        }
    }

    /// p99 expander-load latency in microseconds (0 when the run had no
    /// expander loads).
    pub fn load_p99_us(&self) -> f64 {
        self.load_pctl.percentile(99.0) / 1e6
    }

    /// Median end-to-end request latency in microseconds (0 — not NaN —
    /// when the run completed no requests, e.g. closed-loop runs or a
    /// fully-shed overload).
    pub fn request_p50_us(&self) -> f64 {
        self.req_pctl.percentile(50.0) / 1e6
    }

    /// p99 end-to-end request latency in microseconds (0 — not NaN —
    /// when the run completed no requests).
    pub fn request_p99_us(&self) -> f64 {
        self.req_pctl.percentile(99.0) / 1e6
    }

    /// p99.9 end-to-end request latency in microseconds (0 — not NaN —
    /// when the run completed no requests).
    pub fn request_p999_us(&self) -> f64 {
        self.req_pctl.percentile(99.9) / 1e6
    }

    /// Goodput: in-SLO request completions per simulated second (0 when
    /// the run served no requests or has no exec time).
    pub fn goodput_rps(&self) -> f64 {
        if self.exec_time == 0 {
            0.0
        } else {
            self.serve_completed_in_slo as f64 / (self.exec_time as f64 / 1e12)
        }
    }

    /// Mean nanoseconds per sampled span attributed to `stage` (0 — not
    /// NaN — when the run traced nothing). Stacked across every stage
    /// these reassemble the mean sampled end-to-end latency exactly.
    pub fn obs_stage_per_span_ns(&self, stage: Stage) -> f64 {
        self.obs.as_ref().map_or(0.0, |o| o.stage_per_span_ns(stage))
    }

    /// Mean duration of `stage` when traversed, in ns (0 when untraced).
    pub fn obs_stage_mean_ns(&self, stage: Stage) -> f64 {
        self.obs.as_ref().map_or(0.0, |o| o.stage_mean_ns(stage))
    }

    /// p99 duration of `stage` when traversed, in ns (0 when untraced).
    pub fn obs_stage_p99_ns(&self, stage: Stage) -> f64 {
        self.obs.as_ref().map_or(0.0, |o| o.stage_p99_ns(stage))
    }

    /// `stage`'s share of the total attributed time, in [0, 1] (0 when
    /// untraced).
    pub fn obs_stage_share(&self, stage: Stage) -> f64 {
        self.obs.as_ref().map_or(0.0, |o| o.stage_share(stage))
    }

    /// Sampled span count (0 when the run traced nothing).
    pub fn obs_spans(&self) -> u64 {
        self.obs.as_ref().map_or(0, |o| o.spans)
    }

    /// Ledger conservation violations across sampled spans (must be 0;
    /// property-tested in `tests/props.rs`).
    pub fn obs_violations(&self) -> u64 {
        self.obs.as_ref().map_or(0, |o| o.violations)
    }

    /// Telemetry frames recorded (0 when the run armed no recorder).
    pub fn telemetry_frames(&self) -> usize {
        self.telemetry.as_ref().map_or(0, |t| t.frames.len())
    }

    /// Health-monitor alerts fired (0 when unarmed).
    pub fn telemetry_alerts(&self) -> usize {
        self.telemetry.as_ref().map_or(0, |t| t.alerts.len())
    }

    /// Frames dropped past the recorder's `max_frames` cap (0 when
    /// unarmed; nonzero means the conservation sum is intentionally
    /// short by the dropped windows).
    pub fn telemetry_dropped(&self) -> u64 {
        self.telemetry.as_ref().map_or(0, |t| t.dropped)
    }

    /// Sum a per-frame counter delta across the recorded stream (0 when
    /// unarmed). For conserved counters this equals the run-final total
    /// — property-tested in `tests/props.rs`.
    pub fn telemetry_total(&self, field: impl Fn(&crate::telemetry::Frame) -> u64) -> u64 {
        self.telemetry.as_ref().map_or(0, |t| t.total(field))
    }

    /// Events per wall second (simulator throughput).
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.events as f64 / (self.wall_ns as f64 / 1e9)
        }
    }

    /// One-line human summary.
    pub fn summary_line(&self) -> String {
        format!(
            "exec {:.3} ms | load avg {:.0} ns p-mean | llc hit {:.1}% | ep hit {:.1}% | faults {} | gc {} | {:.1} M events/s",
            self.exec_ms(),
            self.load_latency.mean() / 1000.0,
            self.llc.hit_rate() * 100.0,
            self.ep_hit_rate() * 100.0,
            self.faults,
            self.gc_episodes,
            self.events_per_sec() / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ep_hit_rate_handles_zero() {
        let m = RunMetrics::default();
        assert_eq!(m.ep_hit_rate(), 0.0);
    }

    #[test]
    fn ep_hit_rate_computes() {
        let m = RunMetrics { ep_cache_hits: 3, media_reads: 1, ..Default::default() };
        assert!((m.ep_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn exec_ms_converts() {
        let m = RunMetrics { exec_time: 2_000_000_000, ..Default::default() }; // 2 ms in ps
        assert!((m.exec_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn summary_line_formats() {
        let m = RunMetrics::default();
        assert!(m.summary_line().contains("exec"));
    }

    #[test]
    fn dev_cache_hit_rate_handles_zero_and_computes() {
        assert_eq!(RunMetrics::default().dev_cache_hit_rate(), 0.0);
        let m = RunMetrics { cache_hits: 3, cache_misses: 1, ..Default::default() };
        assert!((m.dev_cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_request_reservoirs_read_zero_not_nan() {
        // Satellite guard: a run with no completed requests (closed-loop,
        // or overload that shed everything) must report 0, never NaN.
        let m = RunMetrics::default();
        for v in [m.request_p50_us(), m.request_p99_us(), m.request_p999_us()] {
            assert!(!v.is_nan(), "empty reservoir produced NaN");
            assert_eq!(v, 0.0);
        }
        assert_eq!(m.load_p99_us(), 0.0);
        assert_eq!(m.goodput_rps(), 0.0, "zero exec time must not divide");
    }

    #[test]
    fn request_percentiles_and_goodput_compute() {
        let mut m = RunMetrics::default();
        for i in 1..=1000u64 {
            m.req_pctl.add(i as f64 * 1e6); // 1..=1000 µs
        }
        assert!((m.request_p50_us() - 500.0).abs() < 10.0, "{}", m.request_p50_us());
        assert!(m.request_p99_us() > 950.0);
        assert!(m.request_p999_us() >= m.request_p99_us());
        m.exec_time = 2_000_000_000_000; // 2 s in ps
        m.serve_completed_in_slo = 500;
        assert!((m.goodput_rps() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn obs_accessors_read_zero_when_untraced() {
        let m = RunMetrics::default();
        assert_eq!(m.obs_spans(), 0);
        assert_eq!(m.obs_violations(), 0);
        for s in Stage::ALL {
            assert_eq!(m.obs_stage_per_span_ns(s), 0.0);
            assert_eq!(m.obs_stage_mean_ns(s), 0.0);
            assert_eq!(m.obs_stage_p99_ns(s), 0.0);
            assert_eq!(m.obs_stage_share(s), 0.0);
        }
    }

    #[test]
    fn telemetry_accessors_read_zero_when_unarmed() {
        let m = RunMetrics::default();
        assert_eq!(m.telemetry_frames(), 0);
        assert_eq!(m.telemetry_alerts(), 0);
        assert_eq!(m.telemetry_dropped(), 0);
        assert_eq!(m.telemetry_total(|f| f.d_loads), 0);
    }

    #[test]
    fn tier_fast_ratio_handles_zero_and_computes() {
        assert_eq!(RunMetrics::default().tier_fast_ratio(), 0.0);
        let m = RunMetrics {
            tier_fast_accesses: 9,
            tier_slow_accesses: 1,
            ..Default::default()
        };
        assert!((m.tier_fast_ratio() - 0.9).abs() < 1e-12);
    }
}
