//! System configuration: the five GPU configurations of the paper's
//! evaluation (UVM, GDS, CXL, CXL-SR, CXL-DS) plus GPU-DRAM (ideal), the
//! Fig. 9d ablation points (CXL-NAIVE, CXL-DYN) and the Fig. 3b / headline
//! comparator built on a PCIe-era controller (CXL-SMT).

use crate::cxl::ControllerKind;
use crate::expander::CacheSpec;
use crate::fabric::FabricSpec;
use crate::gpu::LlcConfig;
use crate::media::{DramModel, DramTimings, MediaKind, SsdModel, SsdParams};
use crate::obs::ObsSpec;
use crate::telemetry::TelemetrySpec;
use crate::ras::FaultSpec;
use crate::rootcomplex::{EpBackend, RootPort, SrPolicy, TierConfig};
use crate::serve::ServeSpec;
use crate::util::toml::Document;

/// Top-level memory-expansion strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemStrategy {
    /// Ideal: local GPU memory holds the whole footprint.
    GpuDram,
    /// Unified virtual memory (host DRAM + page faults).
    Uvm,
    /// GPUDirect Storage (SSD + page faults).
    Gds,
    /// CXL expander through the root complex.
    Cxl,
}

/// Full system configuration for one simulation run.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub name: String,
    pub strategy: MemStrategy,
    /// Expander backend media (ignored for GpuDram/Uvm).
    pub media: MediaKind,
    pub controller: ControllerKind,
    pub sr_policy: SrPolicy,
    pub ds_enabled: bool,
    /// GPU local memory size.
    pub local_bytes: u64,
    /// Total workload footprint (paper: 10x local).
    pub footprint: u64,
    pub llc: LlcConfig,
    pub warps: usize,
    /// Outstanding loads per warp before stalling.
    pub mlp: usize,
    pub total_ops: usize,
    pub seed: u64,
    /// UVM/GDS migration block.
    pub uvm_block: u64,
    /// Number of CXL root ports.
    pub ports: usize,
    /// Reserved GPU memory for the DS stack.
    pub ds_capacity: u64,
    /// Collect Fig. 9e time series.
    pub timeline: bool,
    /// Per-port media override (heterogeneous expanders, Fig. 1a's
    /// "DRAMs and/or SSDs"); `None` = every port uses `media`.
    pub media_per_port: Option<Vec<MediaKind>>,
    /// Hot-page tiering across heterogeneous ports (DESIGN.md §12):
    /// interleaved HDM enumeration, access tracking and (when
    /// `tier.migrate`) epoch-based page migration.
    pub tier: TierConfig,
    /// Pooled-fabric attachment (DESIGN.md §13): route the expander
    /// through a virtual CXL switch instead of direct root ports, with
    /// optional per-tenant QoS. Mutually exclusive with `tier`.
    pub fabric: FabricSpec,
    /// Shard count for sharded pool runs (`fabric::shard`, DESIGN.md
    /// §17): how many contiguous tenant groups the conservative-
    /// lookahead coordinator advances in parallel. `0` = auto (one
    /// shard per tenant). Purely a wall-clock knob — results are
    /// bit-identical to the serial pool at every value.
    pub pool_shards: usize,
    /// Expander-side device DRAM cache inside each SSD endpoint
    /// (DESIGN.md §14). Composes with every topology — direct, tiered,
    /// pooled — because [`SystemConfig::build_ports`] attaches it
    /// per-endpoint; a disabled or zero-capacity spec attaches nothing
    /// (the `cxl`-bit-identity guarantee).
    pub cache: CacheSpec,
    /// Deterministic fault schedule (DESIGN.md §15): link CRC errors
    /// with burst windows, media latency spikes, controller timeouts,
    /// and an optional scheduled hard degradation of one endpoint.
    /// Composes with every topology because [`SystemConfig::build_ports`]
    /// arms it per-endpoint; an inert spec (all rates zero) attaches
    /// nothing — `cxl-ras` at zero rates is bit-identical to `cxl`.
    pub ras: FaultSpec,
    /// Online serving front door (DESIGN.md §16): open-loop arrivals,
    /// admission control, deadlines and load shedding, with each request
    /// expanded into warp work. Composes with every topology because the
    /// coordinator swaps the warps' op source, not the memory path; an
    /// inert spec (disabled or zero rate) builds no front door — the run
    /// is bit-identical to the same config without serving.
    pub serve: ServeSpec,
    /// Span tracing + latency-attribution ledger (DESIGN.md §18,
    /// `rust/src/obs/`). Disabled by default and structurally inert —
    /// no named config arms it; the `obs` experiment (and the
    /// `sim.obs` TOML key) do.
    pub obs: ObsSpec,
    /// Flight recorder (DESIGN.md §19, `rust/src/telemetry/`): epoch
    /// time-series frames + health monitors. Disabled by default and
    /// structurally inert — no named config arms it; the `telemetry`
    /// experiment (and the `sim.telemetry` TOML key) do.
    pub telemetry: TelemetrySpec,
}

impl SystemConfig {
    /// Baseline scale: 4 MiB local GPU memory, 40 MiB footprint, 64
    /// warps. Deliberately scaled down from real hardware so every
    /// figure's full sweep runs in seconds; all configs share the scale,
    /// so the paper's *ratios* are preserved.
    pub fn base() -> SystemConfig {
        SystemConfig {
            name: "cxl".into(),
            strategy: MemStrategy::Cxl,
            media: MediaKind::Ddr5,
            controller: ControllerKind::Panmnesia,
            sr_policy: SrPolicy::Off,
            ds_enabled: false,
            local_bytes: 4 << 20,
            footprint: 40 << 20,
            llc: LlcConfig::default_vortex(),
            warps: 16,
            mlp: 4,
            total_ops: 300_000,
            seed: 0xC11A,
            uvm_block: 16 << 10,
            ports: 4,
            ds_capacity: 1 << 20,
            timeline: false,
            media_per_port: None,
            tier: TierConfig::default(),
            fabric: FabricSpec::default(),
            pool_shards: 0,
            cache: CacheSpec::default(),
            ras: FaultSpec::default(),
            serve: ServeSpec::default(),
            obs: ObsSpec::default(),
            telemetry: TelemetrySpec::default(),
        }
    }

    /// Construct the root-port (or pooled-endpoint) set this
    /// configuration describes: one port per `ports`, media from
    /// `media_per_port` (fallback `media`), shared SR policy, DS only
    /// on SSD media. The direct and fabric topologies build their
    /// endpoints through this one helper so a pooled endpoint is
    /// port-for-port identical to its direct-attached twin.
    pub fn build_ports(&self) -> Vec<RootPort> {
        (0..self.ports)
            .map(|i| {
                let media = self
                    .media_per_port
                    .as_ref()
                    .and_then(|m| m.get(i).copied())
                    .unwrap_or(self.media);
                let ep = match media {
                    MediaKind::Ddr5 => EpBackend::Dram(DramModel::new(DramTimings::ddr5_5600())),
                    ssd => EpBackend::Ssd(SsdModel::new(SsdParams::for_kind(ssd))),
                };
                RootPort::new(
                    i,
                    self.controller,
                    ep,
                    self.sr_policy,
                    self.ds_enabled && media.is_ssd(),
                    self.ds_capacity,
                )
                .with_cache(self.cache)
                .with_ras(self.ras, self.seed)
            })
            .collect()
    }

    /// A named configuration from the paper's evaluation (plus this
    /// repo's extensions). One line per name, stating the paper artifact
    /// it serves:
    ///
    /// * `gpu-dram` — the ideal baseline every figure normalizes to
    ///   (local memory holds the whole footprint).
    /// * `uvm` — Unified Virtual Memory comparator (Fig. 9a, headline).
    /// * `gds` — GPUDirect Storage comparator (Fig. 9b).
    /// * `cxl` — plain CXL expander, no SR/DS (Figs. 9a–9d).
    /// * `cxl-naive` — SR with the naive next-line policy (Fig. 9d).
    /// * `cxl-dyn` — SR with the dynamic-range policy (Fig. 9d).
    /// * `cxl-sr` — SR with the full window policy (Figs. 9b–9e).
    /// * `cxl-ds` — SR + Deterministic Store (Figs. 9b, 9c, 9e).
    /// * `cxl-smt` — PCIe-era commercial EP controller comparator
    ///   (Fig. 3b, headline's 1.36x).
    /// * `cxl-hybrid` — mixed DRAM/SSD ports, static HDM split (Fig. 1a
    ///   topology; ablation A3).
    /// * `cxl-tier` — hybrid ports + interleaved HDM + hot-page
    ///   migration (DESIGN.md §12, `tiering` experiment).
    /// * `cxl-tier-static` — `cxl-tier` topology with migration disabled
    ///   (the tiering ablation point).
    /// * `cxl-pool` — the expander behind a pooled virtual CXL switch
    ///   (DESIGN.md §13, `multi-tenant` experiment); engines mirror
    ///   `cxl`, so a single-tenant pool is bit-identical to direct
    ///   attachment (the passthrough invariant).
    /// * `cxl-pool-qos` — `cxl-pool` plus the per-tenant QoS token
    ///   bucket on switch ingress (the QoS ablation point).
    /// * `cxl-pool-shard` — `cxl-pool` with the sharded conservative-
    ///   lookahead coordinator armed (DESIGN.md §17, `pool-scale`
    ///   experiment): identical switch spec, so results are
    ///   bit-identical to `cxl-pool`; only `pool_shards` (wall-clock
    ///   parallelism) differs.
    /// * `cxl-cache` — `cxl` plus the expander-side device DRAM cache
    ///   with adaptive admission (DESIGN.md §14, `cache` experiment);
    ///   at zero capacity it is bit-identical to `cxl`.
    /// * `cxl-cache-bypass` — `cxl-cache` with the admission predictor
    ///   disabled (every miss installs): the ablation that prices the
    ///   streaming-bypass capability.
    /// * `cxl-ras` — `cxl` plus the representative RAS fault schedule
    ///   (DESIGN.md §15, `ras` experiment): link CRC retries with burst
    ///   windows, media latency spikes, controller timeouts. With every
    ///   rate zeroed it is bit-identical to `cxl`.
    /// * `cxl-pool-ras` — `cxl-pool` plus the same fault schedule: the
    ///   degraded-endpoint failover scenario on the shared switch (WRR
    ///   demotion, dirty-line rescue, victim-tail bound in `BENCH_ras`).
    /// * `cxl-serve` — `cxl` driven by the online serving front door
    ///   (DESIGN.md §16, `serve` experiment): open-loop Poisson arrivals
    ///   expand into weight-read + KV-append warp work, with admission
    ///   control, SLO deadlines and load shedding. With the arrival rate
    ///   zeroed it is bit-identical to `cxl`.
    /// * `cxl-pool-serve` — `cxl-pool-qos` under the same front door:
    ///   the serving knee behind the shared QoS switch. With the rate
    ///   zeroed it is bit-identical to `cxl-pool-qos`.
    ///
    /// Panics on an unknown name; [`SystemConfig::try_named`] is the
    /// message-not-panic variant for CLI/config paths.
    pub fn named(name: &str, media: MediaKind) -> SystemConfig {
        Self::try_named(name, media).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`SystemConfig::named`], but an unknown name is a `Result` error
    /// with the known-name catalog instead of a panic.
    pub fn try_named(name: &str, media: MediaKind) -> Result<SystemConfig, String> {
        let mut c = SystemConfig::base();
        c.name = name.into();
        c.media = media;
        match name {
            "gpu-dram" => {
                c.strategy = MemStrategy::GpuDram;
                // Ideal: everything fits locally.
                c.local_bytes = c.footprint;
            }
            "uvm" => c.strategy = MemStrategy::Uvm,
            "gds" => c.strategy = MemStrategy::Gds,
            "cxl" => c.strategy = MemStrategy::Cxl,
            "cxl-naive" => {
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Naive;
            }
            "cxl-dyn" => {
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Dynamic;
            }
            "cxl-sr" => {
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Window;
            }
            "cxl-ds" => {
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Window;
                c.ds_enabled = true;
            }
            "cxl-smt" => {
                c.strategy = MemStrategy::Cxl;
                c.controller = ControllerKind::Smt;
            }
            "cxl-hybrid" => {
                // Heterogeneous expander: alternate DRAM and SSD ports
                // behind one host bridge (Fig. 1a's mixed topology),
                // with SR + DS enabled for the SSD ports.
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Window;
                c.ds_enabled = true;
                c.media_per_port = Some(
                    (0..c.ports)
                        .map(|i| if i % 2 == 0 { MediaKind::Ddr5 } else { media })
                        .collect(),
                );
            }
            "cxl-tier" | "cxl-tier-static" => {
                // The hybrid topology with the tiering subsystem: HDM
                // windows are grouped per media class and way-interleaved
                // within each group, and (for `cxl-tier`) the migration
                // engine promotes hot SSD-resident pages onto the DRAM
                // ports each epoch. `cxl-tier-static` keeps the identical
                // topology and tracker but freezes placement — the
                // ablation that isolates the migration win.
                c.strategy = MemStrategy::Cxl;
                c.sr_policy = SrPolicy::Window;
                c.ds_enabled = true;
                c.media_per_port = Some(
                    (0..c.ports)
                        .map(|i| if i % 2 == 0 { MediaKind::Ddr5 } else { media })
                        .collect(),
                );
                c.tier.enabled = true;
                c.tier.migrate = name == "cxl-tier";
            }
            "cxl-cache" | "cxl-cache-bypass" => {
                // Expander-side device cache (DESIGN.md §14): engines
                // mirror `cxl` (SR/DS off) so the cache's effect is
                // isolated against the plain expander; the `-bypass`
                // variant admits every miss — ablating the adaptive
                // admission predictor, whose whole job is keeping
                // streaming scans out of the device DRAM.
                c.strategy = MemStrategy::Cxl;
                c.cache.enabled = true;
                if name == "cxl-cache-bypass" {
                    c.cache = c.cache.admit_all();
                }
            }
            "cxl-ras" => {
                // RAS fault injection on the plain expander (DESIGN.md
                // §15): engines mirror `cxl` exactly; only the fault
                // schedule is armed, so every delta against `cxl` is
                // attributable to injected faults and their recovery.
                c.strategy = MemStrategy::Cxl;
                c.ras = FaultSpec::representative();
            }
            "cxl-pool-ras" => {
                // The pooled fabric under the same fault schedule:
                // pooled endpoints retry and degrade exactly as direct
                // ones, plus the switch-side failover machinery (WRR
                // share demotion) for degraded-endpoint scenarios.
                c.strategy = MemStrategy::Cxl;
                c.fabric.enabled = true;
                c.ras = FaultSpec::representative();
            }
            "cxl-serve" => {
                // Serving front door on the plain expander (DESIGN.md
                // §16): memory engines mirror `cxl` exactly; only the
                // request layer is armed, so every delta against `cxl`
                // is attributable to open-loop arrivals and admission
                // control.
                c.strategy = MemStrategy::Cxl;
                c.serve = ServeSpec::representative();
            }
            "cxl-pool-serve" => {
                // The serving front door over the QoS-pooled fabric:
                // requests are admitted at the front door, then their
                // memory traffic is shaped by the switch ingress bucket —
                // the two throttles the `serve` experiment compares.
                c.strategy = MemStrategy::Cxl;
                c.fabric.enabled = true;
                c.fabric.qos = true;
                c.serve = ServeSpec::representative();
            }
            "cxl-pool" | "cxl-pool-qos" | "cxl-pool-shard" => {
                // Pooled fabric (DESIGN.md §13): the expander endpoints
                // sit behind a shared virtual CXL switch. Engines stay
                // exactly as in `cxl` so the single-tenant, no-QoS pool
                // reproduces direct attachment bit-identically; the
                // `-qos` variant arms the per-tenant token bucket. The
                // `-shard` variant keeps `cxl-pool`'s exact switch spec
                // (bit-identity across the two is a determinism-suite
                // guarantee) and arms the sharded coordinator's
                // auto shard count (DESIGN.md §17).
                c.strategy = MemStrategy::Cxl;
                c.fabric.enabled = true;
                c.fabric.qos = name == "cxl-pool-qos";
            }
            other => {
                return Err(format!(
                    "unknown configuration `{other}` (known: {})",
                    Self::known_names().join(", ")
                ))
            }
        }
        Ok(c)
    }

    /// All evaluation-relevant configuration names.
    pub fn known_names() -> &'static [&'static str] {
        &[
            "gpu-dram", "uvm", "gds", "cxl", "cxl-naive", "cxl-dyn", "cxl-sr", "cxl-ds",
            "cxl-smt", "cxl-hybrid", "cxl-tier", "cxl-tier-static", "cxl-pool",
            "cxl-pool-qos", "cxl-pool-shard", "cxl-cache", "cxl-cache-bypass", "cxl-ras",
            "cxl-pool-ras", "cxl-serve", "cxl-pool-serve",
        ]
    }

    /// Scale the system down for SSD-expander experiments (Figs. 9b-9e):
    /// SSD media latencies are µs-to-ms, so the footprint must be small
    /// enough that the trace covers it within a tractable op budget. All
    /// configs within one figure share this scale, preserving ratios.
    pub fn ssd_scale(&mut self) -> &mut Self {
        self.footprint = 5 << 20;
        self.local_bytes = if self.strategy == MemStrategy::GpuDram {
            self.footprint
        } else {
            512 << 10
        };
        self.llc.capacity = 256 << 10;
        self.ds_capacity = 256 << 10;
        self
    }

    /// Effective shard count for a sharded pool run over `tenants`
    /// tenants: the `pool_shards` knob, where `0` (auto) means one
    /// shard per tenant — maximum overlap; the engine clamps to the
    /// tenant count either way.
    pub fn effective_shards(&self, tenants: usize) -> usize {
        if self.pool_shards == 0 {
            tenants.max(1)
        } else {
            self.pool_shards
        }
    }

    /// Apply overrides from a parsed TOML document (`[sim]` table).
    pub fn apply_toml(&mut self, doc: &Document) {
        self.local_bytes = doc.int_or("sim.local_bytes", self.local_bytes as i64) as u64;
        self.footprint = doc.int_or("sim.footprint", self.footprint as i64) as u64;
        self.warps = doc.int_or("sim.warps", self.warps as i64) as usize;
        self.mlp = doc.int_or("sim.mlp", self.mlp as i64) as usize;
        self.total_ops = doc.int_or("sim.total_ops", self.total_ops as i64) as usize;
        self.seed = doc.int_or("sim.seed", self.seed as i64) as u64;
        self.uvm_block = doc.int_or("sim.uvm_block", self.uvm_block as i64) as u64;
        self.ports = doc.int_or("sim.ports", self.ports as i64) as usize;
        self.ds_capacity = doc.int_or("sim.ds_capacity", self.ds_capacity as i64) as u64;
        self.timeline = doc.bool_or("sim.timeline", self.timeline);
        self.pool_shards = doc.int_or("sim.pool_shards", self.pool_shards as i64) as usize;
        self.cache.capacity_bytes =
            doc.int_or("sim.cache_bytes", self.cache.capacity_bytes as i64) as u64;
        self.serve.enabled = doc.bool_or("sim.serve", self.serve.enabled);
        self.serve.rate_rps =
            doc.int_or("sim.serve_rps", self.serve.rate_rps as i64) as f64;
        self.obs.enabled = doc.bool_or("sim.obs", self.obs.enabled);
        self.obs.sample_shift =
            doc.int_or("sim.obs_shift", self.obs.sample_shift as i64) as u32;
        self.telemetry.enabled = doc.bool_or("sim.telemetry", self.telemetry.enabled);
        self.telemetry.epoch =
            doc.int_or("sim.telemetry_epoch", self.telemetry.epoch as i64) as u64;
    }
}

/// Parse a media name from the CLI (`dram|optane|znand|nand`).
pub fn media_from_name(name: &str) -> Option<MediaKind> {
    match name.to_ascii_lowercase().as_str() {
        "dram" | "ddr5" => Some(MediaKind::Ddr5),
        "optane" | "pram" | "o" => Some(MediaKind::Optane),
        "znand" | "z-nand" | "z" => Some(MediaKind::Znand),
        "nand" | "n" => Some(MediaKind::Nand),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_configs_resolve() {
        for name in SystemConfig::known_names() {
            let c = SystemConfig::named(name, MediaKind::Znand);
            assert_eq!(c.name, *name);
        }
    }

    #[test]
    fn gpu_dram_fits_everything_locally() {
        let c = SystemConfig::named("gpu-dram", MediaKind::Ddr5);
        assert_eq!(c.local_bytes, c.footprint);
    }

    #[test]
    fn cxl_variants_set_engines() {
        assert_eq!(SystemConfig::named("cxl", MediaKind::Znand).sr_policy, SrPolicy::Off);
        assert_eq!(
            SystemConfig::named("cxl-naive", MediaKind::Znand).sr_policy,
            SrPolicy::Naive
        );
        assert_eq!(
            SystemConfig::named("cxl-dyn", MediaKind::Znand).sr_policy,
            SrPolicy::Dynamic
        );
        let sr = SystemConfig::named("cxl-sr", MediaKind::Znand);
        assert_eq!(sr.sr_policy, SrPolicy::Window);
        assert!(!sr.ds_enabled);
        let ds = SystemConfig::named("cxl-ds", MediaKind::Znand);
        assert!(ds.ds_enabled);
        assert_eq!(
            SystemConfig::named("cxl-smt", MediaKind::Ddr5).controller,
            ControllerKind::Smt
        );
    }

    #[test]
    fn tier_configs_set_topology_and_migration() {
        let tier = SystemConfig::named("cxl-tier", MediaKind::Znand);
        assert!(tier.tier.enabled && tier.tier.migrate);
        assert!(tier.ds_enabled);
        let media = tier.media_per_port.as_ref().unwrap();
        assert!(media.iter().step_by(2).all(|m| *m == MediaKind::Ddr5));
        assert!(media.iter().skip(1).step_by(2).all(|m| *m == MediaKind::Znand));
        let ablation = SystemConfig::named("cxl-tier-static", MediaKind::Znand);
        assert!(ablation.tier.enabled && !ablation.tier.migrate);
        assert_eq!(ablation.media_per_port, tier.media_per_port);
        // Untiered configs never enable the subsystem.
        assert!(!SystemConfig::named("cxl-hybrid", MediaKind::Znand).tier.enabled);
    }

    #[test]
    fn cache_configs_set_the_device_cache() {
        use crate::expander::AdmitPolicy;
        let cached = SystemConfig::named("cxl-cache", MediaKind::Znand);
        assert!(cached.cache.enabled);
        assert_eq!(cached.cache.admit.policy, AdmitPolicy::Adaptive);
        assert_eq!(cached.sr_policy, SrPolicy::Off, "engines mirror plain cxl");
        assert!(!cached.ds_enabled);
        let ablation = SystemConfig::named("cxl-cache-bypass", MediaKind::Znand);
        assert!(ablation.cache.enabled);
        assert_eq!(ablation.cache.admit.policy, AdmitPolicy::AdmitAll);
        // No other config enables the cache.
        assert!(!SystemConfig::named("cxl", MediaKind::Znand).cache.enabled);
        assert!(!SystemConfig::named("cxl-ds", MediaKind::Znand).cache.enabled);
    }

    #[test]
    fn build_ports_attaches_the_cache_to_ssd_endpoints_only() {
        let mut c = SystemConfig::named("cxl-cache", MediaKind::Znand);
        c.media_per_port =
            Some(vec![MediaKind::Ddr5, MediaKind::Znand, MediaKind::Ddr5, MediaKind::Znand]);
        let ports = c.build_ports();
        for (i, p) in ports.iter().enumerate() {
            assert_eq!(p.cache.is_some(), i % 2 == 1, "port {i} cache attachment");
        }
        // Zero capacity attaches nothing anywhere.
        c.cache.capacity_bytes = 0;
        assert!(c.build_ports().iter().all(|p| p.cache.is_none()));
    }

    #[test]
    fn ras_configs_arm_the_fault_schedule() {
        let ras = SystemConfig::named("cxl-ras", MediaKind::Znand);
        assert!(ras.ras.enabled && !ras.ras.is_inert());
        assert_eq!(ras.sr_policy, SrPolicy::Off, "engines mirror plain cxl");
        assert!(!ras.fabric.enabled && !ras.cache.enabled);
        let pool = SystemConfig::named("cxl-pool-ras", MediaKind::Znand);
        assert!(pool.fabric.enabled && !pool.fabric.qos && !pool.ras.is_inert());
        // Every built port carries the fault state...
        assert!(ras.build_ports().iter().all(|p| p.ras.is_some()));
        // ...and zeroing the rates attaches nothing (bit-transparency).
        let mut zeroed = ras.clone();
        zeroed.ras = FaultSpec { enabled: true, ..FaultSpec::default() };
        assert!(zeroed.build_ports().iter().all(|p| p.ras.is_none()));
        assert!(!SystemConfig::named("cxl", MediaKind::Znand).ras.enabled);
        assert!(!SystemConfig::named("cxl-pool", MediaKind::Znand).ras.enabled);
    }

    #[test]
    fn serve_configs_arm_the_front_door() {
        let serve = SystemConfig::named("cxl-serve", MediaKind::Ddr5);
        assert!(serve.serve.enabled && !serve.serve.is_inert());
        assert_eq!(serve.sr_policy, SrPolicy::Off, "engines mirror plain cxl");
        assert!(!serve.fabric.enabled && !serve.cache.enabled);
        let pool = SystemConfig::named("cxl-pool-serve", MediaKind::Ddr5);
        assert!(pool.fabric.enabled && pool.fabric.qos && !pool.serve.is_inert());
        // Zeroing the arrival rate makes the spec inert — the
        // bit-transparency lever the determinism suite leans on.
        let mut zeroed = serve.clone();
        zeroed.serve.rate_rps = 0.0;
        assert!(zeroed.serve.is_inert());
        assert!(!SystemConfig::named("cxl", MediaKind::Ddr5).serve.enabled);
        assert!(!SystemConfig::named("cxl-pool-qos", MediaKind::Ddr5).serve.enabled);
    }

    #[test]
    fn obs_toml_overrides_apply() {
        let doc = crate::util::toml::parse("[sim]\nobs = true\nobs_shift = 0").unwrap();
        let mut c = SystemConfig::base();
        assert!(!c.obs.enabled, "tracing is off by default (structural inertness)");
        c.apply_toml(&doc);
        assert!(c.obs.enabled);
        assert_eq!(c.obs.sample_shift, 0);
    }

    #[test]
    fn telemetry_toml_overrides_apply() {
        let doc =
            crate::util::toml::parse("[sim]\ntelemetry = true\ntelemetry_epoch = 25000000")
                .unwrap();
        let mut c = SystemConfig::base();
        assert!(!c.telemetry.enabled, "recorder is off by default (structural inertness)");
        c.apply_toml(&doc);
        assert!(c.telemetry.enabled);
        assert_eq!(c.telemetry.epoch, 25 * crate::sim::US);
    }

    #[test]
    fn serve_toml_overrides_apply() {
        let doc =
            crate::util::toml::parse("[sim]\nserve = true\nserve_rps = 50000").unwrap();
        let mut c = SystemConfig::base();
        c.apply_toml(&doc);
        assert!(c.serve.enabled);
        assert_eq!(c.serve.rate_rps, 50_000.0);
    }

    #[test]
    #[should_panic(expected = "unknown configuration")]
    fn unknown_name_panics() {
        SystemConfig::named("bogus", MediaKind::Ddr5);
    }

    #[test]
    fn try_named_reports_the_catalog_instead_of_panicking() {
        let err = SystemConfig::try_named("bogus", MediaKind::Ddr5).unwrap_err();
        assert!(err.contains("unknown configuration `bogus`"));
        assert!(err.contains("cxl-pool"), "error should list known names: {err}");
    }

    #[test]
    fn pool_configs_mirror_cxl_plus_fabric() {
        let cxl = SystemConfig::named("cxl", MediaKind::Znand);
        let pool = SystemConfig::named("cxl-pool", MediaKind::Znand);
        assert!(pool.fabric.enabled && !pool.fabric.qos);
        assert_eq!(pool.strategy, cxl.strategy);
        assert_eq!(pool.sr_policy, cxl.sr_policy);
        assert_eq!(pool.ds_enabled, cxl.ds_enabled);
        assert_eq!(pool.ports, cxl.ports);
        let qos = SystemConfig::named("cxl-pool-qos", MediaKind::Znand);
        assert!(qos.fabric.enabled && qos.fabric.qos);
        assert!(!SystemConfig::named("cxl", MediaKind::Znand).fabric.enabled);
    }

    #[test]
    fn pool_shard_config_keeps_the_serial_pool_switch_spec() {
        // The §17 bit-identity guarantee leans on this: `cxl-pool-shard`
        // must describe the exact same simulated machine as `cxl-pool` —
        // the shard count is wall-clock parallelism, not model state.
        let pool = SystemConfig::named("cxl-pool", MediaKind::Znand);
        let shard = SystemConfig::named("cxl-pool-shard", MediaKind::Znand);
        assert_eq!(shard.fabric, pool.fabric);
        assert_eq!(shard.strategy, pool.strategy);
        assert_eq!(shard.sr_policy, pool.sr_policy);
        assert_eq!(shard.ports, pool.ports);
        // The knob: 0 = auto (one shard per tenant), explicit otherwise.
        assert_eq!(shard.pool_shards, 0);
        assert_eq!(shard.effective_shards(8), 8);
        assert_eq!(shard.effective_shards(0), 1);
        let mut pinned = shard.clone();
        pinned.pool_shards = 4;
        assert_eq!(pinned.effective_shards(64), 4);
    }

    #[test]
    fn build_ports_follows_media_per_port_and_gates_ds_on_ssd() {
        let c = SystemConfig::named("cxl-hybrid", MediaKind::Znand);
        let ports = c.build_ports();
        assert_eq!(ports.len(), c.ports);
        for (i, p) in ports.iter().enumerate() {
            assert_eq!(p.backend.is_ssd(), i % 2 == 1, "port {i} media");
            assert_eq!(p.ds.enabled, p.backend.is_ssd(), "DS only fronts SSD media");
        }
    }

    #[test]
    fn toml_overrides_apply() {
        let doc = crate::util::toml::parse("[sim]\nwarps = 8\ntotal_ops = 1000\npool_shards = 4")
            .unwrap();
        let mut c = SystemConfig::base();
        c.apply_toml(&doc);
        assert_eq!(c.warps, 8);
        assert_eq!(c.total_ops, 1000);
        assert_eq!(c.pool_shards, 4);
    }

    #[test]
    fn media_names_parse() {
        assert_eq!(media_from_name("znand"), Some(MediaKind::Znand));
        assert_eq!(media_from_name("O"), Some(MediaKind::Optane));
        assert_eq!(media_from_name("bogus"), None);
    }
}
